"""Serving example: batched generation with the KV-cache engine across
three architecture families (dense GQA / SSM / hybrid) — prefill builds the
cache, decode extends it token by token; windowed decode demonstrates the
long-context ring buffer.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.engine import Engine


def main():
    for arch in ["granite-3-2b", "mamba2-370m", "hymba-1.5b"]:
        cfg = registry.smoke_arch(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, max_len=96)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab_size)
        t0 = time.time()
        out = eng.generate(prompt, steps=32, temperature=0.7,
                           key=jax.random.PRNGKey(2))
        print(f"{arch:14s} [{cfg.family:6s}] generated {out.shape} "
              f"in {time.time()-t0:.2f}s")

    # windowed decode: dense arch with a sliding-window cache
    cfg = registry.smoke_arch("granite-3-2b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=256, window=32)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                cfg.vocab_size)
    out = eng.generate(prompt, steps=64)
    print(f"windowed decode (ring buffer 32): {out.shape} OK")


if __name__ == "__main__":
    main()
