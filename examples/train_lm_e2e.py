"""End-to-end driver: train a ~100M-param LM with VRL-SGD for a few hundred
steps (CPU-scaled by default; pass --full-width for the real 100M run).

This is the deliverable-(b) end-to-end example: real model, non-iid data
pipeline, periodic sync, checkpointing, and final average-model perplexity.

  PYTHONPATH=src python examples/train_lm_e2e.py                 # ~3 min CPU
  PYTHONPATH=src python examples/train_lm_e2e.py --full-width \
      --steps 300                                                # ~100M run
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import registry
from repro.configs.base import VRLConfig
from repro.data import lm_token_stream
from repro.models import transformer as T
from repro.train.loss import cross_entropy_lm
from repro.train.train_loop import make_train_step


def build_cfg(full_width: bool):
    base = registry.get_arch("qwen2-0.5b")
    if full_width:
        # ~100M params: 8 layers of qwen2-0.5b width, 32k vocab
        return dataclasses.replace(base, num_layers=8, vocab_size=32_768)
    return registry.smoke_arch("qwen2-0.5b", num_layers=4, d_model=128,
                               d_ff=512, vocab_size=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = build_cfg(args.full_width)
    # Clipped SGD inner step. NOTE (measured, see EXPERIMENTS.md): the Δ
    # correction is calibrated in raw-gradient units by eq. (4), so adaptive
    # inner optimizers (Adam) silently break the variance reduction — the
    # framework exposes them for research but the faithful path is SGD.
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=args.k,
                    learning_rate=1.0, warmup=True, clip_norm=5.0,
                    inner_optimizer="sgd", weight_decay=0.0)
    bundle = make_train_step(cfg, vrl, remat=args.full_width)
    state = bundle.init_state(jax.random.PRNGKey(0), args.workers)
    n = sum(p.size for p in jax.tree.leaves(state.params)) // args.workers
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"-> {n/1e6:.1f}M params x {args.workers} workers")

    data = lm_token_stream(args.workers, args.seq, cfg.vocab_size,
                           steps=args.steps, batch=args.batch, alpha=0.02,
                           seed=0)
    step = jax.jit(bundle.train_step)

    @jax.jit
    def eval_ppl(state, toks, labels):
        logits, _ = T.forward(cfg, bundle.average_model(state),
                              toks.reshape(-1, args.seq))
        return jnp.exp(cross_entropy_lm(logits, labels.reshape(-1, args.seq)))

    t0 = time.time()
    for t in range(args.steps):
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, loss = step(state, toks, labels)
        if (t + 1) % 25 == 0 or t == 0:
            ppl = float(eval_ppl(state, toks, labels))
            print(f"step {t+1:4d}  loss {float(loss):.4f}  "
                  f"avg-model ppl {ppl:.1f}  "
                  f"[{(time.time()-t0)/(t+1):.2f}s/step, "
                  f"{int(state.step)-int(state.last_sync)} since sync]")
    ckpt.save(args.ckpt, state, meta={"steps": args.steps})
    print(f"checkpoint -> {args.ckpt}; total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
