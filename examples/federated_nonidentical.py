"""Federated-style example: the paper's §6 protocol on the transfer-learning
analog — 8 workers, disjoint class shards, MLP on frozen features,
k=20 (the paper's Table 2 hyper-parameters), with warm-up ablation.

  PYTHONPATH=src python examples/federated_nonidentical.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import run_mlp_task  # noqa: E402
from repro.data import feature_classification, label_skew
from repro.data.partition import class_shard_partition


def main():
    data = feature_classification(n=4096, dim=256, num_classes=64, seed=0)
    parts = class_shard_partition(data.y, 8, seed=0)
    print(f"8 workers, class-sharded: label skew (TV) = "
          f"{label_skew(data.y, parts):.3f} (1.0 = fully disjoint)")
    results = {}
    for alg, warm in [("ssgd", False), ("vrl_sgd", False),
                      ("vrl_sgd", True), ("local_sgd", False),
                      ("easgd", False)]:
        tag = alg + ("-w" if warm else "")
        losses = run_mlp_task(alg, num_workers=8, batch=32, lr=0.5, k=20,
                              steps=300, partition="class_shard", data=data,
                              warmup=warm)
        results[tag] = (losses[10], float(np.mean(losses[-20:])))
        print(f"  {tag:12s} loss@10 {results[tag][0]:.4f}  "
              f"final {results[tag][1]:.4f}")
    print("expected ordering (paper Fig. 1): "
          "ssgd ≈ vrl_sgd(-w) < local_sgd < easgd")


if __name__ == "__main__":
    main()
