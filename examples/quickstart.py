"""Quickstart: train a tiny LM with VRL-SGD across 4 simulated workers on
non-identical data, then compare against Local SGD.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import compressors as cc
from repro.configs import registry
from repro.configs.base import EngineConfig, VRLConfig
from repro.data import lm_token_stream
from repro.models import transformer as T
from repro.train.loss import cross_entropy_lm
from repro.train.train_loop import make_train_step

WORKERS, BATCH, SEQ, STEPS, K = 4, 8, 32, 150, 20
CLIENTS = 16            # logical-client population for train_clients


def train(algorithm: str, data, compress: str | None = None,
          overlap: bool = False, shards: int = 1,
          moment_dtype: str = "float32", sm3: bool = False) -> list[float]:
    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm=algorithm, comm_period=K, learning_rate=0.2,
                    warmup=not overlap, overlap=overlap,
                    moment_dtype=moment_dtype, sm3=sm3,
                    engine=EngineConfig(shards=shards),
                    compress=(cc.parse_compressor(compress) if compress
                              else None))
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), WORKERS)
    if overlap:
        # overlapped rounds are a ROUND-level construct: drive whole
        # communication periods (k steps per call), not single steps
        rstep = jax.jit(bundle.round_step, donate_argnums=(0,))
    step = jax.jit(bundle.train_step)

    @jax.jit
    def eval_avg(state, toks, labels):
        logits, _ = T.forward(cfg, bundle.average_model(state),
                              toks.reshape(-1, SEQ))
        return cross_entropy_lm(logits, labels.reshape(-1, SEQ))

    losses = []
    if overlap:
        for r in range(STEPS // K):
            toks = jnp.stack([jnp.asarray(data[r * K + i])
                              for i in range(K)])
            labels = jnp.roll(toks, -1, axis=-1)
            state, _ = rstep(state, toks, labels)
            losses.append(float(eval_avg(state, toks[-1], labels[-1])))
        return losses
    for t in range(STEPS):
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, _ = step(state, toks, labels)
        losses.append(float(eval_avg(state, toks, labels)))
    return losses


def train_clients(data) -> list[float]:
    """VRL-SGD with partial participation: CLIENTS logical clients in a
    host-side store, cohorts of WORKERS gathered per round.  Each client
    keeps its own Δ / moments; sampled cohorts start from the server
    consensus and the round itself is the unchanged compiled executable."""
    from repro.core.clients import ClientStore, sample_cohort

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=K, learning_rate=0.2,
                    warmup=False)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), WORKERS)
    store = ClientStore(state, CLIENTS)
    rstep = jax.jit(bundle.round_step, donate_argnums=(0,))
    recenter = jax.jit(bundle.engine.recenter_drift)
    cdata = lm_token_stream(CLIENTS, SEQ, cfg.vocab_size, steps=STEPS,
                            batch=BATCH, alpha=0.02, seed=1)

    @jax.jit
    def eval_avg(state, toks, labels):
        logits, _ = T.forward(cfg, bundle.average_model(state),
                              toks.reshape(-1, SEQ))
        return cross_entropy_lm(logits, labels.reshape(-1, SEQ))

    losses = []
    for r in range(STEPS // K):
        cohort = sample_cohort(CLIENTS, WORKERS, r)
        st = recenter(store.gather(cohort, seed_params=r > 0))
        toks = jnp.stack([jnp.asarray(cdata[r * K + i][cohort])
                          for i in range(K)])
        labels = jnp.roll(toks, -1, axis=-1)
        st, _ = rstep(st, toks, labels)
        store.scatter(st, cohort)
        losses.append(float(eval_avg(st, toks[-1], labels[-1])))
    return losses


def train_elastic(data) -> list[float]:
    """VRL-SGD with elastic membership: worker 1 crashes a third of the
    way in and rejoins at two thirds — the run never stops, the other
    workers' invariants are repaired in place."""
    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=K, learning_rate=0.2,
                    membership=True)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), WORKERS)
    step = jax.jit(bundle.train_step)
    set_member = jax.jit(bundle.engine.set_membership)

    @jax.jit
    def eval_avg(state, toks, labels):
        logits, _ = T.forward(cfg, bundle.average_model(state),
                              toks.reshape(-1, SEQ))
        return cross_entropy_lm(logits, labels.reshape(-1, SEQ))

    mask = np.ones(WORKERS, np.float32)
    losses = []
    for t in range(STEPS):
        if t == STEPS // 3:              # crash: drop worker 1, repair
            mask[1] = 0.0
            state = set_member(state, mask)
        if t == 2 * STEPS // 3:          # rejoin from the consensus
            mask[1] = 1.0
            state = set_member(state, mask)
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, _ = step(state, toks, labels)
        losses.append(float(eval_avg(state, toks, labels)))
    return losses


def train_observed(data) -> None:
    """VRL-SGD with the telemetry stream on: every round lands a
    schema-versioned JSONL record (repro.obs) plus a one-pass jitted
    diagnostics read — the Σ Δ = 0 invariant residual, the ζ² dispersion
    proxy (1/n) Σ ‖Δᵢ − Δ̄‖², per-worker drift — and the report renders
    the stream afterwards."""
    import os
    import tempfile

    from repro.obs import MetricsWriter, read_metrics
    from repro.obs import diagnostics as obs_diag
    from repro.obs import report as obs_report

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=K, learning_rate=0.2,
                    warmup=False)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), WORKERS)
    rstep = jax.jit(bundle.round_step, donate_argnums=(0,))
    diag = jax.jit(bundle.engine.diagnostics)

    path = os.path.join(tempfile.mkdtemp(prefix="quickstart-obs-"),
                        "metrics.jsonl")
    with MetricsWriter(path, run_meta={"algorithm": "vrl_sgd",
                                       "workers": WORKERS, "k": K,
                                       "steps": STEPS}) as mw:
        for r in range(STEPS // K):
            toks = jnp.stack([jnp.asarray(data[r * K + i])
                              for i in range(K)])
            labels = jnp.roll(toks, -1, axis=-1)
            state, losses = rstep(state, toks, labels)
            rec = obs_diag.to_record(diag(state))
            rec["alarms"] = obs_diag.check_alarms(rec,
                                                  invariant_threshold=1e-3)
            mw.emit("round", t=(r + 1) * K, r=r + 1, k=K,
                    loss=float(jnp.mean(losses)))
            mw.emit("diag", t=(r + 1) * K, r=r + 1, **rec)
        mw.emit("run_end", steps=STEPS,
                avg_model_loss=float(jnp.mean(losses)))
    print(obs_report.summarize(read_metrics(path), label="quickstart"))


def main():
    cfg = registry.smoke_arch("qwen2-0.5b", vocab_size=64)
    print("non-identical data: each worker samples its own skewed unigram "
          "distribution (the paper's hard regime), k =", K)
    data = lm_token_stream(WORKERS, SEQ, cfg.vocab_size, steps=STEPS,
                           batch=BATCH, alpha=0.02, seed=0)
    # stl_sgd is Local SGD on a stagewise schedule: with no explicit
    # comm_schedule it defaults to the STL-SGD doubling ramp 1 -> K, so the
    # early rounds sync densely (cheap while the period is short) before
    # stretching to K.  Try it under the launch driver too:
    #   PYTHONPATH=src python -m repro.launch.train --algorithm stl_sgd \
    #       --comm-schedule stagewise --smoke
    for alg in ["vrl_sgd", "local_sgd", "ssgd", "stl_sgd"]:
        losses = train(alg, data)
        print(f"  {alg:10s} avg-model loss: start {losses[0]:.3f} -> "
              f"final {np.mean(losses[-10:]):.3f}")
    print("expected: vrl_sgd ≈ ssgd, both < local_sgd (paper Fig. 1); "
          "stl_sgd sits between (dense early syncs, Local-SGD tail)")

    # compressed sync (repro.comm): each sync transmits the int8-quantized
    # drift against the shared post-sync reference, with error feedback
    # carrying the quantization error to the next round — ~4x fewer bytes
    # per round for a near-identical trajectory.  On the launch driver:
    #   PYTHONPATH=src python -m repro.launch.train --smoke --compress int8
    losses_c = train("vrl_sgd", data, compress="int8")
    print(f"  {'vrl+int8':10s} avg-model loss: start {losses_c[0]:.3f} -> "
          f"final {np.mean(losses_c[-10:]):.3f}  "
          f"(sync payload quantized int8 + error feedback)")

    # overlapped rounds: the sync all-reduce is issued at round START over
    # the positions each worker transmitted at the PREVIOUS boundary, so it
    # runs concurrently with the k local steps and its (one-round-stale)
    # mean is folded in at the boundary — same bytes, the collective off
    # the critical path.  On the launch driver (add --deadline 0.1 to
    # simulate stragglers that retransmit their last position):
    #   PYTHONPATH=src python -m repro.launch.train --smoke --overlap
    losses_o = train("vrl_sgd", data, overlap=True)
    print(f"  {'vrl+ovlp':10s} avg-model loss (per round): start "
          f"{losses_o[0]:.3f} -> final {np.mean(losses_o[-3:]):.3f}  "
          f"(sync collective hidden behind the next round's local steps)")

    # sharded + shrunk engine state: shards=4 row-shards every (W, R, C)
    # flat buffer (layout-only padding on this single host device; on a
    # mesh carrying the shard axis the rows split across devices and the
    # sync stays ONE per-shard all-reduce), bf16 momentum halves mu, and
    # SM3 replaces Adam's dense nu with factored (row, col) max-stats.
    # On the launch driver:
    #   PYTHONPATH=src python -m repro.launch.train --smoke --shards 4 \
    #       --moment-dtype bfloat16 --sm3
    # and the dry-run memory artifact prices the engine state per device
    # (qwen2-0.5b: 6.51 -> 0.58 GiB/device at --shards 8 + bf16 moments):
    #   PYTHONPATH=src python -m repro.launch.dryrun --engine-mem \
    #       --arch qwen2-0.5b --shards 8 --moment-dtype bfloat16
    losses_q = train("vrl_sgd", data, shards=4, moment_dtype="bfloat16",
                     sm3=True)
    print(f"  {'vrl+shard':10s} avg-model loss: start {losses_q[0]:.3f} -> "
          f"final {np.mean(losses_q[-10:]):.3f}  "
          f"(4-way row-sharded buffers, bf16 + SM3 factored moments)")

    # Fault tolerance: with membership=True the state carries an
    # active-worker mask and every sync is a masked mean over it — a
    # crashed worker's rows stay in the buffers (nothing recompiles) but
    # no sync reads them.  engine.set_membership is the between-rounds
    # repair: it recentres Δ over the survivors (Σ_i Δ_i = 0 again, the
    # invariant that makes the next sync a correct VRL update) and
    # reseeds rejoiners from the continuing consensus.  Unlike
    # --deadline (a straggler who MISSES a round but keeps training),
    # a crash leaves the active set until its rejoin.  On the launch
    # driver the whole story is flag-driven — deterministic fault
    # injection, divergence rollback, atomic step checkpoints, and
    # elastic restarts that reshard a W-worker checkpoint onto W':
    #   PYTHONPATH=src python -m repro.launch.train --smoke --workers 8 \
    #       --membership --guard --ckpt /tmp/run --ckpt-every 10 \
    #       --faults "nan@3:12,crash@1:15,rejoin@1:30,killsave:20"
    #   PYTHONPATH=src python -m repro.launch.train --smoke --workers 4 \
    #       --ckpt /tmp/run --resume auto        # 8 -> 4, Δ recentred
    # What survives a crash: the newest COMPLETE ckpt-XXXXXXXX dir (the
    # save commits via atomic rename, so a mid-save kill leaves the
    # previous good step), the global step, params/Δ/bias/moments, and
    # compressor/layout metadata that refuses mismatched restores.
    losses_e = train_elastic(data)
    print(f"  {'vrl+elastic':10s} avg-model loss: start {losses_e[0]:.3f} "
          f"-> final {np.mean(losses_e[-10:]):.3f}  "
          f"(worker 1 crashed at step 50, rejoined at 100)")

    # Partial participation (federated scale): M logical clients live in
    # a host-side ClientStore behind W device slots; each round a
    # seed-deterministic cohort of W clients is gathered into the flat
    # buffers (one contiguous copy per buffer), Σ Δ is recentred over the
    # cohort, the UNCHANGED compiled round runs — still exactly one sync
    # all-reduce — and the rows scatter back.  Sampled cohorts start from
    # the server consensus (the federated broadcast); what persists per
    # client is its control variate, moments, and data shard.  On the
    # launch driver (--participation is just a cross-check that W = p·M):
    #   PYTHONPATH=src python -m repro.launch.train --smoke --workers 64 \
    #       --clients 256 --participation 0.25 --alpha 0.1
    # --clients == --workers is full participation and stays BITWISE the
    # storeless path (CI-gated).  Measured on the fig1 non-identical
    # task (benchmarks/step_time.py --bench participation, M=16):
    # rounds-to-target 16 / 34 / 73 at p = 1.0 / 0.5 / 0.25 — each round
    # does p times the gradient work, and the trade is almost exactly
    # inverse-proportional.
    losses_p = train_clients(data)
    print(f"  {'vrl+clients':10s} avg-model loss: start {losses_p[0]:.3f} "
          f"-> final {np.mean(losses_p[-3:]):.3f}  "
          f"({CLIENTS} clients, cohorts of {WORKERS}, one sync "
          f"all-reduce per round)")

    # Telemetry (repro.obs): the launch driver streams every round as a
    # schema-versioned JSONL record — loss, measured wire bytes, and a
    # one-pass jitted diagnostics read of the paper's invariants (Σ Δ = 0
    # residual, the ζ² control-variate dispersion proxy, per-worker
    # drift, masked non-finite counts) OUTSIDE the compiled round, so
    # the one-all-reduce contract is untouched.  On the launch driver:
    #   PYTHONPATH=src python -m repro.launch.train --smoke --diag \
    #       --metrics /tmp/run.jsonl --invariant-alarm 1e-3 --guard
    #   python scripts/report.py /tmp/run.jsonl           # or diff 2 runs
    # An --invariant-alarm trip feeds the same rollback path as --guard's
    # finiteness check.  The same stream + report, engine-level:
    print()
    train_observed(data)


if __name__ == "__main__":
    main()
