#!/usr/bin/env python
"""Summarize one metrics stream, or diff two.

Usage:
  python scripts/report.py run.jsonl              # one-run report
  python scripts/report.py clean.jsonl chaos.jsonl  # A-vs-B diff (+ both
                                                    # summaries with -v)

The input files are the schema-versioned JSONL streams a
``--metrics out.jsonl`` training run emits (see ``repro.obs.metrics``).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")))

from repro.obs import report  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("metrics", help="metrics JSONL file")
    ap.add_argument("other", nargs="?", default=None,
                    help="second metrics file: print a diff instead")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="with two files, also print both summaries")
    args = ap.parse_args(argv)

    a = report.load(args.metrics)
    if args.other is None:
        print(report.summarize(a, label=os.path.basename(args.metrics)))
        return 0
    b = report.load(args.other)
    labels = (os.path.basename(args.metrics), os.path.basename(args.other))
    if args.verbose:
        print(report.summarize(a, label=labels[0]))
        print()
        print(report.summarize(b, label=labels[1]))
        print()
    print(report.diff(a, b, labels=labels))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
