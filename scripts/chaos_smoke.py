"""CI chaos smoke: faulted + killed + resharded run vs the fault-free run.

Three driver invocations on an 8-device host mesh
(``--xla_force_host_platform_device_count=8``):

  1. clean    — W=8, no faults, 40 steps               -> clean.json
  2. chaos A  — W=8 with an injected NaN gradient, a crash/rejoin pair,
                and a simulated kill inside the step-20 checkpoint save;
                the process "dies" mid-run (--steps 24)
  3. chaos B  — ``--resume auto`` restart at W=4 (resharding the W=8
                checkpoint), same fault schedule, runs to 40 -> chaos.json

Gate: the chaos run's final average-model loss is finite and within
tolerance of the clean run's.  The trajectories legitimately differ
(membership churn + resharding change the effective batch), so the
tolerance is loose — this is a liveness-and-sanity gate, not a bitwise
one (bitwise full-mask parity is asserted in tests/test_fault.py).

Every run streams --metrics telemetry into ``results/chaos_metrics/``
(kept, unlike the tempdir — CI uploads it as an artifact).  The streams
are themselves gated: chaos A must record the guard rollback and the
crash membership change, chaos B the restore and the rejoin, and the
chaos-B report is rendered at the end (repro.obs.report).

Run from the repo root:  python scripts/chaos_smoke.py
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")))

from repro.obs import report as obs_report  # noqa: E402
from repro.obs.metrics import read_metrics  # noqa: E402

FAULTS = "nan@1:12,crash@1:15,rejoin@1:30,killsave:20"
METRICS_DIR = os.path.join("results", "chaos_metrics")
COMMON = ["--arch", "qwen2-0.5b", "--smoke", "--batch", "2", "--seq", "32",
          "--k", "5", "--lr", "0.02", "--backend", "xla", "--mesh-grid"]


def run(tag, extra, *, devices=8, check=True):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.abspath("src")
    cmd = [sys.executable, "-m", "repro.launch.train"] + COMMON + extra
    print(f"--- {tag}: {' '.join(extra)}", flush=True)
    proc = subprocess.run(cmd, env=env, text=True, capture_output=True)
    sys.stdout.write(proc.stdout[-3000:])
    sys.stderr.write(proc.stderr[-3000:])
    if check and proc.returncode != 0:
        raise SystemExit(f"{tag} failed with rc={proc.returncode}")
    return proc


def _events(path):
    return [r["event"] for r in read_metrics(path)]


def _check_stream(tag, path, expected):
    """Assert the run's telemetry stream recorded the expected events."""
    have = set(_events(path))
    missing = [e for e in expected if e not in have]
    if missing:
        raise SystemExit(f"{tag}: metrics stream {path} is missing "
                         f"expected events {missing} (has {sorted(have)})")
    print(f"{tag}: metrics stream ok — {sorted(have)}")


def main() -> int:
    work = tempfile.mkdtemp(prefix="chaos-smoke-")
    clean_json = os.path.join(work, "clean.json")
    chaos_json = os.path.join(work, "chaos.json")
    ckpt = os.path.join(work, "ckpt")
    # metrics land OUTSIDE the tempdir so CI can upload them
    os.makedirs(METRICS_DIR, exist_ok=True)
    m_clean = os.path.join(METRICS_DIR, "clean.jsonl")
    m_chaos_a = os.path.join(METRICS_DIR, "chaosA.jsonl")
    m_chaos_b = os.path.join(METRICS_DIR, "chaosB.jsonl")
    try:
        run("clean", ["--workers", "8", "--steps", "40",
                      "--loss-out", clean_json, "--metrics", m_clean])
        run("chaos-A (dies mid-run)",
            ["--workers", "8", "--steps", "24", "--membership", "--guard",
             "--faults", FAULTS, "--ckpt", ckpt, "--ckpt-every", "10",
             "--metrics", m_chaos_a])
        run("chaos-B (resume auto, resharded 8 -> 4)",
            ["--workers", "4", "--steps", "40", "--membership", "--guard",
             "--faults", FAULTS, "--ckpt", ckpt, "--ckpt-every", "10",
             "--resume", "auto", "--loss-out", chaos_json,
             "--metrics", m_chaos_b])
        with open(clean_json) as f:
            clean = json.load(f)["avg_model_loss"]
        with open(chaos_json) as f:
            chaos = json.load(f)["avg_model_loss"]
        tol = max(0.5, 0.15 * clean)
        print(f"clean avg_model_loss {clean:.4f}  "
              f"chaos avg_model_loss {chaos:.4f}  tol {tol:.4f}")
        if not (chaos == chaos and abs(chaos) != float("inf")):
            raise SystemExit("chaos run produced a non-finite final loss")
        if abs(chaos - clean) > tol:
            raise SystemExit(
                f"chaos final loss {chaos:.4f} deviates from clean "
                f"{clean:.4f} by more than {tol:.4f}")
        # the telemetry streams must have recorded the chaos timeline:
        # A trips the NaN guard (rollback) and loses worker 1 (crash),
        # B restores the checkpoint and sees the step-30 rejoin
        _check_stream("clean", m_clean,
                      ["run_start", "round", "sync", "diag", "run_end"])
        _check_stream("chaos-A", m_chaos_a,
                      ["fault", "rollback", "membership", "checkpoint"])
        _check_stream("chaos-B", m_chaos_b,
                      ["restore", "membership", "run_end"])
        print()
        print(obs_report.summarize(read_metrics(m_chaos_b),
                                   label="chaos-B"))
        print()
        print("chaos smoke OK")
        return 0
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
