"""CI chaos smoke: faulted + killed + resharded run vs the fault-free run.

Three driver invocations on an 8-device host mesh
(``--xla_force_host_platform_device_count=8``):

  1. clean    — W=8, no faults, 40 steps               -> clean.json
  2. chaos A  — W=8 with an injected NaN gradient, a crash/rejoin pair,
                and a simulated kill inside the step-20 checkpoint save;
                the process "dies" mid-run (--steps 24)
  3. chaos B  — ``--resume auto`` restart at W=4 (resharding the W=8
                checkpoint), same fault schedule, runs to 40 -> chaos.json

Gate: the chaos run's final average-model loss is finite and within
tolerance of the clean run's.  The trajectories legitimately differ
(membership churn + resharding change the effective batch), so the
tolerance is loose — this is a liveness-and-sanity gate, not a bitwise
one (bitwise full-mask parity is asserted in tests/test_fault.py).

Run from the repo root:  python scripts/chaos_smoke.py
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

FAULTS = "nan@1:12,crash@1:15,rejoin@1:30,killsave:20"
COMMON = ["--arch", "qwen2-0.5b", "--smoke", "--batch", "2", "--seq", "32",
          "--k", "5", "--lr", "0.02", "--backend", "xla", "--mesh-grid"]


def run(tag, extra, *, devices=8, check=True):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.abspath("src")
    cmd = [sys.executable, "-m", "repro.launch.train"] + COMMON + extra
    print(f"--- {tag}: {' '.join(extra)}", flush=True)
    proc = subprocess.run(cmd, env=env, text=True, capture_output=True)
    sys.stdout.write(proc.stdout[-3000:])
    sys.stderr.write(proc.stderr[-3000:])
    if check and proc.returncode != 0:
        raise SystemExit(f"{tag} failed with rc={proc.returncode}")
    return proc


def main() -> int:
    work = tempfile.mkdtemp(prefix="chaos-smoke-")
    clean_json = os.path.join(work, "clean.json")
    chaos_json = os.path.join(work, "chaos.json")
    ckpt = os.path.join(work, "ckpt")
    try:
        run("clean", ["--workers", "8", "--steps", "40",
                      "--loss-out", clean_json])
        run("chaos-A (dies mid-run)",
            ["--workers", "8", "--steps", "24", "--membership", "--guard",
             "--faults", FAULTS, "--ckpt", ckpt, "--ckpt-every", "10"])
        run("chaos-B (resume auto, resharded 8 -> 4)",
            ["--workers", "4", "--steps", "40", "--membership", "--guard",
             "--faults", FAULTS, "--ckpt", ckpt, "--ckpt-every", "10",
             "--resume", "auto", "--loss-out", chaos_json])
        with open(clean_json) as f:
            clean = json.load(f)["avg_model_loss"]
        with open(chaos_json) as f:
            chaos = json.load(f)["avg_model_loss"]
        tol = max(0.5, 0.15 * clean)
        print(f"clean avg_model_loss {clean:.4f}  "
              f"chaos avg_model_loss {chaos:.4f}  tol {tol:.4f}")
        if not (chaos == chaos and abs(chaos) != float("inf")):
            raise SystemExit("chaos run produced a non-finite final loss")
        if abs(chaos - clean) > tol:
            raise SystemExit(
                f"chaos final loss {chaos:.4f} deviates from clean "
                f"{clean:.4f} by more than {tol:.4f}")
        print("chaos smoke OK")
        return 0
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
