"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun*.jsonl. Prints markdown to stdout.

Roofline terms use two-point calibration: XLA's cost_analysis counts a
while-loop (scan) body ONCE, so
    per-layer = (2-layer unrolled run) - (scanned run)
    total     = scanned + (num_layers - 1) * per-layer
Collective bytes come from the scanned run's loop-aware HLO parse.

  PYTHONPATH=src python scripts/gen_experiments_tables.py
"""
from __future__ import annotations

import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16  # noqa: E402

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
K = 20


def load(paths):
    dedup = {}
    for path in paths:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                r = json.loads(line)
                dedup[(r["arch"], r["shape"], r["mesh"], r["fn"])] = r
    return dedup


def fmt_gib(b):
    return "?" if (b is None or b < 0) else f"{b/2**30:.1f}"


def corrected_terms(scanned: dict, u2: dict, num_layers: int):
    """Two-point calibration -> (t_compute, t_memory, t_collective, flops)."""
    body_f = max(u2["hlo_flops"] - scanned["hlo_flops"], 0.0)
    body_b = max(u2["hlo_bytes"] - scanned["hlo_bytes"], 0.0)
    flops = scanned["hlo_flops"] + (num_layers - 1) * body_f
    nbytes = scanned["hlo_bytes"] + (num_layers - 1) * body_b
    coll = scanned["coll_bytes"]  # loop-aware parser already scales
    return (flops / PEAK_FLOPS_BF16, nbytes / HBM_BW, coll / ICI_LINK_BW,
            flops)


def best_rows(fns: dict, kind: str):
    scanned = fns.get(kind)
    u2 = fns.get(f"{kind}+unroll+u2") or fns.get(f"{kind}+u2")
    return scanned, u2


def main():
    rows = load(["results/dryrun.jsonl", "results/dryrun_multi.jsonl"])
    by_combo = defaultdict(dict)
    for (arch, shape, mesh, fn), r in rows.items():
        by_combo[(arch, shape, mesh)][fn] = r
    archs = [a for a in registry.list_archs()
             if any(k[0] == a for k in by_combo)]

    print("### §Dry-run — compile/fit matrix\n")
    print("| arch | shape | single | GiB/dev | multi | GiB/dev |")
    print("|---|---|---|---|---|---|")
    for arch in archs:
        for shape in SHAPES:
            cells = []
            for mesh in ["single", "multi"]:
                fns = by_combo.get((arch, shape, mesh), {})
                r = (fns.get("train") or fns.get("prefill")
                     or fns.get("decode"))
                if r is None:
                    cells += ["—", "—"]
                else:
                    cells += ["OK" if r.get("ok") else "FAIL",
                              fmt_gib(r.get("per_device_bytes"))]
            print(f"| {arch} | {shape} | " + " | ".join(cells) + " |")

    print("\n### §Roofline — single-pod terms (per device, per step)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "bottleneck | useful ratio | model TFLOPs |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in archs:
        L = registry.get_arch(arch).num_layers
        for shape in SHAPES:
            fns = by_combo.get((arch, shape, "single"), {})
            kind = {"train_4k": "local", "prefill_32k": "prefill",
                    "decode_32k": "decode", "long_500k": "decode"}[shape]
            scanned, u2 = best_rows(fns, kind)
            if not scanned or not scanned.get("ok"):
                continue
            if u2 and u2.get("ok"):
                tc, tm, tl, flops = corrected_terms(scanned, u2, L)
            else:
                tc, tm = scanned["t_compute"], scanned["t_memory"]
                tl = scanned["t_collective"]
                flops = scanned["hlo_flops"]
            if shape == "train_4k" and "sync" in fns:
                tl += fns["sync"].get("t_collective", 0.0) / K
            bott = max((("compute", tc), ("memory", tm),
                        ("collective", tl)), key=lambda kv: kv[1])[0]
            chips = 256
            useful = scanned["model_flops"] / (flops * chips) if flops else 0
            print(f"| {arch} | {shape} | {tc*1e3:.2f} | {tm*1e3:.2f} | "
                  f"{tl*1e3:.2f} | **{bott}** | {useful:.3f} | "
                  f"{scanned['model_flops']/1e12:.1f} |")

    fails = [r for r in rows.values() if not r.get("ok")]
    if fails:
        print("\n### Failures\n")
        for r in fails:
            print(f"- {r['arch']}/{r['shape']}/{r['mesh']}/{r['fn']}: "
                  f"{r['error'][:200]}")


if __name__ == "__main__":
    main()
