"""Client sampling: cohorts, the host store, and the driver glue.

Layers, mirroring ``tests/test_fault.py``'s structure:

1. ``sample_cohort`` — seed-deterministic, distinct sorted int64 ids,
   identity at full participation, and every client participates over
   enough rounds (no starvation).
2. ``ClientStore`` engine layer — the M == W gather/round/scatter
   round-trip is BITWISE the storeless trajectory for every flat
   algorithm (the acceptance gate); a strict-subset cohort's Δ is
   recentred to Σ = 0 by ``Engine.recenter_drift``; consensus seeding
   replaces cohort params; scatter skips dead slots; the checkpoint
   tree (clients + server consensus) round-trips with named errors on
   mismatch; overlap and undersized populations are refused loudly.
3. Driver flag validation — malformed --clients/--participation combos
   exit early with named messages.
4. Driver smoke — a real M > W train run composed with crash/rejoin
   faults, in-process.

The collective-count acceptance (a gathered strict-subset cohort's round
is STILL exactly one sync all-reduce on an 8-device mesh — the compiled
round is unchanged by construction, and this pins it) runs in a
subprocess, same idiom as tests/test_fault.py.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VRLConfig
from repro.core import flat_algorithms, make_engine
from repro.core.clients import ClientStore, cohort_schedule, sample_cohort

# ----------------------------------------------------------------- sampler


def test_cohort_is_deterministic_sorted_distinct():
    a = sample_cohort(32, 8, round_index=3, seed=7)
    b = sample_cohort(32, 8, round_index=3, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int64
    assert (np.diff(a) > 0).all()                 # sorted AND distinct
    assert a.min() >= 0 and a.max() < 32
    c = sample_cohort(32, 8, round_index=4, seed=7)
    d = sample_cohort(32, 8, round_index=3, seed=8)
    assert not np.array_equal(a, c)
    assert not np.array_equal(a, d)


def test_full_participation_is_identity():
    np.testing.assert_array_equal(sample_cohort(6, 6, 11),
                                  np.arange(6, dtype=np.int64))


def test_every_client_participates():
    seen = set()
    for cohort in cohort_schedule(24, 6, rounds=40, seed=0):
        seen.update(cohort.tolist())
    assert seen == set(range(24))


def test_cohort_size_validated():
    with pytest.raises(ValueError, match=r"cohort_size must be in \[1, 4\]"):
        sample_cohort(4, 5, 0)
    with pytest.raises(ValueError, match="cohort_size must be in"):
        sample_cohort(4, 0, 0)


# ------------------------------------------------------------ engine layer

W = 4
TEMPLATE = {"w": jnp.zeros((12, 8)), "b": jnp.zeros((5,))}
P0 = {"w": jnp.ones((12, 8)) * 0.3, "b": jnp.ones((5,)) * -0.2}


def _cfg(alg="vrl_sgd", **kw):
    return VRLConfig(algorithm=alg, comm_period=4, learning_rate=0.05,
                     weight_decay=0.0, warmup=False, update_backend="xla",
                     **kw)


def _gk(eng, state, r, k=4, scale=0.1):
    return jax.tree.map(
        lambda x: jnp.stack([jnp.sin(x + r * k + i) * scale
                             for i in range(k)]),
        eng.params_tree(state))


@pytest.mark.parametrize("alg",
                         [a for a in flat_algorithms()
                          if a != "hier_vrl_sgd"])
def test_full_participation_round_trip_is_bitwise(alg):
    """The acceptance gate: with M == W the gather/round/scatter loop
    produces BITWISE the storeless trajectory, for every flat algorithm
    (params AND every per-client leaf)."""
    eng = make_engine(_cfg(alg), TEMPLATE)
    rs = jax.jit(eng.round_step, donate_argnums=(0,))

    s0 = eng.init(P0, W)                       # storeless reference
    s1 = eng.init(P0, W)
    store = ClientStore(s1, W)
    for r in range(3):
        s0 = rs(s0, _gk(eng, s0, r))
        cohort = sample_cohort(W, W, r)
        st = store.gather(cohort)
        st = rs(st, _gk(eng, st, r))
        store.scatter(st, cohort)
    tree = store.to_tree()["clients"]
    for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_strict_subset_recenter_restores_invariant():
    """After rounds over rotating cohorts, a gathered strict subset's Δ
    sums to the cohort mean — recenter_drift restores Σ Δ = 0 without
    moving the cohort-mean model."""
    eng = make_engine(_cfg(), TEMPLATE)
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    rec = jax.jit(eng.recenter_drift)
    state = eng.init(P0, W)
    store = ClientStore(state, 10)
    for r in range(4):
        st = store.gather(sample_cohort(10, W, r), seed_params=r > 0)
        st = rec(st)
        d = np.asarray(st.delta)
        assert np.abs(d.sum(0)).max() < 1e-5
        st = rs(st, _gk(eng, st, r))
        store.scatter(st, sample_cohort(10, W, r))


def test_seed_params_installs_consensus():
    eng = make_engine(_cfg(), TEMPLATE)
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    state = eng.init(P0, 2)
    store = ClientStore(state, 6)
    c0 = sample_cohort(6, 2, 0)
    st = rs(store.gather(c0), _gk(eng, state, 0, k=4))
    store.scatter(st, c0)
    # a later cohort of NEVER-sampled clients starts at the consensus,
    # not at x0
    rest = np.array(sorted(set(range(6)) - set(c0.tolist()))[:2],
                    np.int64)
    seeded = store.gather(rest, seed_params=True)
    np.testing.assert_array_equal(
        np.asarray(seeded.params)[0], store.server_params)
    np.testing.assert_array_equal(
        np.asarray(seeded.params)[1], store.server_params)
    unseeded = store.gather(rest)
    assert not np.array_equal(np.asarray(unseeded.params)[0],
                              store.server_params)


def test_scatter_skips_dead_slots():
    from repro.core.types import MemberState

    eng = make_engine(_cfg(membership=True), TEMPLATE)
    state = eng.init(P0, W)
    store = ClientStore(state, 8)
    cohort = np.array([0, 2, 4, 6], np.int64)
    before = np.array(store.to_tree()["clients"].params)
    st = store.gather(cohort, member=state.member)
    st = st._replace(
        params=jnp.asarray(np.full_like(np.asarray(st.params), 7.0)),
        member=MemberState(
            active=jnp.array([1, 0, 1, 1], jnp.float32).reshape(W, 1, 1),
            n_active=jnp.float32(3)))
    store.scatter(st, cohort)
    after = np.array(store.to_tree()["clients"].params)
    assert (after[[0, 4, 6]] == 7.0).all()        # alive slots landed
    np.testing.assert_array_equal(after[2], before[2])   # dead slot kept
    np.testing.assert_array_equal(after[[1, 3, 5, 7]],
                                  before[[1, 3, 5, 7]])  # non-cohort kept


def test_store_tree_round_trips_and_validates():
    eng = make_engine(_cfg(), TEMPLATE)
    state = eng.init(P0, W)
    store = ClientStore(state, 6)
    tree = store.to_tree()
    assert set(tree) == {"clients", "server_params"}
    store2 = ClientStore(eng.init(P0, W), 6)
    store2.load_tree(tree)
    np.testing.assert_array_equal(store2.server_params,
                                  store.server_params)
    with pytest.raises(ValueError, match="'clients', 'server_params'"):
        store2.load_tree({"clients": tree["clients"]})
    bad = dict(tree)
    bad["clients"] = jax.tree.map(
        lambda x: x[:1] if getattr(x, "ndim", 0) == 3 else x,
        tree["clients"])
    with pytest.raises(ValueError, match="leaf shape mismatch"):
        store2.load_tree(bad)


def test_store_refuses_overlap_and_undersized_population():
    eng = make_engine(_cfg(overlap=True), TEMPLATE)
    state = eng.init(P0, W)
    with pytest.raises(ValueError, match="overlapped rounds"):
        ClientStore(state, 8)
    eng = make_engine(_cfg(), TEMPLATE)
    with pytest.raises(ValueError, match="must be >= the cohort size"):
        ClientStore(eng.init(P0, W), W - 1)


def test_gather_validates_cohort_shape():
    eng = make_engine(_cfg(), TEMPLATE)
    store = ClientStore(eng.init(P0, W), 8)
    with pytest.raises(ValueError, match=r"cohort must have shape \(4,\)"):
        store.gather(np.arange(3))


# ------------------------------------------------- driver flag validation


@pytest.mark.parametrize("flags,msg", [
    (["--clients", "-1"], "--clients must be >= 0"),
    (["--workers", "4", "--clients", "2"],
     "--clients 2 must be >= --workers 4"),
    (["--participation", "0.5"], "--participation needs --clients"),
    (["--clients", "8", "--participation", "1.5"],
     r"fraction in \(0, 1\]"),
    (["--workers", "4", "--clients", "8", "--participation", "0.25"],
     "cohort of 2, but --workers is 4"),
    (["--workers", "2", "--clients", "4", "--overlap"],
     "--clients .* overlap"),
    (["--workers", "2", "--clients", "4", "--no-round"],
     "--no-round"),
    (["--workers", "2", "--clients", "4", "--backend", "reference"],
     "reference"),
])
def test_bad_client_flags_exit_with_named_message(flags, msg):
    from repro.launch import train

    with pytest.raises(SystemExit, match=msg):
        train.main(["--smoke", "--steps", "4"] + flags)


# ------------------------------------------------------------ driver smoke


def test_driver_client_sampling_composes_with_faults(tmp_path):
    """M=8 clients over W=4 slots with a crash/rejoin pair: the run
    completes, stays finite, and checkpoints a client store that
    records the population."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.launch import train

    root = str(tmp_path / "ck")
    train.main(["--smoke", "--steps", "8", "--workers", "4",
                "--clients", "8", "--batch", "2", "--seq", "32",
                "--k", "2", "--alpha", "0.1", "--lr", "0.05",
                "--faults", "crash@2:3,rejoin@2:5",
                "--ckpt", root, "--ckpt-every", "8"])
    found = ckpt.latest_step(root)
    assert found is not None and found[0] == 8
    meta = ckpt.load_meta(found[1])["meta"]
    assert meta["clients"] == 8
    assert len(meta["assignment"]) == 8
    z = np.load(os.path.join(found[1], "arrays.npz"))
    assert z["clients/params"].shape[0] == 8
    assert np.isfinite(z["clients/params"]).all()
    assert np.isfinite(z["server_params"]).all()


# ------------------------------------- collective count on an 8-device mesh

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import re
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import VRLConfig
    from repro.core import make_engine
    from repro.core.clients import ClientStore, sample_cohort

    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="xla")
    eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}

    def shard(x):
        nd = getattr(x, "ndim", 0)
        spec = P("data", None, None) if nd == 3 else P(*([None] * nd))
        return jax.device_put(x, NamedSharding(mesh, spec))

    state = jax.tree.map(shard, eng.init(p0, 8))
    store = ClientStore(state, 32)

    # a strict-subset cohort, gathered onto the mesh shardings
    cohort = sample_cohort(32, 8, round_index=1, seed=0)
    st = store.gather(cohort, like=state)

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    out = {}
    out["gathered_sharding_matches"] = bool(
        st.params.sharding == state.params.sharding)
    # THE acceptance property: the round over a gathered cohort is the
    # SAME executable — still exactly one sync all-reduce per k steps
    gk = jax.tree.map(lambda x: jnp.stack([jnp.sin(3.0 * x + t) + 0.1 * x
                                           for t in range(4)]),
                      eng.params_tree(st))
    hlo_round = jax.jit(eng.round_step, donate_argnums=(0,)
                        ).lower(st, gk).compile().as_text()
    out["round_all_reduce"] = count_ar(hlo_round)
    # the out-of-round cohort recentre stays collective-frugal
    hlo_rec = jax.jit(eng.recenter_drift).lower(st).compile().as_text()
    out["recenter_all_reduce"] = count_ar(hlo_rec)
    # and the round actually runs on the gathered state
    st2 = jax.jit(eng.round_step, donate_argnums=(0,))(st, gk)
    out["finite"] = bool(np.isfinite(np.asarray(st2.params)).all())
    print(json.dumps(out))
""")


def test_gathered_cohort_round_is_still_one_all_reduce():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["gathered_sharding_matches"] is True, out
    assert out["round_all_reduce"] == 1, out
    assert out["recenter_all_reduce"] <= 4, out
    assert out["finite"] is True, out
