"""Flat-buffer engine executors vs reference tree path: trajectory parity.

Both engine executors (core/engine.py: "fused" Pallas and "xla" plain-jnp)
must reproduce the reference executor exactly (fp32, atol 1e-5) for every
flat algorithm in the registry x all three inner optimizers over multiple
sync periods, and the paper invariants must hold on the fused path.  The
algorithm list derives from ``engine.flat_algorithms()`` so new AlgoSpecs
are covered automatically (stl_sgd runs its default stagewise schedule
through the matrix; bvr_l_sgd its bias variate).  Spec-reduction identities
are bitwise: stl_sgd on a constant schedule IS local_sgd; bvr_l_sgd with
the correction zeroed IS vrl_sgd.  Also covers the flat layout
(core/flat.py): exact roundtrips, auto tiling, and checkpoint save/restore
with the unravel spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs.base import EngineConfig, HierConfig, VRLConfig
from repro.core import (flat, flat_algorithms, get_algorithm,
                        hierarchical as H, make_engine)
from repro.core.schedule import const_comm

ALGORITHMS = list(flat_algorithms())    # registry-derived: new specs ride in
INNER = ["sgd", "momentum", "adam"]
W, K, STEPS = 4, 4, 13          # 13 steps at k=4 -> 3 completed sync periods

TEMPLATE = {"w": jnp.zeros((8, 3)), "b": jnp.zeros((5,)),
            "deep": {"u": jnp.zeros((2, 2, 4))}}


def _params0():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return {"w": jax.random.normal(ks[0], (8, 3)),
            "b": jax.random.normal(ks[1], (5,)),
            "deep": {"u": jax.random.normal(ks[2], (2, 2, 4))}}


def _grads(params, t):
    """Deterministic non-identical pseudo-gradients as a fn of params.

    Leaves carry the worker axis and the sin phase differs per worker, so
    workers drift apart between syncs (exercises Δ and the averaging)."""
    def one(x):
        w = x.shape[0]
        phase = jnp.arange(w, dtype=x.dtype).reshape((w,) + (1,) * (x.ndim - 1))
        return jnp.sin(3.0 * x + 0.7 * t + phase) + 0.1 * x
    return jax.tree.map(one, params)


def _cfg(alg, inner, k=K, warmup=False, backend="fused"):
    return VRLConfig(algorithm=alg, comm_period=k, learning_rate=0.05,
                     weight_decay=1e-3, inner_optimizer=inner,
                     momentum=0.9 if inner == "momentum" else 0.0,
                     warmup=warmup, update_backend=backend)


def _run_pair(alg_name, inner, steps=STEPS, k=K, warmup=False,
              backend="fused"):
    cfg = _cfg(alg_name, inner, k=k, warmup=warmup, backend=backend)
    alg = get_algorithm(alg_name)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    sref = alg.init(cfg, p0, W)
    sfus = eng.init(p0, W)
    ref_step = jax.jit(
        lambda s, t: alg.train_step(cfg, s, _grads(s.params, t)))
    fus_step = jax.jit(
        lambda s, t: eng.train_step(s, _grads(eng.params_tree(s), t)))
    for t in range(steps):
        tt = jnp.float32(t)
        sref = ref_step(sref, tt)
        sfus = fus_step(sfus, tt)
    return alg, eng, sref, sfus


@pytest.mark.parametrize("inner", INNER)
@pytest.mark.parametrize("alg_name", ALGORITHMS)
def test_fused_matches_reference_trajectory(alg_name, inner):
    alg, eng, sref, sfus = _run_pair(alg_name, inner)
    for a, b in zip(jax.tree.leaves(sref.params),
                    jax.tree.leaves(eng.params_tree(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # the evaluation model agrees too
    for a, b in zip(jax.tree.leaves(alg.average_model(sref)),
                    jax.tree.leaves(eng.average_model(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert int(sfus.step) == STEPS
    assert int(sfus.last_sync) == int(sref.last_sync)


@pytest.mark.parametrize("inner", INNER)
@pytest.mark.parametrize("alg_name", ALGORITHMS)
def test_xla_matches_reference_trajectory(alg_name, inner):
    """The xla executor (kernels/xla_update, what "auto" picks on CPU)
    reproduces the reference tree path exactly, like the fused one."""
    alg, eng, sref, sfus = _run_pair(alg_name, inner, backend="xla")
    for a, b in zip(jax.tree.leaves(sref.params),
                    jax.tree.leaves(eng.params_tree(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(alg.average_model(sref)),
                    jax.tree.leaves(eng.average_model(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert eng.backend == "xla"
    assert int(sfus.step) == STEPS
    assert int(sfus.last_sync) == int(sref.last_sync)


@pytest.mark.parametrize("inner", INNER)
def test_fused_delta_matches_reference(inner):
    _, eng, sref, sfus = _run_pair("vrl_sgd", inner)
    dref = jax.tree.leaves(sref.delta)
    dfus = jax.tree.leaves(flat.unflatten_stacked(eng.spec, sfus.delta))
    for a, b in zip(dref, dfus):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fused_delta_sums_to_zero():
    """Paper §4.1: Σ_i Δ_i = 0 after every sync — on the fused path."""
    _, eng, _, sfus = _run_pair("vrl_sgd", "sgd", steps=12)
    # padding lanes are zero on every worker, so the buffer-level sum works
    total = float(jnp.max(jnp.abs(jnp.sum(sfus.delta, axis=0))))
    assert total < 1e-5


def test_fused_k1_equals_ssgd():
    """Paper §4.1: VRL-SGD with k=1 is exactly S-SGD — on the fused path."""
    _, eng_v, _, s_vrl = _run_pair("vrl_sgd", "sgd", steps=20, k=1)
    _, eng_s, _, s_ssgd = _run_pair("ssgd", "sgd", steps=20, k=1)
    for a, b in zip(jax.tree.leaves(eng_v.params_tree(s_vrl)),
                    jax.tree.leaves(eng_s.params_tree(s_ssgd))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_fused_warmup_syncs_after_first_step():
    """Remark 5.3: VRL-SGD-W syncs once after step 1 on the fused path."""
    _, _, sref, sfus = _run_pair("vrl_sgd", "sgd", steps=1, warmup=True)
    assert int(sfus.last_sync) == 1
    assert int(sref.last_sync) == 1
    d = jnp.sum(sfus.delta, axis=0)
    assert float(jnp.max(jnp.abs(d))) < 1e-5
    assert float(jnp.max(jnp.abs(sfus.delta))) > 0.0


# ------------------------------------------- variant-spec reductions (new)
@pytest.mark.parametrize("backend", ["xla", "fused"])
def test_stl_const_schedule_is_local_sgd_bitwise(backend):
    """STL-SGD is Local SGD plus a stagewise cadence: on a CONSTANT
    schedule the trajectory must be bitwise local_sgd (same kernels, same
    sync rule, same round boundaries)."""
    import dataclasses

    cfg_stl = dataclasses.replace(_cfg("stl_sgd", "sgd", backend=backend),
                                  comm_schedule=const_comm(K))
    cfg_loc = _cfg("local_sgd", "sgd", backend=backend)
    e1, e2 = make_engine(cfg_stl, TEMPLATE), make_engine(cfg_loc, TEMPLATE)
    p0 = _params0()
    s1, s2 = e1.init(p0, W), e2.init(p0, W)
    st1 = jax.jit(lambda s, t: e1.train_step(s, _grads(e1.params_tree(s), t)))
    st2 = jax.jit(lambda s, t: e2.train_step(s, _grads(e2.params_tree(s), t)))
    for t in range(STEPS):
        s1 = st1(s1, jnp.float32(t))
        s2 = st2(s2, jnp.float32(t))
    np.testing.assert_array_equal(np.asarray(s1.params),
                                  np.asarray(s2.params))
    assert int(s1.last_sync) == int(s2.last_sync) == 12


@pytest.mark.parametrize("backend", ["xla", "fused"])
def test_bvr_zero_correction_is_vrl_sgd_bitwise(backend):
    """bvr_beta=0 turns the bias machinery off at trace time: the
    bvr_l_sgd trajectory must be bitwise vrl_sgd (params AND Δ), and the
    state must not even carry a B buffer."""
    import dataclasses

    cfg_bvr = dataclasses.replace(_cfg("bvr_l_sgd", "sgd", backend=backend),
                                  bvr_beta=0.0)
    cfg_vrl = _cfg("vrl_sgd", "sgd", backend=backend)
    e1, e2 = make_engine(cfg_bvr, TEMPLATE), make_engine(cfg_vrl, TEMPLATE)
    p0 = _params0()
    s1, s2 = e1.init(p0, W), e2.init(p0, W)
    assert s1.bias == ()                 # zeroed correction: no B buffer
    st1 = jax.jit(lambda s, t: e1.train_step(s, _grads(e1.params_tree(s), t)))
    st2 = jax.jit(lambda s, t: e2.train_step(s, _grads(e2.params_tree(s), t)))
    for t in range(STEPS):
        s1 = st1(s1, jnp.float32(t))
        s2 = st2(s2, jnp.float32(t))
    np.testing.assert_array_equal(np.asarray(s1.params),
                                  np.asarray(s2.params))
    np.testing.assert_array_equal(np.asarray(s1.delta),
                                  np.asarray(s2.delta))


@pytest.mark.parametrize("inner", INNER)
def test_bvr_bias_matches_reference(inner):
    """BVR's B variate: engine executors match the per-leaf reference, and
    Σ_i B_i = 0 after syncs (same telescoping argument as Δ)."""
    alg, eng, sref, sfus = _run_pair("bvr_l_sgd", inner)
    bref = jax.tree.leaves(sref.bias)
    bfus = jax.tree.leaves(flat.unflatten_stacked(eng.spec, sfus.bias))
    assert float(max(jnp.max(jnp.abs(b)) for b in bref)) > 0.0
    for a, b in zip(bref, bfus):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    total = float(jnp.max(jnp.abs(jnp.sum(sfus.bias, axis=0))))
    assert total < 1e-5


def test_train_loop_fused_backend_matches_reference():
    """End-to-end through make_train_step: real LM forward/backward, both
    backends, same data -> same losses and same average model."""
    import dataclasses

    from repro.configs import registry
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl_ref = VRLConfig(algorithm="vrl_sgd", comm_period=3,
                        learning_rate=0.2, weight_decay=0.0, warmup=False,
                        update_backend="reference")
    vrl_fus = dataclasses.replace(vrl_ref, update_backend="fused")
    w, b, s = 2, 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (w, b, s), 0, 64)
    labels = jnp.roll(toks, -1, -1)

    losses = {}
    states = {}
    for name, vrl in [("ref", vrl_ref), ("fused", vrl_fus)]:
        bundle = make_train_step(cfg, vrl, remat=False)
        state = bundle.init_state(jax.random.PRNGKey(0), w)
        step = jax.jit(bundle.train_step)
        ls = []
        for _ in range(7):
            state, loss = step(state, toks, labels)
            ls.append(float(loss))
        losses[name] = ls
        states[name] = bundle.average_model(state)
    np.testing.assert_allclose(losses["ref"], losses["fused"], atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(states["ref"]),
                     jax.tree.leaves(states["fused"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


# ----------------------------------------------------- hierarchical engine
def _hier_grads(params, t):
    """Non-identical pseudo-gradients over a (P, D, ...) grid; the phase
    differs per worker so pods AND workers drift apart between syncs."""
    def one(x):
        p, d = x.shape[:2]
        phase = jnp.arange(p * d, dtype=x.dtype).reshape(
            (p, d) + (1,) * (x.ndim - 2))
        return jnp.sin(3.0 * x + 0.7 * t + phase) + 0.1 * x
    return jax.tree.map(one, params)


def _hier_cfg(inner, k1, k2, grid=(2, 3), backend="fused"):
    return VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                     weight_decay=1e-3, inner_optimizer=inner,
                     momentum=0.9 if inner == "momentum" else 0.0,
                     warmup=False, update_backend=backend,
                     hier=HierConfig(k1=k1, k2=k2, grid=grid))


def _run_hier_pair(inner, k1, k2, steps=13, grid=(2, 3), backend="fused"):
    cfg = _hier_cfg(inner, k1, k2, grid=grid, backend=backend)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    sref = H.init(cfg, p0, grid)
    sfus = eng.init(p0, grid[0] * grid[1])
    ref_step = jax.jit(
        lambda s, t: H.train_step(cfg, s, _hier_grads(s.params, t)))
    fus_step = jax.jit(
        lambda s, t: eng.train_step(s, _hier_grads(eng.params_tree(s), t)))
    for t in range(steps):
        tt = jnp.float32(t)
        sref = ref_step(sref, tt)
        sfus = fus_step(sfus, tt)
    return eng, sref, sfus


@pytest.mark.parametrize("inner", INNER)
@pytest.mark.parametrize("k1,k2", [(2, 4), (3, 9), (4, 8)])
def test_hier_fused_matches_reference(inner, k1, k2):
    """Two-level fused vs reference trajectory parity: params, both Δ
    levels, and the evaluation model (13 steps -> several boundaries of
    each level at every (k1, k2))."""
    eng, sref, sfus = _run_hier_pair(inner, k1, k2)
    for a, b in zip(jax.tree.leaves(sref.params),
                    jax.tree.leaves(eng.params_tree(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(H.average_model(sref)),
                    jax.tree.leaves(eng.average_model(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # Δ parity: magnitudes scale with 1/(k·γ), so param-level fp noise is
    # amplified ~1/(k1·0.05)x — tolerance follows the same scale
    datol = 1e-6 + 2.5e-6 / (k1 * 0.05)
    for a, b in zip(jax.tree.leaves(sref.delta1),
                    jax.tree.leaves(flat.unflatten_grid(eng.spec,
                                                        sfus.delta1))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=datol)
    for a, b in zip(jax.tree.leaves(sref.delta2),
                    jax.tree.leaves(flat.unflatten_grid(eng.spec,
                                                        sfus.delta2))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=datol)
    assert int(sfus.step) == 13
    assert int(sfus.last_sync1) == int(sref.last_sync1)
    assert int(sfus.last_sync2) == int(sref.last_sync2)


@pytest.mark.parametrize("inner", ["sgd", "adam"])
def test_hier_xla_matches_reference(inner):
    """Two-level xla executor vs reference trajectory parity (params and
    the evaluation model; Δ parity is covered by the fused matrix)."""
    eng, sref, sfus = _run_hier_pair(inner, 2, 4, backend="xla")
    for a, b in zip(jax.tree.leaves(sref.params),
                    jax.tree.leaves(eng.params_tree(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(H.average_model(sref)),
                    jax.tree.leaves(eng.average_model(sfus))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert int(sfus.last_sync1) == int(sref.last_sync1)
    assert int(sfus.last_sync2) == int(sref.last_sync2)


@pytest.mark.parametrize("inner", ["sgd", "adam"])
def test_hier_reduces_to_flat_vrl_fused(inner):
    """k1 = k2 = k with one pod IS the paper's Algorithm 1: the fused
    hierarchical trajectory equals the fused flat vrl_sgd spec EXACTLY
    (bitwise — same reductions, same kernels, Δ2 stays identically 0)."""
    w, k, steps = 4, 4, 13
    cfgf = _cfg("vrl_sgd", inner, k=k)
    cfgh = _hier_cfg(inner, k, k, grid=(1, w))
    ef = make_engine(cfgf, TEMPLATE)
    eh = make_engine(cfgh, TEMPLATE)
    p0 = _params0()
    sf = ef.init(p0, w)
    sh = eh.init(p0, w)
    f_step = jax.jit(
        lambda s, t: ef.train_step(s, _grads(ef.params_tree(s), t)))
    h_step = jax.jit(
        lambda s, t: eh.train_step(s, _hier_grads(eh.params_tree(s), t)))
    for t in range(steps):
        sf = f_step(sf, jnp.float32(t))
        sh = h_step(sh, jnp.float32(t))
    np.testing.assert_array_equal(np.asarray(sf.params),
                                  np.asarray(sh.params)[0])
    np.testing.assert_array_equal(np.asarray(sf.delta),
                                  np.asarray(sh.delta1)[0])
    assert float(jnp.max(jnp.abs(sh.delta2))) == 0.0


def test_hier_checkpoint_roundtrip_with_grid(tmp_path):
    """(P, D) flat state persists with its unravel spec AND worker grid;
    a different grid refuses to restore."""
    cfg = _hier_cfg("adam", 2, 4)
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), 6)
    state = eng.train_step(state, _hier_grads(eng.params_tree(state), 0.0))
    ckpt.save_flat_state(str(tmp_path / "h"), state, eng.spec,
                         meta={"step": 1}, grid=eng.grid)
    restored = ckpt.restore_flat_state(str(tmp_path / "h"), state, eng.spec,
                                       grid=eng.grid)
    np.testing.assert_allclose(np.asarray(restored.params),
                               np.asarray(state.params))
    np.testing.assert_allclose(np.asarray(restored.delta2),
                               np.asarray(state.delta2))
    with pytest.raises(ValueError, match="worker grid"):
        ckpt.restore_flat_state(str(tmp_path / "h"), state, eng.spec,
                                grid=(3, 2))


# ------------------------------------------------------------- flat layout
def test_flat_roundtrip_exact():
    spec = flat.make_spec(TEMPLATE)
    tree = _params0()
    buf = flat.flatten_tree(spec, tree)
    out = flat.unflatten_tree(spec, buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_roundtrip_stacked_exact():
    spec = flat.make_spec(TEMPLATE)
    tree = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (3, *x.shape)) + jnp.arange(3.0)
        .reshape(3, *([1] * x.ndim)), _params0())
    buf = flat.flatten_stacked(spec, tree)
    assert buf.shape == (3, spec.rows, spec.lanes)
    out = flat.unflatten_stacked(spec, buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_roundtrip_grid_exact():
    spec = flat.make_spec(TEMPLATE)
    tree = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (2, 3, *x.shape)) + jnp.arange(6.0)
        .reshape(2, 3, *([1] * x.ndim)), _params0())
    buf = flat.flatten_grid(spec, tree)
    assert buf.shape == (2, 3, spec.rows, spec.lanes)
    out = flat.unflatten_grid(spec, buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_choose_block_caps_waste():
    for rows in [1, 3, 8, 17, 100, 1000, 1024, 5000, 100000]:
        b = flat.choose_block(rows)
        padded = -(-rows // b) * b
        waste = (padded - rows) / padded
        assert b in (1024, 512, 256, 128, 64, 32, 16, 8)
        assert waste <= 0.25 or b == 8, (rows, b, waste)
    assert flat.choose_block(100000) == 1024     # big buffers -> big tiles
    assert flat.choose_block(3) == 8             # floor preserved


def test_spec_meta_roundtrip_and_mismatch(tmp_path):
    cfg = _cfg("vrl_sgd", "adam")
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), W)
    state = eng.train_step(state, _grads(eng.params_tree(state), 0.0))
    ckpt.save_flat_state(str(tmp_path / "c"), state, eng.spec,
                         meta={"step": 1})
    restored = ckpt.restore_flat_state(str(tmp_path / "c"), state, eng.spec)
    np.testing.assert_allclose(np.asarray(restored.params),
                               np.asarray(state.params))
    np.testing.assert_allclose(np.asarray(restored.inner.mu),
                               np.asarray(state.inner.mu))
    assert int(restored.step) == 1
    # a different layout must refuse to restore
    other = make_engine(cfg, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="unravel spec"):
        ckpt.restore_flat_state(str(tmp_path / "c"), state, other.spec)
