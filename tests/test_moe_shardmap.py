"""shard_map MoE dispatch (EXPERIMENTS.md §Perf pair C fix): numerics match
the GSPMD reference exactly when capacity is not binding; dispatch is local
by construction. Runs in a subprocess (needs an 8-device placeholder env)."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro import compat
    from repro.configs import registry
    from repro.models import moe as moe_ref
    from repro.models.moe_shardmap import moe_mlp_shardmap
    from repro.launch import roofline as rl
    from jax.sharding import PartitionSpec as P

    cfg = registry.smoke_arch("phi3.5-moe-42b-a6.6b")
    cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2,
                              capacity_factor=8.0, num_shared_experts=0)
    mesh = compat.make_mesh((2, 4), ("data", "model"), devices=jax.devices())
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {"router": jax.random.normal(ks[0], (d, e)) * 0.1,
         "w_gate": jax.random.normal(ks[1], (e, d, ff)) * 0.05,
         "w_up": jax.random.normal(ks[2], (e, d, ff)) * 0.05,
         "w_down": jax.random.normal(ks[3], (e, ff, d)) * 0.05}
    x = jax.random.normal(ks[4], (64, d))
    y_ref, _ = moe_ref.moe_mlp(cfg, p, x)
    with compat.set_mesh(mesh):
        fn = jax.jit(lambda p, x: moe_mlp_shardmap(cfg, p, x, mesh))
        y_sm, _ = fn(p, x)
        coll = rl.collective_bytes(fn.lower(p, x).compile().as_text())
    err = float(jnp.max(jnp.abs(y_sm - y_ref)))
    print(json.dumps({"err": err, "coll": coll}))
""")


def test_shardmap_moe_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-4, out
    # collective profile is exactly weight-AG + output-psum (+ routing aux):
    # no all-to-all, no hidden-state all-reduce blowup
    coll = out["coll"]
    assert "all-to-all" not in coll or coll["all-to-all"] == 0, coll
    assert coll.get("all-gather", 0) > 0 and coll.get("all-reduce", 0) > 0
