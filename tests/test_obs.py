"""Telemetry subsystem tests (repro.obs + the driver wiring).

Covers, at three levels:
  unit      — MetricsWriter/read_metrics round-trip, schema guard, phase
              timers, the legacy-results converter shim
  engine    — the invariant monitor catches a seeded Σ Δ violation and a
              NaN-poisoned worker in ONE diagnostics pass (and does NOT
              count a dropped worker's dead rows); measured wire bytes
              match comm.rep_nbytes of an actual compressed payload
  driver    — an in-process --metrics training run emits the documented
              event stream (round/sync/diag with residuals and wire
              bytes) that report.py renders; the early-exit resume path
              evaluates the restored averaged model instead of writing
              null; a tripped --invariant-alarm feeds the --guard
              rollback; and (subprocess, 8-device mesh) building
              Engine.diagnostics leaves the compiled round's HLO at
              EXACTLY one sync all-reduce
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VRLConfig
from repro.core import make_engine
from repro.obs import convert, report
from repro.obs import diagnostics as obs_diag
from repro.obs.metrics import (SCHEMA_VERSION, MetricsWriter, NullWriter,
                               read_metrics, run_meta)
from repro.obs.timers import PhaseTimers, percentile


# ------------------------------------------------------------------ unit
def test_writer_reader_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsWriter(path, run_meta={"arch": "x", "workers": 2}) as mw:
        assert mw.active
        mw.emit("round", t=2, r=1, loss=np.float32(1.5),
                wire_bytes=np.int64(4096))
        mw.emit("diag", t=2, drift_per_worker=jnp.arange(2.0))
        mw.emit("run_end", steps=2, avg_model_loss=1.25)
    recs = read_metrics(path)
    assert [r["event"] for r in recs] == ["run_start", "round", "diag",
                                         "run_end"]
    assert all(r["schema"] == SCHEMA_VERSION for r in recs)
    assert run_meta(recs) == {"arch": "x", "workers": 2}
    # numpy/jax values were coerced to plain JSON types
    assert recs[1]["loss"] == 1.5 and recs[1]["wire_bytes"] == 4096
    assert recs[2]["drift_per_worker"] == [0.0, 1.0]
    # wall_s is monotone from the stream open
    assert recs[0]["wall_s"] == 0.0
    assert all(recs[i]["wall_s"] <= recs[i + 1]["wall_s"]
               for i in range(len(recs) - 1))


def test_reader_rejects_newer_schema_and_garbage(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"schema": SCHEMA_VERSION + 1,
                             "event": "round"}) + "\n")
    with pytest.raises(ValueError, match="newer than this reader"):
        read_metrics(str(p))
    p.write_text('{"no_event": 1}\n')
    with pytest.raises(ValueError, match="'schema' and 'event'"):
        read_metrics(str(p))
    p.write_text("not json\n")
    with pytest.raises(ValueError, match="invalid JSON"):
        read_metrics(str(p))


def test_null_writer_is_inert(tmp_path):
    nw = NullWriter()
    assert not nw.active and nw.path is None
    nw.emit("round", t=1)       # must be a no-op, not an error
    nw.close()


def test_phase_timers_percentiles():
    t = PhaseTimers()
    for ms in (1, 2, 3, 4, 100):
        t.add("round", ms / 1e3)
    with t.phase("eval"):
        pass
    s = t.summary()
    assert s["round"]["n"] == 5
    assert s["round"]["p50_ms"] == pytest.approx(3.0)
    assert s["round"]["p95_ms"] == pytest.approx(100.0)
    assert s["eval"]["n"] == 1
    assert percentile([5.0], 95) == 5.0


def test_report_summarize_and_diff(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsWriter(path, run_meta={"arch": "a", "algorithm": "vrl_sgd",
                                       "workers": 2, "steps": 4}) as mw:
        mw.emit("round", t=2, r=1, k=2, loss=2.0, wire_bytes=1024)
        mw.emit("sync", t=2, r=1, wire_bytes=1024, participants=2)
        mw.emit("diag", t=2, r=1, delta_residual=1e-6, drift_sq_mean=0.5,
                zeta_sq_proxy=3.0, nonfinite_workers=0.0, alarms=[])
        mw.emit("eval", t=2, r=1, avg_model_loss=1.9, local_loss=2.0)
        mw.emit("rollback", t_fail=4, reason="non-finite state",
                back_to=2, retry=1)
        mw.emit("run_end", steps=4, final_loss=1.8, avg_model_loss=1.8,
                rounds=2, phases={"round": {"n": 2, "total_s": 1.0,
                                            "mean_ms": 500.0,
                                            "p50_ms": 500.0,
                                            "p95_ms": 600.0}})
    recs = read_metrics(path)
    text = report.summarize(recs, label="unit")
    for needle in ("run report — unit", "loss trajectory",
                   "algorithm health", "delta_residual", "rollback",
                   "wall-clock phases", "avg_model_loss=1.8"):
        assert needle in text, needle
    d = report.diff(recs, recs, labels=("L", "R"))
    assert "avg_model_loss" in d and "rollbacks" in d
    # a partial stream (no run_end — crashed run) still renders
    partial = [r for r in recs if r["event"] != "run_end"]
    assert "partial" in report.summarize(partial)


def test_converter_roundtrip(tmp_path):
    legacy = {"arch": "a", "payload_bytes": 7,
              "table": {"0.25": {"workers": 2, "bytes": 14},
                        "1.0": {"workers": 8, "bytes": 56}}}
    recs = convert.records_from_legacy(legacy, "comm_cohort")
    assert recs[0]["event"] == "run_start" and recs[0]["source"] == "bench"
    assert convert.legacy_view(recs) == legacy
    # two-level table (comm_compress shape)
    nested = {"horizons": [10], "table": {
        "ssgd/none": {"10": {"rounds": 10, "bytes": 100}},
        "vrl/none": {"10": {"rounds": 1, "bytes": 10}}}}
    recs2 = convert.records_from_legacy(nested, "comm_compress")
    keys = sorted(tuple(r["key"]) for r in recs2 if r["event"] == "bench")
    assert keys == [("ssgd/none", "10"), ("vrl/none", "10")]
    assert convert.legacy_view(recs2) == nested
    # raw row list (comm_bench shape)
    rows = [{"coll_bytes": 1}, {"coll_bytes": 2}]
    recs3 = convert.records_from_legacy(rows, "comm_bench")
    assert convert.legacy_view(recs3) == rows
    # file-to-file, both directions
    src = tmp_path / "legacy.json"
    src.write_text(json.dumps(legacy))
    canon = str(tmp_path / "canon.jsonl")
    convert.convert_file(str(src), canon)
    back = str(tmp_path / "back.json")
    convert.convert_file(canon, back)
    assert json.load(open(back)) == legacy


# ---------------------------------------------------------------- engine
def _engine(workers=4, **over):
    template = {"w": jnp.zeros((48, 16)), "b": jnp.zeros((17,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="xla",
                    **over)
    eng = make_engine(cfg, template)
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (48, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (17,))}
    return eng, eng.init(p0, workers)


def test_invariant_monitor_catches_seeded_delta_violation():
    """Σ Δ = 0 is the paper's control-variate invariant: seeding +0.5
    onto one worker's Δ must raise the residual above threshold in ONE
    diagnostics pass, and check_alarms must name it."""
    eng, state = _engine()
    rec = obs_diag.to_record(jax.jit(eng.diagnostics)(state))
    assert rec["delta_residual"] < 1e-5          # clean init: float noise
    assert obs_diag.check_alarms(rec, invariant_threshold=1e-3) == []
    bad = state._replace(delta=state.delta.at[0].add(0.5))
    rec = obs_diag.to_record(jax.jit(eng.diagnostics)(bad))
    assert rec["delta_residual"] == pytest.approx(0.125)   # 0.5 / W
    alarms = obs_diag.check_alarms(rec, invariant_threshold=1e-3)
    assert len(alarms) == 1 and "sum-delta" in alarms[0]
    # the violation also shows up as control-variate dispersion
    assert rec["zeta_sq_proxy"] > 0.0


def test_invariant_monitor_catches_nan_poisoned_worker():
    eng, state = _engine()
    bad = state._replace(params=state.params.at[2, 5].set(jnp.nan))
    rec = obs_diag.to_record(jax.jit(eng.diagnostics)(bad))
    assert rec["nonfinite_workers"] == 1.0
    alarms = obs_diag.check_alarms(rec)          # fires with NO threshold
    assert len(alarms) == 1 and "non-finite" in alarms[0]
    assert "NONFINITE" in obs_diag.describe(rec)


def test_dropped_worker_nan_rows_do_not_alarm():
    """A crashed worker's rows legitimately hold garbage — membership
    masks them out of every statistic, so a dead-row NaN must not count
    as a non-finite worker (and must not poison the finite stats)."""
    eng, state = _engine(membership=True)
    mask = np.array([0.0, 1.0, 1.0, 1.0], np.float32)
    state = jax.jit(eng.set_membership)(state, mask)
    dead = state._replace(params=state.params.at[0].set(jnp.nan))
    rec = obs_diag.to_record(jax.jit(eng.diagnostics)(dead))
    assert rec["nonfinite_workers"] == 0.0
    assert np.isfinite(rec["params_rms"])
    assert np.isfinite(rec["delta_residual"])
    assert obs_diag.check_alarms(rec, invariant_threshold=1e-3) == []


def test_wire_bytes_matches_actual_compressed_payload():
    """wire_bytes_per_sync must equal rep_nbytes(compress(payload)) —
    the measured figure, not an estimate."""
    from repro.comm import compressors as cc

    for spec_str in ("int8", "topk"):
        eng, state = _engine(compress=cc.parse_compressor(spec_str))
        wire = obs_diag.wire_bytes_per_sync(eng)
        payload = jnp.linspace(-1.0, 1.0, eng.spec.padded,
                               dtype=jnp.float32
                               ).reshape(eng.spec.rows, eng.spec.lanes)
        rep = cc.compress(eng.compressors[0], payload,
                          rows_used=cc.used_rows(eng.spec.size,
                                                 eng.spec.lanes))
        assert wire["wire_bytes"] == cc.rep_nbytes(rep)
        assert wire["wire_bytes"] < wire["raw_bytes"]
        assert wire["wire_bytes2"] is None       # flat engine
    assert obs_diag.wire_bytes_per_sync(None) is None


# ---------------------------------------------------------------- driver
SMOKE = ["--arch", "qwen2-0.5b", "--smoke", "--workers", "2",
         "--batch", "2", "--seq", "32", "--k", "2", "--lr", "0.02",
         "--backend", "xla"]


def test_training_run_emits_documented_stream(tmp_path):
    from repro.launch import train

    m = str(tmp_path / "m.jsonl")
    lo = str(tmp_path / "loss.json")
    train.main(SMOKE + ["--steps", "4", "--log-every", "1",
                        "--metrics", m, "--loss-out", lo])
    recs = read_metrics(m)
    meta = run_meta(recs)
    assert meta["algorithm"] == "vrl_sgd" and meta["workers"] == 2
    assert meta["wire"]["wire_bytes"] > 0        # measured sync payload
    rounds = [r for r in recs if r["event"] == "round"]
    diags = [r for r in recs if r["event"] == "diag"]
    syncs = [r for r in recs if r["event"] == "sync"]
    assert len(rounds) == 2 and len(syncs) == 2 and len(diags) == 2
    assert all(r["wire_bytes"] == meta["wire"]["wire_bytes"]
               for r in rounds)
    assert syncs[0]["participants"] == 2
    for d in diags:                 # the paper-grounded health fields
        for key in ("delta_residual", "drift_sq_mean", "zeta_sq_proxy",
                    "params_rms", "nonfinite_workers"):
            assert np.isfinite(d[key]), key
        assert d["alarms"] == []
    end = recs[-1]
    assert end["event"] == "run_end" and end["steps"] == 4
    assert np.isfinite(end["avg_model_loss"])
    assert end["phases"]["round"]["n"] == 2
    # --loss-out and the stream agree, and the reporter renders it
    assert json.load(open(lo))["avg_model_loss"] == end["avg_model_loss"]
    text = report.summarize(recs)
    assert "delta_residual" in text and "communication:" in text


def test_early_exit_resume_evaluates_restored_model(tmp_path):
    """Regression: resuming past --steps used to dump
    avg_model_loss: null without ever evaluating the restored model."""
    from repro.launch import train

    ck = str(tmp_path / "ck")
    train.main(SMOKE + ["--steps", "4", "--ckpt", ck,
                        "--ckpt-every", "2"])
    lo = str(tmp_path / "loss.json")
    m = str(tmp_path / "m.jsonl")
    rc = train.main(SMOKE + ["--steps", "2", "--ckpt", ck,
                             "--resume", "auto", "--loss-out", lo,
                             "--metrics", m])
    assert rc == 0
    out = json.load(open(lo))
    assert out["steps"] == 4                     # the checkpoint's step
    assert isinstance(out["avg_model_loss"], float)
    assert np.isfinite(out["avg_model_loss"])    # was None before the fix
    recs = read_metrics(m)
    assert [r["event"] for r in recs] == ["run_start", "restore",
                                          "run_end"]
    assert recs[-1]["avg_model_loss"] == out["avg_model_loss"]


def test_invariant_alarm_feeds_guard_rollback(tmp_path, capsys):
    """Under a lossy sync compressor Σ Δ is genuinely nonzero (the
    EF-bounded rebuild bias), so a near-zero --invariant-alarm must trip
    on the first diagnosed round and drive the --guard rollback path to
    exhaustion — proving the monitor is wired into the same machinery as
    the loss/finiteness guard."""
    from repro.launch import train

    m = str(tmp_path / "m.jsonl")
    with pytest.raises(SystemExit, match="still diverged"):
        train.main(SMOKE + ["--steps", "2", "--compress", "topk",
                            "--guard", "--max-retries", "1",
                            "--invariant-alarm", "1e-9",
                            "--log-every", "1", "--metrics", m])
    out = capsys.readouterr().out
    assert "invariant alarm" in out and "rolled back" in out
    rbs = [r for r in read_metrics(m) if r["event"] == "rollback"]
    assert len(rbs) == 2 and rbs[-1].get("aborted") is True
    assert all("invariant alarm" in r["reason"] for r in rbs)


def test_diag_flags_need_an_engine():
    from repro.launch import train

    with pytest.raises(SystemExit, match="--backend reference has none"):
        train.main(["--arch", "qwen2-0.5b", "--smoke", "--steps", "2",
                    "--workers", "2", "--batch", "2", "--seq", "32",
                    "--backend", "reference", "--diag"])


@pytest.mark.parametrize("flags, msg", [
    (["--invariant-alarm", "-1"], "--invariant-alarm must be >= 0"),
    (["--profile-round", "2"], "--profile-round needs --profile-dir"),
    (["--profile-round", "2", "--profile-dir", "/tmp/x", "--no-round"],
     "drop\\s+--no-round"),
])
def test_bad_obs_flags_exit_with_named_message(flags, msg):
    from repro.launch import train

    with pytest.raises(SystemExit, match=msg):
        train.main(["--smoke", "--steps", "4"] + flags)


# ------------------------------- HLO contract with diagnostics enabled
HLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import VRLConfig
    from repro.core import make_engine

    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="xla")
    eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}
    state = eng.init(p0, 8)

    def shard(x):
        nd = getattr(x, "ndim", 0)
        spec = P("data", None, None) if nd == 3 else P(*([None] * nd))
        return jax.device_put(x, NamedSharding(mesh, spec))

    state = jax.tree.map(shard, state)

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    # the diagnostics jit compiles and runs on the mesh-sharded state
    diag = jax.device_get(jax.jit(eng.diagnostics)(state))
    out = {"diag_keys": sorted(diag.keys()),
           "delta_residual": float(diag["delta_residual"]),
           "drift_len": int(diag["drift_per_worker"].size)}

    # ... and the compiled ROUND is untouched: still exactly ONE sync
    # all-reduce for the k scanned local steps
    gk = jax.tree.map(lambda x: jnp.stack([jnp.sin(3.0 * x + t) + 0.1 * x
                                           for t in range(4)]),
                      eng.params_tree(state))
    hlo_round = jax.jit(eng.round_step, donate_argnums=(0,)
                        ).lower(state, gk).compile().as_text()
    out["round_all_reduce"] = count_ar(hlo_round)
    print(json.dumps(out))
""")


def test_round_hlo_one_all_reduce_with_diagnostics_built():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", HLO_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["round_all_reduce"] == 1          # the contract holds
    assert out["delta_residual"] < 1e-5
    assert out["drift_len"] == 8                 # one entry per worker
    for key in ("delta_residual", "drift_sq_mean", "zeta_sq_proxy",
                "params_rms", "nonfinite_workers"):
        assert key in out["diag_keys"], key
