"""Property-based tests (hypothesis) for the sync-payload compressors.

Own module (the ``test_schedule_properties.py`` pattern) so the
module-level ``importorskip`` skips ONLY the randomized properties when
hypothesis is absent — the deterministic compressed-engine tests in
``test_compress_engine.py`` always run.

Properties held by ``repro.comm.compressors``:

  * rate-1 / ``none`` round-trips are the identity (and resolve to the
    engine's uncompressed path);
  * int8 per-row scaling is invariant under exact (power-of-two) payload
    scaling — the quantization grid scales with the payload;
  * top-k keeps exactly the k largest magnitudes of every row (wire
    format) and the threshold round-trip agrees with it;
  * the error-feedback invariant: residual + decompressed == payload,
    BITWISE, for every compressor — the residual is a literal subtraction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import compressors as cc  # noqa: E402

LANES = 16


def _payload(seed: int, rows: int, scale: float):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, LANES))
    return (scale * x).astype(jnp.float32)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), rows=st.integers(1, 6),
       scale=st.floats(1e-3, 1e3))
def test_ef_invariant_bitwise(seed, rows, scale):
    """resid + dec == payload exactly, for every compressor."""
    x = _payload(seed, rows, scale)
    for spec in [cc.parse_compressor("int8"), cc.parse_compressor("topk:4"),
                 cc.parse_compressor("none")]:
        dec, resid = cc.ef_roundtrip(spec, x)
        np.testing.assert_array_equal(np.asarray(resid + dec),
                                      np.asarray(x))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), rows=st.integers(1, 6))
def test_rate_one_roundtrip_is_identity(seed, rows):
    """topk at rate 1 keeps every lane; both resolve to the identity
    (= the engine's uncompressed path)."""
    x = _payload(seed, rows, 1.0)
    dec, resid = cc.ef_roundtrip(cc.CompressorSpec("topk", rate=1), x)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(x))
    assert float(jnp.max(jnp.abs(resid))) == 0.0
    assert cc.resolve(cc.parse_compressor("topk:1")) is None
    assert cc.resolve(cc.parse_compressor("none")) is None
    assert cc.resolve(None) is None


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), rows=st.integers(1, 6),
       exp=st.integers(-8, 8))
def test_int8_scale_invariance(seed, rows, exp):
    """Per-row scaling: quantizing 2^n·x decompresses to exactly
    2^n·dec(x) (power-of-two factors are exact in fp32, so the per-row
    max/127 grid scales with the payload)."""
    x = _payload(seed, rows, 1.0)
    c = float(2.0 ** exp)
    dec1, _ = cc.ef_int8(x)
    dec2, _ = cc.ef_int8(c * x)
    np.testing.assert_array_equal(np.asarray(dec2), np.asarray(c * dec1))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), rows=st.integers(1, 6),
       rate=st.sampled_from([2, 4, 8]))
def test_topk_preserves_k_largest(seed, rows, rate):
    """The wire format keeps exactly the k largest magnitudes per row, and
    the threshold round-trip reconstructs the same dense buffer.  (Inputs
    are continuous normals, so the exact-tie case — where threshold-keep
    deliberately retains > k lanes, see ``ef_topk`` — does not arise.)"""
    spec = cc.parse_compressor(f"topk:{rate}")
    x = _payload(seed, rows, 1.0)
    k = cc.topk_k(spec, LANES)
    rep = cc.compress(spec, x)
    assert rep.values.shape == (rows, k)
    a = np.abs(np.asarray(x))
    kept = np.abs(np.asarray(rep.values))
    for r in range(rows):
        expect = np.sort(a[r])[-k:]
        np.testing.assert_allclose(np.sort(kept[r]), expect)
    dense = cc.decompress(spec, rep, rows=rows, lanes=LANES)
    dec, _ = cc.ef_topk(x, k)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), rows=st.integers(1, 6))
def test_int8_wire_roundtrip_matches_ef(seed, rows):
    """compress→decompress through the actual wire representation equals
    the fused round-trip math, and the measured bytes match the formula."""
    spec = cc.parse_compressor("int8")
    x = _payload(seed, rows, 3.0)
    rep = cc.compress(spec, x)
    assert rep.values.dtype == jnp.int8
    dense = cc.decompress(spec, rep, rows=rows, lanes=LANES)
    dec, _ = cc.ef_int8(x)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))
    assert cc.rep_nbytes(rep) == cc.wire_bytes(
        spec, rows=rows, lanes=LANES, size=rows * LANES)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**20), n=st.integers(1, 40),
       lead=st.integers(1, 3))
def test_ef_leaf_matches_padded_rows(seed, n, lead):
    """The per-leaf reference round-trip equals the row round-trip over
    the zero-padded ravel, and keeps the EF invariant on the leaf."""
    spec = cc.parse_compressor("int8")
    x = jax.random.normal(jax.random.PRNGKey(seed), (lead, n))
    dec, resid = cc.ef_leaf(spec, x, 1, lanes=LANES)
    assert dec.shape == x.shape
    np.testing.assert_array_equal(np.asarray(resid + dec), np.asarray(x))
    u = cc.used_rows(n, LANES)
    pad = u * LANES - n
    rows = jnp.pad(x, [(0, 0), (0, pad)]).reshape(lead, u, LANES)
    dec2, _ = cc.ef_int8(rows)
    np.testing.assert_array_equal(
        np.asarray(dec2.reshape(lead, -1)[:, :n]), np.asarray(dec))
