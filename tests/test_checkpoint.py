"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs.base import VRLConfig
from repro.core import get_algorithm


def test_roundtrip_pytree(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(str(tmp_path / "t"), tree, meta={"step": 7})
    out = ckpt.restore(str(tmp_path / "t"), tree)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert ckpt.load_meta(str(tmp_path / "t"))["meta"]["step"] == 7


def test_roundtrip_worker_state(tmp_path):
    cfg = VRLConfig(comm_period=4, learning_rate=0.01)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"w": jnp.ones((3, 2))}, 4)
    state = alg.train_step(cfg, state,
                           {"w": jnp.ones((4, 3, 2)) * 0.1})
    ckpt.save(str(tmp_path / "s"), state)
    restored = ckpt.restore(str(tmp_path / "s"), state)
    assert int(restored.step) == int(state.step)
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(state.params["w"]))
    np.testing.assert_allclose(np.asarray(restored.delta["w"]),
                               np.asarray(state.delta["w"]))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    ckpt.save(str(tmp_path / "m"), tree)
    import pytest
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path / "m"), {"a": jnp.ones((3, 3))})
