"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.comm import compressors as cc
from repro.configs.base import VRLConfig
from repro.core import get_algorithm, make_engine


def test_roundtrip_pytree(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(str(tmp_path / "t"), tree, meta={"step": 7})
    out = ckpt.restore(str(tmp_path / "t"), tree)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert ckpt.load_meta(str(tmp_path / "t"))["meta"]["step"] == 7


def test_roundtrip_worker_state(tmp_path):
    cfg = VRLConfig(comm_period=4, learning_rate=0.01)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"w": jnp.ones((3, 2))}, 4)
    state = alg.train_step(cfg, state,
                           {"w": jnp.ones((4, 3, 2)) * 0.1})
    ckpt.save(str(tmp_path / "s"), state)
    restored = ckpt.restore(str(tmp_path / "s"), state)
    assert int(restored.step) == int(state.step)
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(state.params["w"]))
    np.testing.assert_allclose(np.asarray(restored.delta["w"]),
                               np.asarray(state.delta["w"]))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    ckpt.save(str(tmp_path / "m"), tree)
    import pytest
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path / "m"), {"a": jnp.ones((3, 3))})


def test_flat_state_residuals_roundtrip(tmp_path):
    """Compressed-sync residual/ref buffers persist in the flat state and
    validate: restore succeeds only with the SAME recorded compressors."""
    import pytest

    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                    warmup=False, update_backend="xla",
                    compress=cc.parse_compressor("int8"))
    eng = make_engine(cfg, {"w": jnp.zeros((6, 4))})
    state = eng.init({"w": jnp.ones((6, 4))}, 3)
    step = jax.jit(eng.train_step)
    for t in range(4):     # past a sync so resid/ref are non-trivial
        g = jax.tree.map(lambda x: jnp.sin(x + t),
                         eng.params_tree(state))
        state = step(state, g)
    assert float(jnp.max(jnp.abs(state.comm.resid))) > 0.0
    meta = cc.pair_meta(eng.compressors)
    ckpt.save_flat_state(str(tmp_path / "f"), state, eng.spec,
                         meta={"step": 4}, compressors=meta)
    out = ckpt.restore_flat_state(str(tmp_path / "f"), state, eng.spec,
                                  compressors=meta)
    np.testing.assert_array_equal(np.asarray(out.comm.resid),
                                  np.asarray(state.comm.resid))
    np.testing.assert_array_equal(np.asarray(out.comm.ref),
                                  np.asarray(state.comm.ref))
    # mismatched (or absent) compressors must fail loudly, not silently
    # drop the residuals
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "f"), state, eng.spec,
                                compressors=None)
    # and an UNCOMPRESSED checkpoint refuses a compressed engine
    cfg0 = VRLConfig(algorithm="vrl_sgd", comm_period=2, warmup=False,
                     update_backend="xla")
    eng0 = make_engine(cfg0, {"w": jnp.zeros((6, 4))})
    s0 = eng0.init({"w": jnp.ones((6, 4))}, 3)
    ckpt.save_flat_state(str(tmp_path / "u"), s0, eng0.spec,
                         compressors=cc.pair_meta(eng0.compressors))
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "u"), s0, eng0.spec,
                                compressors=meta)


def test_sharded_quantized_state_roundtrip(tmp_path):
    """Sharded + quantized engine state round-trips bitwise, and the two
    layout dials fail loudly on mismatch: a different shard count changes
    ``spec.meta()`` (row padding is shard-aligned) and fails the flat_spec
    comparison; different moment storage (bf16 momentum, SM3 second
    moment) fails the ``moments`` record comparison."""
    import dataclasses

    import pytest

    from repro.configs.base import EngineConfig

    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                    warmup=False, update_backend="xla",
                    inner_optimizer="adam",
                    moment_dtype="bfloat16", sm3=True,
                    engine=EngineConfig(block=8, shards=4))
    template = {"w": jnp.zeros((40, 24)), "b": jnp.zeros((17,))}
    eng = make_engine(cfg, template)
    p0 = {"w": jnp.ones((40, 24)) * 0.3, "b": jnp.ones((17,)) * -0.1}
    state = eng.init(p0, 2)
    step = jax.jit(eng.train_step)
    for t in range(3):     # past a sync so moments/delta are non-trivial
        g = jax.tree.map(lambda x: jnp.sin(x + t), eng.params_tree(state))
        state = step(state, g)
    assert state.inner.mu.dtype == jnp.bfloat16
    moments = ckpt.moments_meta(cfg)
    assert moments == {"moment_dtype": "bfloat16", "sm3": True}
    ckpt.save_flat_state(str(tmp_path / "q"), state, eng.spec,
                         meta={"step": 3}, moments=moments)
    out = ckpt.restore_flat_state(str(tmp_path / "q"), state, eng.spec,
                                  moments=moments)
    # bf16 momentum and the SM3 (row, col) fp32 stats restore BITWISE —
    # including the sub-fp32 dtype surviving the npz round-trip
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different shard count is a different row padding: loud flat_spec
    # mismatch, not a silently reshaped restore
    cfg2 = dataclasses.replace(cfg, engine=EngineConfig(block=8, shards=2))
    eng2 = make_engine(cfg2, template)
    s2 = eng2.init(p0, 2)
    with pytest.raises(ValueError, match="flat-buffer layout"):
        ckpt.restore_flat_state(str(tmp_path / "q"), s2, eng2.spec,
                                moments=ckpt.moments_meta(cfg2))
    # different moment storage refuses both ways
    cfg3 = dataclasses.replace(cfg, moment_dtype="float32", sm3=False)
    with pytest.raises(ValueError, match="moment"):
        ckpt.restore_flat_state(str(tmp_path / "q"), state, eng.spec,
                                moments=ckpt.moments_meta(cfg3))
    ckpt.save_flat_state(str(tmp_path / "p"), state, eng.spec,
                         moments=ckpt.moments_meta(cfg3))  # saver lied
    with pytest.raises(ValueError, match="moment"):
        ckpt.restore_flat_state(str(tmp_path / "p"), state, eng.spec,
                                moments=moments)


# ------------------------------------------------ atomicity & step layout


def test_torn_write_preserves_previous_checkpoint(tmp_path):
    """A kill mid-save (temp file torn, no rename) leaves the previous
    complete checkpoint untouched and restorable; the orphaned temp is
    swept by the next successful save.  Same story for a kill between
    write and rename (complete temp, never committed)."""
    import glob
    import os

    import pytest

    d = str(tmp_path / "a")
    v1 = {"a": jnp.arange(6.0).reshape(2, 3)}
    v2 = {"a": jnp.arange(6.0).reshape(2, 3) * 10}
    ckpt.save(d, v1, meta={"step": 1})

    with pytest.raises(ckpt.SimulatedKill, match="mid-write"):
        with ckpt.kill_save("mid-write"):
            ckpt.save(d, v2, meta={"step": 2})
    # the published file is the OLD checkpoint, bit-for-bit usable
    out = ckpt.restore(d, v1)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(v1["a"]))
    assert ckpt.load_meta(d)["meta"]["step"] == 1
    # the torn temp is on disk (as after a real SIGKILL)...
    assert glob.glob(os.path.join(d, "arrays.npz.tmp.*"))

    with pytest.raises(ckpt.SimulatedKill, match="pre-rename"):
        with ckpt.kill_save("pre-rename"):
            ckpt.save(d, v2, meta={"step": 2})
    assert ckpt.load_meta(d)["meta"]["step"] == 1

    # ...and the next save sweeps it and commits
    ckpt.save(d, v2, meta={"step": 2})
    assert not glob.glob(os.path.join(d, "arrays.npz.tmp.*"))
    out = ckpt.restore(d, v1)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(v2["a"]))
    assert ckpt.load_meta(d)["meta"]["step"] == 2


def test_save_step_latest_and_retention(tmp_path):
    """Step-dir layout: the ``latest`` pointer tracks the newest complete
    save, ``retain`` prunes old step dirs, and ``latest_step`` survives a
    lost or lying pointer by directory scan."""
    import os

    root = str(tmp_path / "run")
    tree = {"a": jnp.ones((2,))}
    for step in (2, 4, 6):
        ckpt.save_step(root, step,
                       lambda p, s=step: ckpt.save(p, tree,
                                                   meta={"step": s}),
                       retain=2)
    got = ckpt.latest_step(root)
    assert got is not None
    step, path = got
    assert step == 6 and path == ckpt.step_dir(root, 6)
    assert ckpt.load_meta(path)["meta"]["step"] == 6
    # retain=2: the oldest step dir is gone, the newest two remain
    assert not os.path.exists(ckpt.step_dir(root, 2))
    assert os.path.exists(ckpt.step_dir(root, 4))
    # a killed save_step never flips the pointer
    import pytest
    with pytest.raises(ckpt.SimulatedKill):
        with ckpt.kill_save("mid-write"):
            ckpt.save_step(root, 8, lambda p: ckpt.save(p, tree))
    assert ckpt.latest_step(root)[0] == 6
    # lost pointer: scan fallback still finds the newest COMPLETE dir
    os.remove(os.path.join(root, "latest"))
    assert ckpt.latest_step(root)[0] == 6
    # lying pointer (names a dir with no arrays.npz): scan fallback
    with open(os.path.join(root, "latest"), "w") as f:
        f.write("ckpt-00000099")
    assert ckpt.latest_step(root)[0] == 6
    assert ckpt.latest_step(str(tmp_path / "nowhere")) is None


def test_restore_refuses_wrong_worker_count(tmp_path):
    """A flat restore into an engine initialized at a different W fails
    loudly naming both shapes — elastic restarts must go through
    ``restore_resharded``, never a silent reshape."""
    import pytest

    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                    warmup=False, update_backend="xla")
    eng = make_engine(cfg, {"w": jnp.zeros((6, 4))})
    s4 = eng.init({"w": jnp.ones((6, 4))}, 4)
    ckpt.save_flat_state(str(tmp_path / "w4"), s4, eng.spec)
    s6 = eng.init({"w": jnp.ones((6, 4))}, 6)
    with pytest.raises(ValueError, match=r"\(4,.*\(6,"):
        ckpt.restore_flat_state(str(tmp_path / "w4"), s6, eng.spec)


# -------------------------------------------------------------- resharding


def _elastic_state(w, rounds=2):
    cfg = VRLConfig(algorithm="bvr_l_sgd", comm_period=2,
                    learning_rate=0.05, warmup=False, update_backend="xla",
                    membership=True)
    eng = make_engine(cfg, {"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))})
    p0 = {"w": jnp.ones((6, 4)) * 0.3, "b": jnp.ones((3,)) * -0.1}
    state = eng.init(p0, w)
    step = jax.jit(eng.train_step)
    for t in range(2 * rounds + 1):   # ends mid-round: delta non-trivial
        g = jax.tree.map(
            lambda x: jnp.sin(x + t) + 0.01 * jnp.arange(
                w, dtype=x.dtype).reshape((w,) + (1,) * (x.ndim - 1)),
            eng.params_tree(state))
        state = step(state, g)
    return cfg, eng, state


@pytest.mark.parametrize("w_new", [3, 6])
def test_restore_resharded_invariants(tmp_path, w_new):
    """W=4 checkpoint onto W'∈{3, 6}: params/moments tile saved rows
    (row j = saved j % 4), Δ and B recentre to Σ = 0 over the new set,
    membership comes back fully active at W', and the step counter
    resumes."""
    cfg, eng, s4 = _elastic_state(4)
    d = str(tmp_path / "w4")
    ckpt.save_flat_state(d, s4, eng.spec, meta={"step": 5})
    assert ckpt.saved_workers(d) == 4

    engn = make_engine(cfg, {"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))})
    sn = engn.init({"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))}, w_new)
    out = ckpt.restore_resharded(d, sn, engn.spec)

    old_p = np.asarray(s4.params)
    new_p = np.asarray(out.params)
    for j in range(w_new):
        np.testing.assert_array_equal(new_p[j], old_p[j % 4])
    for buf in (np.asarray(out.delta), np.asarray(out.bias)):
        assert np.abs(buf.sum(0)).max() < 1e-5
    m = np.asarray(out.member.active).reshape(-1)
    np.testing.assert_array_equal(m, np.ones(w_new))
    assert float(out.member.n_active) == float(w_new)
    assert int(out.step) == int(s4.step)
    # and the resharded state actually trains
    step = jax.jit(engn.train_step)
    g = jax.tree.map(lambda x: jnp.sin(x), engn.params_tree(out))
    nxt = step(out, g)
    assert np.isfinite(np.asarray(nxt.params)).all()


def test_restore_resharded_refuses_hier_and_validates(tmp_path):
    """Resharding refuses pod-grid checkpoints (topology, not row
    surgery) and runs the same compatibility gate as the plain restore
    (here: a compressor mismatch)."""
    import dataclasses

    import pytest

    from repro.configs.base import HierConfig

    tpl = {"w": jnp.zeros((6, 4))}
    cfgh = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                     update_backend="xla",
                     hier=HierConfig(k1=2, k2=4, grid=(2, 2)))
    engh = make_engine(cfgh, tpl)
    sh = engh.init({"w": jnp.ones((6, 4))}, 4)
    dh = str(tmp_path / "hier")
    ckpt.save_flat_state(dh, sh, engh.spec, grid=engh.grid)
    with pytest.raises(ValueError, match="hierarchical"):
        ckpt.restore_resharded(dh, sh, engh.spec)

    cfg, eng, s4 = _elastic_state(4)
    d = str(tmp_path / "w4")
    ckpt.save_flat_state(d, s4, eng.spec)
    cfgc = dataclasses.replace(cfg, compress=cc.parse_compressor("int8"))
    engc = make_engine(cfgc, {"w": jnp.zeros((6, 4)),
                              "b": jnp.zeros((3,))})
    sc = engc.init({"w": jnp.zeros((6, 4)), "b": jnp.zeros((3,))}, 6)
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_resharded(d, sc, engc.spec,
                               compressors=cc.pair_meta(engc.compressors))


def test_repartition_covers_every_index_once():
    """Elastic data reassignment: every sample owned exactly once at the
    new worker count, old per-worker runs kept contiguous."""
    import pytest

    from repro.data.partition import class_shard_partition, repartition

    labels = np.repeat(np.arange(10), 20)
    parts = class_shard_partition(labels, 4, seed=0)
    for w_new in (3, 4, 6):
        newp = repartition(parts, w_new)
        assert len(newp) == w_new
        allidx = np.concatenate(newp)
        assert len(allidx) == len(labels)
        assert len(np.unique(allidx)) == len(labels)
    with pytest.raises(ValueError, match=">= 1"):
        repartition(parts, 0)
