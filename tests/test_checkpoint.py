"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.comm import compressors as cc
from repro.configs.base import VRLConfig
from repro.core import get_algorithm, make_engine


def test_roundtrip_pytree(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(str(tmp_path / "t"), tree, meta={"step": 7})
    out = ckpt.restore(str(tmp_path / "t"), tree)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert ckpt.load_meta(str(tmp_path / "t"))["meta"]["step"] == 7


def test_roundtrip_worker_state(tmp_path):
    cfg = VRLConfig(comm_period=4, learning_rate=0.01)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"w": jnp.ones((3, 2))}, 4)
    state = alg.train_step(cfg, state,
                           {"w": jnp.ones((4, 3, 2)) * 0.1})
    ckpt.save(str(tmp_path / "s"), state)
    restored = ckpt.restore(str(tmp_path / "s"), state)
    assert int(restored.step) == int(state.step)
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(state.params["w"]))
    np.testing.assert_allclose(np.asarray(restored.delta["w"]),
                               np.asarray(state.delta["w"]))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    ckpt.save(str(tmp_path / "m"), tree)
    import pytest
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path / "m"), {"a": jnp.ones((3, 3))})


def test_flat_state_residuals_roundtrip(tmp_path):
    """Compressed-sync residual/ref buffers persist in the flat state and
    validate: restore succeeds only with the SAME recorded compressors."""
    import pytest

    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                    warmup=False, update_backend="xla",
                    compress=cc.parse_compressor("int8"))
    eng = make_engine(cfg, {"w": jnp.zeros((6, 4))})
    state = eng.init({"w": jnp.ones((6, 4))}, 3)
    step = jax.jit(eng.train_step)
    for t in range(4):     # past a sync so resid/ref are non-trivial
        g = jax.tree.map(lambda x: jnp.sin(x + t),
                         eng.params_tree(state))
        state = step(state, g)
    assert float(jnp.max(jnp.abs(state.comm.resid))) > 0.0
    meta = cc.pair_meta(eng.compressors)
    ckpt.save_flat_state(str(tmp_path / "f"), state, eng.spec,
                         meta={"step": 4}, compressors=meta)
    out = ckpt.restore_flat_state(str(tmp_path / "f"), state, eng.spec,
                                  compressors=meta)
    np.testing.assert_array_equal(np.asarray(out.comm.resid),
                                  np.asarray(state.comm.resid))
    np.testing.assert_array_equal(np.asarray(out.comm.ref),
                                  np.asarray(state.comm.ref))
    # mismatched (or absent) compressors must fail loudly, not silently
    # drop the residuals
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "f"), state, eng.spec,
                                compressors=None)
    # and an UNCOMPRESSED checkpoint refuses a compressed engine
    cfg0 = VRLConfig(algorithm="vrl_sgd", comm_period=2, warmup=False,
                     update_backend="xla")
    eng0 = make_engine(cfg0, {"w": jnp.zeros((6, 4))})
    s0 = eng0.init({"w": jnp.ones((6, 4))}, 3)
    ckpt.save_flat_state(str(tmp_path / "u"), s0, eng0.spec,
                         compressors=cc.pair_meta(eng0.compressors))
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "u"), s0, eng0.spec,
                                compressors=meta)


def test_sharded_quantized_state_roundtrip(tmp_path):
    """Sharded + quantized engine state round-trips bitwise, and the two
    layout dials fail loudly on mismatch: a different shard count changes
    ``spec.meta()`` (row padding is shard-aligned) and fails the flat_spec
    comparison; different moment storage (bf16 momentum, SM3 second
    moment) fails the ``moments`` record comparison."""
    import dataclasses

    import pytest

    from repro.configs.base import EngineConfig

    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                    warmup=False, update_backend="xla",
                    inner_optimizer="adam",
                    moment_dtype="bfloat16", sm3=True,
                    engine=EngineConfig(block=8, shards=4))
    template = {"w": jnp.zeros((40, 24)), "b": jnp.zeros((17,))}
    eng = make_engine(cfg, template)
    p0 = {"w": jnp.ones((40, 24)) * 0.3, "b": jnp.ones((17,)) * -0.1}
    state = eng.init(p0, 2)
    step = jax.jit(eng.train_step)
    for t in range(3):     # past a sync so moments/delta are non-trivial
        g = jax.tree.map(lambda x: jnp.sin(x + t), eng.params_tree(state))
        state = step(state, g)
    assert state.inner.mu.dtype == jnp.bfloat16
    moments = ckpt.moments_meta(cfg)
    assert moments == {"moment_dtype": "bfloat16", "sm3": True}
    ckpt.save_flat_state(str(tmp_path / "q"), state, eng.spec,
                         meta={"step": 3}, moments=moments)
    out = ckpt.restore_flat_state(str(tmp_path / "q"), state, eng.spec,
                                  moments=moments)
    # bf16 momentum and the SM3 (row, col) fp32 stats restore BITWISE —
    # including the sub-fp32 dtype surviving the npz round-trip
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different shard count is a different row padding: loud flat_spec
    # mismatch, not a silently reshaped restore
    cfg2 = dataclasses.replace(cfg, engine=EngineConfig(block=8, shards=2))
    eng2 = make_engine(cfg2, template)
    s2 = eng2.init(p0, 2)
    with pytest.raises(ValueError, match="flat-buffer layout"):
        ckpt.restore_flat_state(str(tmp_path / "q"), s2, eng2.spec,
                                moments=ckpt.moments_meta(cfg2))
    # different moment storage refuses both ways
    cfg3 = dataclasses.replace(cfg, moment_dtype="float32", sm3=False)
    with pytest.raises(ValueError, match="moment"):
        ckpt.restore_flat_state(str(tmp_path / "q"), state, eng.spec,
                                moments=ckpt.moments_meta(cfg3))
    ckpt.save_flat_state(str(tmp_path / "p"), state, eng.spec,
                         moments=ckpt.moments_meta(cfg3))  # saver lied
    with pytest.raises(ValueError, match="moment"):
        ckpt.restore_flat_state(str(tmp_path / "p"), state, eng.spec,
                                moments=moments)
