"""Sharding/spec tests: rank agreement, divisibility rules, padding exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import MeshConfig, pad_for_mesh
from repro.models import transformer as T
from repro.models.param import ParamDef, is_def
from repro.sharding import specs as sh

MESHES = [registry.mesh_roles("qwen2-0.5b"),
          registry.mesh_roles("kimi-k2-1t-a32b"),
          registry.mesh_roles("qwen2-0.5b", multi_pod=True)]


@pytest.mark.parametrize("arch", registry.list_archs())
@pytest.mark.parametrize("multi", [False, True])
def test_specs_match_param_ranks(arch, multi):
    mesh_cfg = registry.mesh_roles(arch, multi_pod=multi)
    cfg = registry.padded_arch(arch, mesh_cfg)
    defs = T.model_defs(cfg)
    specs = sh.partition_specs(defs, cfg, mesh_cfg)
    flat_d = jax.tree.leaves(defs, is_leaf=is_def)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_d) == len(flat_s)
    sizes = dict(zip(mesh_cfg.axis_names, mesh_cfg.shape))
    for d, s in zip(flat_d, flat_s):
        assert len(s) == len(d.shape), (d, s)
        for dim, part in zip(d.shape, tuple(s)):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            need = int(np.prod([sizes[a] for a in axes]))
            assert dim % need == 0, (arch, d, s)


@pytest.mark.parametrize("arch", registry.list_archs())
def test_padded_heads_divide_tensor_axis(arch):
    mesh_cfg = registry.mesh_roles(arch)
    cfg = registry.padded_arch(arch, mesh_cfg)
    if cfg.num_heads:
        assert cfg.num_heads % mesh_cfg.tensor_size == 0
        assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0
    assert cfg.vocab_size % 128 == 0


def test_head_padding_is_exact():
    """Zero-padded q heads change nothing: build a padded model whose real
    head weights equal the unpadded model and compare outputs."""
    cfg = registry.smoke_arch("qwen2-0.5b", num_heads=6, num_kv_heads=2,
                             head_dim=16, d_model=64, d_ff=128)
    cfg_pad = pad_for_mesh(cfg, tensor_size=4)   # 6 -> 8 q heads
    assert cfg_pad.num_heads == 8
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    params_pad = T.init_params(cfg_pad, key)

    # copy real head weights into the padded layout (group-preserving):
    g, gp = 3, 4
    def expand(wq):  # (L, d, 6, hd) -> (L, d, 8, hd), zero extra slots
        L, d, _, hd = wq.shape
        out = np.zeros((L, d, 8, hd), np.float32)
        src = np.asarray(wq).reshape(L, d, 2, g, hd)
        out = out.reshape(L, d, 2, gp, hd)
        out[:, :, :, :g] = src
        return jnp.asarray(out.reshape(L, d, 8, hd))

    def expand_o(wo):  # (L, 6, hd, d) -> (L, 8, hd, d)
        L, _, hd, d = wo.shape
        out = np.zeros((L, 8, hd, d), np.float32)
        src = np.asarray(wo).reshape(L, 2, g, hd, d)
        out = out.reshape(L, 2, gp, hd, d)
        out[:, :, :g] = src
        return jnp.asarray(out.reshape(L, 8, hd, d))

    pp = jax.tree.map(lambda x: x, params_pad)
    for name in ["embed", "final_norm"]:
        pp[name] = params[name]
    pp["layers"] = dict(params_pad["layers"])
    pp["layers"]["norm1"] = params["layers"]["norm1"]
    pp["layers"]["norm2"] = params["layers"]["norm2"]
    pp["layers"]["mlp"] = params["layers"]["mlp"]
    attn = dict(params["layers"]["attn"])
    attn_p = dict(params_pad["layers"]["attn"])
    attn_p["wq"] = expand(attn["wq"])
    attn_p["wo"] = expand_o(attn["wo"])
    attn_p["wk"], attn_p["wv"] = attn["wk"], attn["wv"]
    if "bq" in attn:
        bq = np.zeros((cfg.num_layers, 8, 16), np.float32)
        bq_src = np.asarray(attn["bq"]).reshape(cfg.num_layers, 2, g, 16)
        bq = bq.reshape(cfg.num_layers, 2, gp, 16)
        bq[:, :, :g] = bq_src
        attn_p["bq"] = jnp.asarray(bq.reshape(cfg.num_layers, 8, 16))
        attn_p["bk"], attn_p["bv"] = attn["bk"], attn["bv"]
    pp["layers"]["attn"] = attn_p

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    l1, _ = T.forward(cfg, params, toks)
    l2, _ = T.forward(cfg_pad, pp, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-5)


def test_worker_stacked_spec():
    mesh_cfg = MeshConfig()
    s = sh.worker_stacked_spec(P("model", None), mesh_cfg)
    assert tuple(s) == ("data", "model", None)


def test_batch_spec_roles():
    m1 = registry.mesh_roles("qwen2-0.5b", multi_pod=True)
    s = sh.batch_spec(m1, worker_stacked=True, extra_dims=1)
    assert tuple(s) == (("pod", "data"), None, None)
    m2 = registry.mesh_roles("kimi-k2-1t-a32b", multi_pod=True)
    s = sh.batch_spec(m2, worker_stacked=True, extra_dims=1)
    assert tuple(s) == ("pod", "data", None)
