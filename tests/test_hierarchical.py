"""Hierarchical (two-level) VRL-SGD extension tests.

Reference tree-path behavior (convergence, eq.-8 composition, flat-VRL
reduction) plus the paper invariants on the FUSED pod-major flat-buffer
path (engine ``sync="vrl2"``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HierConfig, VRLConfig
from repro.core import hierarchical as H
from repro.core import get_algorithm, make_engine


def quad_grads_grid(b):
    """2x2 worker grid, four distinct quadratic objectives with optimum of
    the average at x*=0: f_pd = a_pd (x - c_pd)^2, sum a*c = 0."""
    a = jnp.array([[1.0, 2.0], [1.5, 0.5]])
    c = jnp.array([[2.0, -1.0], [-2.0, 2.0]]) * b  # sum(a*c)=2-2-3+1=...
    # choose c so that sum a_pd * c_pd = 0 -> optimum of mean at 0
    c = jnp.array([[2.0, -1.0], [-1.0, 1.0]]) * b  # 1*2 -2*1 -1.5*1 +0.5*1 = -1?
    c = jnp.array([[1.0, -0.5], [-0.5, 0.5]]) * b
    # recompute: sum a*c = 1*1 + 2*(-.5) + 1.5*(-.5) + .5*.5 = 1 -1 -.75 +.25 = -0.5b
    # shift last entry to zero the sum: c[1,1] = (0.5b)/0.5 + ... solve directly:
    c = c.at[1, 1].set((-(1.0 * c[0, 0] + 2.0 * c[0, 1] + 1.5 * c[1, 0])) / 0.5)

    def grads(params):
        x = params["x"]  # (2, 2, 1)
        return {"x": 2 * a[..., None] * (x - c[..., None])}
    return grads


def run_hier(k1, k2, steps=3000, lr=0.02, b=3.0):
    cfg = VRLConfig(learning_rate=lr, weight_decay=0.0)
    state = H.init(cfg, {"x": jnp.array([1.0])}, (2, 2))
    g = quad_grads_grid(b)
    step = jax.jit(lambda s: H.train_step(cfg, s, g(s.params), k1=k1, k2=k2))
    for _ in range(steps):
        state = step(state)
    return state


def test_hierarchical_converges_nonidentical():
    state = run_hier(k1=4, k2=32)
    xhat = float(H.average_model(state)["x"][0])
    assert abs(xhat) < 1e-3


def test_hierarchical_delta_invariants():
    state = run_hier(k1=4, k2=16, steps=64)
    d1 = np.asarray(state.delta1["x"])          # (2,2,1)
    assert np.abs(d1.sum(axis=1)).max() < 1e-4  # zero within each pod
    d2 = np.asarray(state.delta2["x"])          # (2,1,1)
    assert abs(d2.sum()) < 1e-4                 # zero across pods


def test_hierarchical_average_follows_sgd():
    cfg = VRLConfig(learning_rate=0.05, weight_decay=0.0)
    state = H.init(cfg, {"x": jnp.array([0.0])}, (2, 2))
    rng = np.random.RandomState(0)
    xhat = 0.0
    for t in range(30):
        g = jnp.asarray(rng.randn(2, 2, 1).astype(np.float32))
        xhat -= 0.05 * float(g.mean())
        state = H.train_step(cfg, state, {"x": g}, k1=3, k2=9)
        got = float(H.average_model(state)["x"][0])
        assert abs(got - xhat) < 1e-5


def test_reduces_to_flat_vrl_single_pod():
    """grid (1, N), k1 = k2 = k reproduces the paper's Algorithm 1."""
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm("vrl_sgd")
    flat = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    hier = H.init(cfg, {"x": jnp.array([1.0])}, (1, 2))
    b = 4.0

    def g_flat(params):
        x = params["x"]
        return {"x": jnp.stack([2 * (x[0] + 2 * b), 4 * (x[1] - b)])}

    def g_hier(params):
        x = params["x"]  # (1,2,1)
        return {"x": jnp.stack([2 * (x[0, 0] + 2 * b),
                                4 * (x[0, 1] - b)])[None]}

    for _ in range(40):
        flat = alg.train_step(cfg, flat, g_flat(flat.params))
        hier = H.train_step(cfg, hier, g_hier(hier.params), k1=4, k2=4)
    np.testing.assert_allclose(np.asarray(hier.params["x"][0]),
                               np.asarray(flat.params["x"]),
                               rtol=1e-5, atol=1e-6)


def test_cross_pod_savings_vs_flat_quality():
    """k2 = 8*k1: cross-pod traffic drops 8x; convergence must remain close
    to flat VRL at k1 (the point of the hierarchy)."""
    state_h = run_hier(k1=4, k2=32, steps=4000)
    xh = abs(float(H.average_model(state_h)["x"][0]))
    assert xh < 1e-3  # still converges despite 8x fewer global syncs


# -------------------------------------------------------------- fused path
def test_fused_hier_delta_invariants():
    """Σ_i Δ1_i = 0 within each pod, Σ_p Δ2_p = 0 across pods — on the
    fused (P, D, R, C) buffers (padding lanes are zero on every worker, so
    buffer-level sums see exactly the model elements)."""
    cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                    weight_decay=0.0, update_backend="fused",
                    hier=HierConfig(k1=2, k2=6, grid=(2, 3)))
    template = {"x": jnp.zeros((7, 5))}
    eng = make_engine(cfg, template)
    state = eng.init({"x": jax.random.normal(jax.random.PRNGKey(0), (7, 5))},
                     6)

    def grads(params, t):
        def one(x):
            p, d = x.shape[:2]
            phase = jnp.arange(p * d, dtype=x.dtype).reshape(
                (p, d) + (1,) * (x.ndim - 2))
            return jnp.sin(2.0 * x + 0.5 * t + phase) + 0.1 * x
        return jax.tree.map(one, params)

    step = jax.jit(lambda s, t: eng.train_step(
        s, grads(eng.params_tree(s), t)))
    for t in range(12):          # boundaries of both levels
        state = step(state, jnp.float32(t))
    assert int(state.last_sync1) == 12 and int(state.last_sync2) == 12
    d1_pod_sum = jnp.sum(state.delta1, axis=1)      # (P, R, C)
    assert float(jnp.max(jnp.abs(d1_pod_sum))) < 5e-5
    d2_sum = jnp.sum(state.delta2, axis=0)          # (1, R, C)
    assert float(jnp.max(jnp.abs(d2_sum))) < 5e-5
    assert float(jnp.max(jnp.abs(state.delta1))) > 0.0
    assert float(jnp.max(jnp.abs(state.delta2))) > 0.0


def test_fused_hier_average_follows_sgd():
    """Paper eq. 8 survives the two-level composition on the fused path:
    the grid average tracks exact SGD on the mean gradient."""
    cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                    weight_decay=0.0, update_backend="fused",
                    hier=HierConfig(k1=3, k2=9, grid=(2, 2)))
    template = {"x": jnp.zeros((1,))}
    eng = make_engine(cfg, template)
    state = eng.init({"x": jnp.zeros((1,))}, 4)
    rng = np.random.RandomState(0)
    xhat = 0.0
    step = jax.jit(eng.train_step)
    for t in range(30):
        g = jnp.asarray(rng.randn(2, 2, 1).astype(np.float32))
        xhat -= 0.05 * float(g.mean())
        state = step(state, {"x": g})
        got = float(eng.average_model(state)["x"][0])
        assert abs(got - xhat) < 1e-5
