"""Unit tests for the paper's Algorithm 1 and its stated equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VRLConfig
from repro.core import get_algorithm
from repro.core import vrl_sgd

jax.config.update("jax_enable_x64", False)


def quad_grads(b):
    """Appendix E: f1=(x+2b)^2, f2=2(x-b)^2 (zero within-worker noise)."""
    def grads(params):
        x = params["x"]
        return {"x": jnp.stack([2 * (x[0] + 2 * b), 4 * (x[1] - b)])}
    return grads


def run(alg_name, k, steps, lr=0.05, b=5.0, warmup=False):
    cfg = VRLConfig(algorithm=alg_name, comm_period=k, learning_rate=lr,
                    weight_decay=0.0, warmup=warmup)
    alg = get_algorithm(alg_name)
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    g = quad_grads(b)
    step = jax.jit(lambda s: alg.train_step(cfg, s, g(s.params)))
    for _ in range(steps):
        state = step(state)
    return alg, cfg, state


def test_vrl_converges_nonidentical_quadratic():
    alg, cfg, state = run("vrl_sgd", k=16, steps=1500)
    xhat = float(alg.average_model(state)["x"][0])
    assert abs(xhat) < 1e-4


def test_local_sgd_stalls_nonidentical_quadratic():
    alg, cfg, state = run("local_sgd", k=16, steps=1500)
    xhat = float(alg.average_model(state)["x"][0])
    assert abs(xhat) > 0.5  # biased fixed point, grows with k (paper App. E)


def test_vrl_k1_equals_ssgd():
    """Paper §4.1: VRL-SGD with k=1 is exactly S-SGD."""
    _, _, s_vrl = run("vrl_sgd", k=1, steps=50)
    _, _, s_ssgd = run("ssgd", k=1, steps=50)
    np.testing.assert_allclose(np.asarray(s_vrl.params["x"]),
                               np.asarray(s_ssgd.params["x"]),
                               rtol=1e-4, atol=1e-5)


def test_vrl_zero_delta_equals_local_sgd():
    """Paper §4.1: VRL-SGD with Δ forced to 0 is exactly Local SGD."""
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=8, learning_rate=0.05,
                    weight_decay=0.0, warmup=False)
    alg_v = get_algorithm("vrl_sgd")
    alg_l = get_algorithm("local_sgd")
    sv = alg_v.init(cfg, {"x": jnp.array([1.0])}, 2)
    sl = alg_l.init(cfg, {"x": jnp.array([1.0])}, 2)
    g = quad_grads(3.0)
    for _ in range(40):
        sv = alg_v.train_step(cfg, sv, g(sv.params))
        sv = sv._replace(delta=jax.tree.map(jnp.zeros_like, sv.delta))
        sl = alg_l.train_step(cfg, sl, g(sl.params))
    np.testing.assert_allclose(np.asarray(sv.params["x"]),
                               np.asarray(sl.params["x"]), rtol=1e-6)


def test_delta_sums_to_zero():
    """Σ_i Δ_i = 0 after every sync (paper §4.1)."""
    _, _, state = run("vrl_sgd", k=8, steps=64)
    total = float(jnp.sum(state.delta["x"]))
    assert abs(total) < 1e-5


def test_average_model_follows_eq8():
    """x̂ evolves exactly as SGD on the mean gradient, independent of Δ."""
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.1,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"x": jnp.array([2.0])}, 2)
    g = quad_grads(1.0)
    xhat_manual = 2.0
    for _ in range(20):
        grads = g(state.params)
        mean_g = float(jnp.mean(grads["x"]))
        xhat_manual = xhat_manual - 0.1 * mean_g
        state = alg.train_step(cfg, state, grads)
        xhat = float(alg.average_model(state)["x"][0])
        assert abs(xhat - xhat_manual) < 1e-5


def test_warmup_syncs_after_first_step():
    """Remark 5.3: VRL-SGD-W syncs once after step 1 (first period k=1)."""
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=10, learning_rate=0.05,
                    weight_decay=0.0, warmup=True)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    g = quad_grads(5.0)
    state = alg.train_step(cfg, state, g(state.params))
    assert int(state.last_sync) == 1
    # after warm-up, delta equals the per-worker gradient deviation
    grads0 = np.asarray(quad_grads(5.0)({"x": jnp.array([1.0, 1.0])})["x"])
    # not exactly (params moved), but deltas must be symmetric and non-zero
    d = np.asarray(state.delta["x"])
    assert abs(d.sum()) < 1e-5 and abs(d[0]) > 1.0


def test_delta_matches_eq4_closed_form():
    """Δ update: Δ' = Δ + (x̂ − x_i)/(k_eff γ) with the true elapsed period."""
    lr, k = 0.05, 5
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=k, learning_rate=lr,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    g = quad_grads(2.0)
    prev_delta = np.asarray(state.delta["x"]).copy()
    for t in range(k):
        pre = state
        state = alg.train_step(cfg, state, g(state.params))
    # state just synced at t=k; reconstruct from the pre-sync local params
    pre_local = alg.local_step(cfg, pre, g(pre.params))
    x = np.asarray(pre_local.params["x"])
    xbar = x.mean(axis=0, keepdims=True)
    expect = prev_delta + (xbar - x) / (k * lr)
    np.testing.assert_allclose(np.asarray(state.delta["x"]), expect,
                               rtol=1e-5, atol=1e-6)


def test_easgd_center_pull():
    alg, cfg, state = run("easgd", k=4, steps=40, b=0.0)
    # identical objectives (b=0): everything should head to 0 together
    assert abs(float(state.center["x"][0])) < 1.0


from repro.core import flat_algorithms  # noqa: E402


@pytest.mark.parametrize("alg_name", flat_algorithms())
def test_identical_case_all_converge(alg_name):
    """Paper Fig. 2: with identical worker objectives everyone converges —
    for every flat algorithm in the registry (derived, so new specs like
    stl_sgd/bvr_l_sgd are covered automatically)."""
    alg, cfg, state = run(alg_name, k=8, steps=800, b=0.0)
    xhat = float(alg.average_model(state)["x"][0])
    assert abs(xhat) < 1e-3
