"""The paper's central systems property, verified on compiled HLO:

  * a VRL-SGD LOCAL step contains ZERO collectives over the worker axis
    (pure data parallelism would all-reduce gradients every step);
  * the SYNC step contains exactly the model-averaging all-reduce;
  * S-SGD's train step all-reduces every step.

Runs in a subprocess because the 8-device placeholder env must be set
before jax initializes (the test process already owns a 1-device jax).
"""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import registry
    from repro.configs.base import MeshConfig, VRLConfig
    from repro.launch import roofline as rl
    from repro.launch.dryrun import state_specs, batch_sharding_spec
    from repro.train.train_loop import make_train_step

    mesh_cfg = MeshConfig(shape=(8,), axis_names=("data",),
                          worker_axes=("data",), fsdp_axes=(),
                          tensor_axes=())
    cfg = registry.smoke_arch("granite-3-2b")
    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices(),
                         axis_types=(jax.sharding.AxisType.Auto,))
    out = {}
    for alg in ["vrl_sgd", "ssgd"]:
        vrl = VRLConfig(algorithm=alg, comm_period=4, learning_rate=0.01)
        bundle = make_train_step(cfg, vrl, remat=False)
        st_spec = state_specs(cfg, mesh_cfg, vrl)
        state_abs = jax.eval_shape(
            lambda: bundle.init_state(jax.random.PRNGKey(0), 8))
        toks = jax.ShapeDtypeStruct((8, 2, 32), jnp.int32)
        with jax.set_mesh(mesh):
            for name, fn in [("local", bundle.local_step),
                             ("sync", bundle.sync_step)]:
                if name == "sync":
                    c = jax.jit(fn, in_shardings=(st_spec,),
                                out_shardings=st_spec).lower(state_abs).compile()
                else:
                    c = jax.jit(fn,
                                in_shardings=(st_spec, P("data", None, None),
                                              P("data", None, None)),
                                out_shardings=(st_spec, P())
                                ).lower(state_abs, toks, toks).compile()
                out[f"{alg}/{name}"] = rl.collective_bytes(c.as_text())
    print(json.dumps(out))
""")


def test_local_step_has_no_worker_collectives():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])

    vrl_local = out["vrl_sgd/local"].get("total", 0.0)
    vrl_sync = out["vrl_sgd/sync"].get("total", 0.0)
    ssgd_local = out["ssgd/local"].get("total", 0.0)

    # paper's mechanism: local steps are communication-free (allowing the
    # 4-byte scalar-loss metric all-reduce — not model state) ...
    assert vrl_local <= 64.0, out
    # ... the sync all-reduces the model ...
    assert vrl_sync > 0.0, out
    # ... while S-SGD pays every step (its "local" step IS a train step)
    assert ssgd_local > 0.0, out
    # and the amortized VRL traffic at k=4 is below S-SGD's per-step traffic
    assert vrl_sync / 4 < ssgd_local, out
