"""The paper's central systems property, verified on compiled HLO — now
measured through the FUSED flat-buffer backend (the production update path):

  * a VRL-SGD LOCAL step contains ZERO worker-axis collectives (pure data
    parallelism would all-reduce gradients every step);
  * the SYNC step contains exactly the model-averaging all-reduce — ONE
    all-reduce of the flat buffer spanning all 8 devices;
  * S-SGD's train step all-reduces every step;
  * hierarchical VRL-SGD on a 2x4 pod grid: the level-1 sync is exactly ONE
    all-reduce whose replica groups span only the intra-pod axis (2 groups
    of 4), the level-2 sync exactly ONE all-reduce over the cross-pod axis
    (4 groups of 2), and local steps stay communication-free.

Runs in a subprocess because the 8-device placeholder env must be set
before jax initializes (the test process already owns a 1-device jax).
"""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs import registry
    from repro.configs.base import HierConfig, VRLConfig
    from repro.core import engine as engine_mod
    from repro.launch import roofline as rl
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("granite-3-2b")
    mesh = compat.make_mesh((2, 4), ("pod", "data"), devices=jax.devices())
    axes = ("pod", "data")

    def all_reduce_groups(hlo):
        groups = []
        for line in hlo.splitlines():
            if "all-reduce(" not in line and "all-reduce-start(" not in line:
                continue
            m = re.search(r"replica_groups=\\{\\{(.+?)\\}\\}", line)
            if m:
                groups.append(sorted(
                    len(g.split(",")) for g in m.group(1).split("},{")))
                continue
            m = re.search(r"replica_groups=\\[(\\d+),(\\d+)\\]", line)
            if m:
                groups.append([int(m.group(2))] * int(m.group(1)))
        return groups

    def lower(bundle, state_abs, name, fn, with_data=False):
        sts = compat.shardings(
            mesh, engine_mod.state_partition_specs(state_abs, axes))
        if with_data:
            dspec = compat.shardings(mesh, P(axes, None, None))
            c = jax.jit(fn, in_shardings=(sts, dspec, dspec),
                        out_shardings=(sts, compat.shardings(mesh, P()))
                        ).lower(state_abs, toks, toks).compile()
        else:
            c = jax.jit(fn, in_shardings=(sts,), out_shardings=sts
                        ).lower(state_abs).compile()
        hlo = c.as_text()
        return {"bytes": rl.collective_bytes(hlo),
                "ar_groups": all_reduce_groups(hlo)}

    toks = jax.ShapeDtypeStruct((8, 2, 32), jnp.int32)
    out = {}
    with compat.set_mesh(mesh):
        for alg in ["vrl_sgd", "ssgd"]:
            vrl = VRLConfig(algorithm=alg, comm_period=4, learning_rate=0.01,
                            update_backend="fused")
            bundle = make_train_step(cfg, vrl, remat=False, mesh=mesh,
                                     worker_axes=axes)
            state_abs = jax.eval_shape(
                lambda: bundle.init_state(jax.random.PRNGKey(0), 8))
            out[f"{alg}/local"] = lower(bundle, state_abs, alg,
                                        bundle.local_step, with_data=True)
            out[f"{alg}/sync"] = lower(bundle, state_abs, alg,
                                       bundle.sync_step)

        vrl_h = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.01,
                          update_backend="fused",
                          hier=HierConfig(k1=2, k2=8, grid=(2, 4),
                                          axes=axes))
        bundle = make_train_step(cfg, vrl_h, remat=False, mesh=mesh,
                                 worker_axes=axes)
        state_abs = jax.eval_shape(
            lambda: bundle.init_state(jax.random.PRNGKey(0), 8))
        out["hier/local"] = lower(bundle, state_abs, "hier",
                                  bundle.local_step, with_data=True)
        out["hier/sync1"] = lower(bundle, state_abs, "hier",
                                  bundle.sync1_step)
        out["hier/sync2"] = lower(bundle, state_abs, "hier",
                                  bundle.sync2_step)
    print(json.dumps(out))
""")


_OUT = None


def _run():
    global _OUT
    if _OUT is None:
        env = dict(os.environ, PYTHONPATH="src")
        res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                             capture_output=True, text=True, timeout=900)
        assert res.returncode == 0, res.stderr[-2000:]
        _OUT = json.loads(res.stdout.strip().splitlines()[-1])
    return _OUT


def test_fused_local_step_has_no_worker_collectives():
    out = _run()
    vrl_local = out["vrl_sgd/local"]["bytes"].get("total", 0.0)
    vrl_sync = out["vrl_sgd/sync"]["bytes"].get("total", 0.0)
    ssgd_local = out["ssgd/local"]["bytes"].get("total", 0.0)

    # paper's mechanism: local steps are communication-free (allowing the
    # 4-byte scalar-loss metric all-reduce — not model state) ...
    assert vrl_local <= 64.0, out
    # ... the sync all-reduces the model: exactly ONE flat-buffer
    # all-reduce spanning all 8 devices ...
    assert vrl_sync > 0.0, out
    assert out["vrl_sgd/sync"]["ar_groups"] == [[8]], out
    # ... while S-SGD pays every step (its "local" step IS a train step)
    assert ssgd_local > 0.0, out
    # and the amortized VRL traffic at k=4 is below S-SGD's per-step traffic
    assert vrl_sync / 4 < ssgd_local, out


def test_hierarchical_sync_levels_use_their_own_axis():
    out = _run()
    # level-1: exactly one all-reduce, spanning ONLY the intra-pod axis
    # (2 pods x 4 workers -> 2 replica groups of 4)
    assert out["hier/sync1"]["ar_groups"] == [[4, 4]], out
    # level-2: exactly one all-reduce over the cross-pod axis (4 groups of 2)
    assert out["hier/sync2"]["ar_groups"] == [[2, 2, 2, 2]], out
    # local steps: no model-state collectives at either level
    assert out["hier/local"]["bytes"].get("total", 0.0) <= 64.0, out
    # cross-pod traffic per boundary is the flat buffer once — no extra
    # collectives hide in the level-2 step
    assert out["hier/sync2"]["bytes"].get("total", 0.0) > 0.0, out
