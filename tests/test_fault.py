"""Elastic fault tolerance: schedules, membership invariants, guards.

Four layers, matching the fault subsystem's own layering:

1. ``repro.fault.FaultSchedule`` — the spec grammar round-trips, random
   schedules are seed-deterministic, gradient poisons land at the right
   (step, worker) slot and fire exactly ONCE (rollback replays are
   clean), while crash/rejoin masks are a pure fold (replays see the
   same membership).
2. Engine membership (``VRLConfig.membership``) — with the mask fully
   active the trajectory is BITWISE identical to the membership=False
   engine (flat at a non-power-of-2 W, where sum*(1/n) vs sum/n rounding
   would differ, and hierarchical); every drop/rejoin repair restores
   Σ_i Δ_i = 0 (and Σ_i B_i = 0 for BVR) over the active set; a dead
   worker's NaNs never leak into survivors; the repair composes with
   compressed sync (EF residuals of dropped workers zeroed) and
   overlapped rounds; EASGD refuses membership loudly.
3. Train-loop hooks — ``StepBundle.round_step_fault`` with an all-ones
   multiplier reproduces ``round_step`` exactly; a NaN multiplier makes
   exactly the targeted worker sick and ``StepBundle.health`` flips;
   the reference backend refuses membership.
4. Driver flag validation — out-of-range flags and malformed/impossible
   fault specs exit early with named messages.

The collective-count acceptance (masked sync is still exactly ONE
all-reduce per round on an 8-device mesh, and full-mask mesh parity) runs
in a subprocess, same idiom as tests/test_engine_collectives.py.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HierConfig, VRLConfig
from repro.core import flat_algorithms, make_engine
from repro.fault import FaultEvent, FaultSchedule

# ---------------------------------------------------------------- schedule


def test_spec_parse_roundtrip():
    fs = FaultSchedule.parse("nan@1:12, crash@1:30,rejoin@1:60,killsave:50")
    assert len(fs) == 4
    assert fs.describe() == "nan@1:12,crash@1:30,killsave:50,rejoin@1:60"
    assert fs.events[0] == FaultEvent("nan", 12, 1)
    assert fs.membership_events() == [FaultEvent("crash", 30, 1),
                                      FaultEvent("rejoin", 60, 1)]


def test_scale_spec_parse_roundtrip():
    fs = FaultSchedule.parse("scale@0:20:1e3,nan@1:12")
    assert fs.describe() == "nan@1:12,scale@0:20:1000"
    assert fs.events[1] == FaultEvent("scale", 20, 0, 1e3)
    # describe() output re-parses to the same schedule
    assert FaultSchedule.parse(fs.describe()).events == fs.events
    # fractional and sub-1 multipliers survive the g-format roundtrip
    fs2 = FaultSchedule.parse("scale@2:5:0.125")
    assert FaultSchedule.parse(fs2.describe()).events == fs2.events


@pytest.mark.parametrize("bad,msg", [
    ("frob@1:3", "unknown fault kind"),
    ("nan@1", "no ':step'"),
    ("nan:3", "needs a worker"),
    ("nan@1:x", "not an integer"),
    ("nan@z:3", "not an integer"),
    ("nan@-1:3", "worker must be >= 0"),
    ("nan@1:-3", "step must be >= 0"),
    ("killsave@2:3", "killsave takes no worker"),
    ("  ,  ", "contains no events"),
    ("scale@1:12", "needs a multiplier"),
    ("scale@1:12:zzz", "not a float"),
    ("scale@1:12:inf", "multiplier must be finite"),
    ("scale@1:12:nan", "multiplier must be finite"),
])
def test_spec_errors_are_named(bad, msg):
    with pytest.raises(ValueError, match=msg):
        FaultSchedule.parse(bad)


def test_random_schedule_is_seed_deterministic():
    a = FaultSchedule.random(100, 8, seed=7, n_grad=2, n_churn=2,
                             killsave=True)
    b = FaultSchedule.random(100, 8, seed=7, n_grad=2, n_churn=2,
                             killsave=True)
    assert a.describe() == b.describe()
    c = FaultSchedule.random(100, 8, seed=8, n_grad=2, n_churn=2,
                             killsave=True)
    assert a.describe() != c.describe()
    # every drawn mask keeps at least one survivor
    for t in range(100):
        assert a.active_at(t, 8).sum() >= 1
    with pytest.raises(ValueError, match=">= 2 workers"):
        FaultSchedule.random(100, 1, seed=0)


def test_grad_mul_placement_and_single_fire():
    fs = FaultSchedule.parse("nan@2:5,inf@0:6")
    assert fs.grad_mul(0, 4, 4) is None          # clean round -> None
    m = fs.grad_mul(4, 4, 4)                     # round covering [4, 8)
    assert m.shape == (4, 4)
    assert np.isnan(m[1, 2]) and np.isinf(m[2, 0])
    assert (m[np.isfinite(m)] == 1.0).all()
    # consumed: the rollback replay of the same round is clean
    assert fs.grad_mul(4, 4, 4) is None


def test_scale_grad_mul_is_finite_and_placed():
    fs = FaultSchedule.parse("scale@3:6:1e3")
    m = fs.grad_mul(4, 4, 4)                     # round covering [4, 8)
    assert m[2, 3] == 1e3
    assert np.isfinite(m).all()                  # silent: no NaN/Inf
    assert (m[m != 1e3] == 1.0).all()
    assert fs.grad_mul(4, 4, 4) is None          # one-shot


def test_membership_fold_is_pure():
    fs = FaultSchedule.parse("crash@1:3,rejoin@1:7,crash@2:5")
    np.testing.assert_array_equal(fs.active_at(2, 4), [1, 1, 1, 1])
    np.testing.assert_array_equal(fs.active_at(4, 4), [1, 0, 1, 1])
    np.testing.assert_array_equal(fs.active_at(6, 4), [1, 0, 0, 1])
    # replaying an earlier step after a rollback sees the same mask
    np.testing.assert_array_equal(fs.active_at(4, 4), [1, 0, 1, 1])
    np.testing.assert_array_equal(fs.active_at(9, 4), [1, 1, 0, 1])
    # killsave is one-shot across the whole run, grad faults likewise
    fs2 = FaultSchedule.parse("killsave:5")
    assert not fs2.killsave_at(4)
    assert fs2.killsave_at(6) and not fs2.killsave_at(7)


# ------------------------------------------------- engine membership layer

W = 5                # deliberately non-power-of-2: 1/W is not exact
TEMPLATE = {"w": jnp.zeros((12, 8)), "b": jnp.zeros((5,))}
P0 = {"w": jnp.ones((12, 8)) * 0.3, "b": jnp.ones((5,)) * -0.2}


def _cfg(alg="vrl_sgd", backend="xla", **kw):
    return VRLConfig(algorithm=alg, comm_period=4, learning_rate=0.05,
                     weight_decay=0.0, warmup=False,
                     update_backend=backend, **kw)


def _gk(eng, state, r, k=4, scale=0.1):
    return jax.tree.map(
        lambda x: jnp.stack([jnp.sin(x + r * k + i) * scale
                             for i in range(k)]),
        eng.params_tree(state))


def _run(cfg, rounds=3, w=W):
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(P0, w)
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(rounds):
        state = rs(state, _gk(eng, state, r))
    return eng, state


@pytest.mark.parametrize("alg",
                         [a for a in flat_algorithms() if a != "easgd"])
def test_full_mask_is_bitwise_identical(alg):
    """The fault-free path costs nothing: with every worker active the
    membership engine's trajectory equals the membership=False engine
    BITWISE, at W=5 where a masked mean computed as sum/n (instead of
    the baseline's algebraically-simplified sum*(1/n)) would diverge in
    the last bit."""
    _, s0 = _run(_cfg(alg))
    _, s1 = _run(_cfg(alg, membership=True))
    assert np.array_equal(np.asarray(s0.params), np.asarray(s1.params))
    if hasattr(s0, "delta") and not isinstance(s0.delta, tuple):
        assert np.array_equal(np.asarray(s0.delta), np.asarray(s1.delta))


def test_easgd_refuses_membership():
    with pytest.raises(ValueError, match="easgd"):
        make_engine(_cfg("easgd", membership=True), TEMPLATE)


def test_drop_repairs_invariant_and_contains_nan():
    """Dropping a worker recentres Δ over the survivors (Σ Δ = 0 again),
    and a dead worker's NaN rows never reach an active row or the
    average model — the sync masks with where, not multiply."""
    eng, s = _run(_cfg(membership=True), rounds=2)
    setm = jax.jit(eng.set_membership, donate_argnums=(0,))
    mask = np.array([1, 0, 1, 1, 1], np.float32)
    s = setm(s, mask)
    act = np.asarray(s.member.active).reshape(-1) > 0
    np.testing.assert_array_equal(act, mask > 0)
    assert float(s.member.n_active) == 4.0
    d = np.asarray(s.delta)
    assert np.abs(d[act].sum(0)).max() < 1e-5
    assert np.abs(d[~act]).max() == 0.0          # dropped rows zeroed
    # poison the dead row, run two rounds: survivors stay finite
    pm = np.array(s.params)
    pm[1] = np.nan
    s = s._replace(params=jnp.asarray(pm))
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(2):
        s = rs(s, _gk(eng, s, r))
    assert np.isfinite(np.asarray(s.params)[act]).all()
    for leaf in jax.tree.leaves(eng.average_model(s)):
        assert np.isfinite(np.asarray(leaf)).all()
    # rejoin: the sick worker restarts from the continuing consensus
    s = setm(s, np.ones(W, np.float32))
    assert float(s.member.n_active) == float(W)
    assert np.isfinite(np.asarray(s.params)).all()
    assert np.abs(np.asarray(s.delta).sum(0)).max() < 1e-5
    xhat = np.asarray(s.params)[0]
    np.testing.assert_array_equal(np.asarray(s.params)[1], xhat)


def test_bvr_bias_invariant_survives_drop():
    eng, s = _run(_cfg("bvr_l_sgd", membership=True), rounds=2)
    s = jax.jit(eng.set_membership)(s, np.array([1, 1, 0, 1, 1],
                                                np.float32))
    act = np.asarray(s.member.active).reshape(-1) > 0
    assert np.abs(np.asarray(s.delta)[act].sum(0)).max() < 1e-5
    assert np.abs(np.asarray(s.bias)[act].sum(0)).max() < 1e-5


def test_membership_composes_with_compression():
    """A dropped worker's error-feedback residual is zeroed (its backlog
    has no owner) and the compressed masked sync keeps survivors
    finite."""
    from repro.comm import compressors as cc

    cfg = _cfg(membership=True, compress=cc.parse_compressor("int8"))
    eng, s = _run(cfg, rounds=2)
    s = jax.jit(eng.set_membership)(s, np.array([0, 1, 1, 1, 1],
                                                np.float32))
    assert np.abs(np.asarray(s.comm.resid)[0]).max() == 0.0
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(2):
        s = rs(s, _gk(eng, s, r))
    act = np.asarray(s.member.active).reshape(-1) > 0
    assert np.isfinite(np.asarray(s.params)[act]).all()


def test_membership_composes_with_overlap():
    """Overlapped rounds: the repair reseeds a dropped worker's pending
    contribution from the consensus, and post-drop rounds keep the
    invariant on the active set."""
    eng, s = _run(_cfg(membership=True, overlap=True), rounds=2)
    s = jax.jit(eng.set_membership)(s, np.array([1, 0, 1, 1, 1],
                                                np.float32))
    rs = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(2):
        s = rs(s, _gk(eng, s, r))
    act = np.asarray(s.member.active).reshape(-1) > 0
    assert np.isfinite(np.asarray(s.params)[act]).all()
    assert np.abs(np.asarray(s.delta)[act].sum(0)).max() < 1e-3


def test_hier_membership_pod_and_worker_drop():
    """Hierarchical: dropping a worker preserves the intra-pod invariant
    (Σ Δ1 = 0 over the pod's survivors); dropping a WHOLE pod preserves
    the cross-pod invariant (Σ Δ2 = 0 over alive pods, n_active counts
    pods); rejoining everyone restores both.  Full-mask trajectory is
    bitwise the membership=False hierarchical engine."""
    grid = (2, 3)
    cfgh = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                     update_backend="xla", membership=True,
                     hier=HierConfig(k1=2, k2=4, grid=grid))

    def runh(cfg):
        e = make_engine(cfg, TEMPLATE)
        s = e.init(P0, 6)
        rs = jax.jit(e.round_step, donate_argnums=(0,))
        for r in range(3):
            s = rs(s, _gk(e, s, r, k=2))
        return e, s

    _, s0 = runh(dataclasses.replace(cfgh, membership=False))
    engh, sh = runh(cfgh)
    assert np.array_equal(np.asarray(s0.params), np.asarray(sh.params))

    seth = jax.jit(engh.set_membership)
    m = np.ones(grid, np.float32)
    m[0, 1] = 0          # one worker out of pod 0
    m[1, :] = 0          # all of pod 1
    sh = seth(sh, m)
    assert float(sh.member.n_active) == 1.0      # alive PODS
    np.testing.assert_array_equal(
        np.asarray(sh.member.n_pod).reshape(-1), [2.0, 0.0])
    keep = np.asarray(sh.member.active)[..., 0, 0] > 0
    d1 = np.asarray(sh.delta1)
    assert np.abs((d1[0] * keep[0][:, None, None]).sum(0)).max() < 1e-5
    d2 = np.asarray(sh.delta2)
    alive = np.asarray(sh.member.n_pod).reshape(-1) > 0
    assert np.abs(d2[alive].sum(0)).max() < 1e-5
    rsh = jax.jit(engh.round_step, donate_argnums=(0,))
    for r in range(2):
        sh = rsh(sh, _gk(engh, sh, r, k=2))
    assert np.isfinite(np.asarray(sh.params)).all()
    sh = seth(sh, np.ones(grid, np.float32))
    assert float(sh.member.n_active) == 2.0
    assert np.abs(np.asarray(sh.delta2).sum(0)).max() < 1e-5


# ---------------------------------------------------- train-loop fault hooks


def _bundle(backend="auto", membership=True):
    from repro.configs import registry
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=3, learning_rate=0.2,
                    weight_decay=0.0, warmup=False,
                    update_backend=backend, membership=membership)
    return make_train_step(cfg, vrl, remat=False)


def test_round_step_fault_clean_matches_round_step():
    """An all-ones multiplier is a no-op: the fault round reproduces the
    clean round bitwise, so the chaos harness can't perturb a healthy
    run."""
    bundle = _bundle()
    w, b, sq, k = 2, 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (k, w, b, sq), 0, 64)
    labels = jnp.roll(toks, -1, -1)
    s_a = bundle.init_state(jax.random.PRNGKey(0), w)
    s_b = bundle.init_state(jax.random.PRNGKey(0), w)
    s_a, l_a = jax.jit(bundle.round_step)(s_a, toks, labels)
    gmul = jnp.ones((k, w), jnp.float32)
    s_b, l_b = jax.jit(bundle.round_step_fault)(s_b, toks, labels, gmul)
    np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_b))
    assert np.array_equal(np.asarray(s_a.params), np.asarray(s_b.params))


def test_nan_poison_trips_health_and_prior_drop_contains():
    """A NaN multiplier on an ACTIVE worker poisons the round-closing
    sync (every worker averages it in) and health() goes False — the
    signal the divergence guard rolls back on.  The same poisoned round
    run AFTER dropping that worker stays healthy: the masked sync reads
    no dead rows, so the sick worker's NaNs never cross."""
    bundle = _bundle()
    w, b, sq, k = 2, 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (k, w, b, sq), 0, 64)
    labels = jnp.roll(toks, -1, -1)
    health = jax.jit(bundle.health)
    rfault = jax.jit(bundle.round_step_fault)
    gmul = jnp.ones((k, w), jnp.float32).at[1, 1].set(jnp.nan)

    state = bundle.init_state(jax.random.PRNGKey(0), w)
    sick, losses = rfault(state, toks, labels, gmul)
    assert not bool(health(sick, losses[-1]))
    assert np.isnan(np.asarray(sick.params)).any()

    state = bundle.init_state(jax.random.PRNGKey(0), w)
    state = jax.jit(bundle.engine.set_membership)(
        state, np.array([1, 0], np.float32))
    state, losses = rfault(state, toks, labels, gmul)
    assert bool(health(state, losses[-1]))
    assert np.isfinite(np.asarray(state.params)[0]).all()


def test_reference_backend_refuses_membership():
    with pytest.raises(ValueError, match="membership"):
        _bundle(backend="reference")


# --------------------------------------------------- driver flag validation


@pytest.mark.parametrize("flags,msg", [
    (["--deadline", "1.5"], "probability in \\[0, 1\\]"),
    (["--ckpt-every", "0"], "--ckpt-every must be a positive"),
    (["--shards", "0"], "--shards must be >= 1"),
    (["--steps", "-3"], "--steps must be >= 1"),
    (["--k", "0"], "--k must be >= 1"),
    (["--workers", "0"], "--workers must be >= 1"),
    (["--ckpt-retain", "-1"], "--ckpt-retain must be >= 0"),
    (["--max-retries", "-1"], "--max-retries must be >= 0"),
    (["--workers", "2", "--faults", "nan@5:3"],
     "targets a worker >= --workers"),
    (["--workers", "2", "--faults", "crash@0:3,crash@1:4"],
     "no active worker at step 4"),
    (["--workers", "2", "--faults", "frob@0:3"], "unknown fault kind"),
    (["--membership", "--backend", "reference"],
     "membership"),
])
def test_bad_flags_exit_with_named_message(flags, msg):
    from repro.launch import train

    with pytest.raises(SystemExit, match=msg):
        train.main(["--smoke", "--steps", "4"] + flags)


def test_guard_catches_scale_poison_and_rolls_back(capsys):
    """A finite scale poison passes every finiteness check — the state
    never goes NaN — so ONLY the loss-trend guard can catch it.  Poison
    the first local step of a round: that round's mean loss blows up,
    the guard rolls back to the round snapshot, and the consumed fault
    lets the replay finish clean."""
    from repro.launch import train

    train.main(["--smoke", "--steps", "6", "--workers", "4",
                "--batch", "2", "--seq", "32", "--k", "2",
                "--lr", "0.05", "--guard", "--max-retries", "2",
                "--faults", "scale@1:2:1e3", "--log-every", "1"])
    out = capsys.readouterr().out
    assert "gradient fault in round [2, 4)" in out
    assert "loss blow-up" in out                 # the trend branch fired,
    assert "non-finite state" not in out         # not the finiteness one
    assert "rolled back to step 2 (retry 1/2)" in out
    assert "done: 6 steps" in out


# ------------------------------------- collective count on an 8-device mesh

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import json
    import re
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import VRLConfig
    from repro.core import make_engine

    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="xla",
                    membership=True)
    eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}

    def shard(x):
        nd = getattr(x, "ndim", 0)
        spec = P("data", None, None) if nd == 3 else P(*([None] * nd))
        return jax.device_put(x, NamedSharding(mesh, spec))

    state = jax.tree.map(shard, eng.init(p0, 8))

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    out = {}
    # the acceptance property: the MASKED sync is still exactly one
    # all-reduce (n_active rides in state, no survivor-count collective),
    # and the whole compiled round keeps one collective per k steps
    hlo_sync = jax.jit(eng.sync).lower(state).compile().as_text()
    out["sync_all_reduce"] = count_ar(hlo_sync)
    gk = jax.tree.map(lambda x: jnp.stack([jnp.sin(3.0 * x + t) + 0.1 * x
                                           for t in range(4)]),
                      eng.params_tree(state))
    hlo_round = jax.jit(eng.round_step, donate_argnums=(0,)
                        ).lower(state, gk).compile().as_text()
    out["round_all_reduce"] = count_ar(hlo_round)
    # the repair itself is collective-frugal: one jit covers every mask
    hlo_m = jax.jit(eng.set_membership).lower(
        state, jnp.ones((8,), jnp.float32)).compile().as_text()
    out["repair_all_reduce"] = count_ar(hlo_m)

    # full-mask mesh parity: same trajectory as membership=False
    eng0 = make_engine(dataclasses.replace(cfg, membership=False),
                       template, mesh=mesh, worker_axes=("data",))
    s0 = jax.tree.map(shard, eng0.init(p0, 8))
    s1 = state
    r0 = jax.jit(eng0.round_step, donate_argnums=(0,))
    r1 = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(3):
        gk = jax.tree.map(lambda x: jnp.stack(
            [jnp.sin(3.0 * x + r * 4 + t) + 0.1 * x for t in range(4)]),
            eng0.params_tree(s0))
        s0 = r0(s0, gk)
        s1 = r1(s1, gk)
    out["mesh_full_mask_bitwise"] = bool(np.array_equal(
        np.asarray(s0.params), np.asarray(s1.params)))

    # drop two workers ON the mesh: invariant holds under sharding
    s1 = jax.jit(eng.set_membership)(
        s1, jnp.array([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32))
    act = np.asarray(s1.member.active).reshape(-1) > 0
    d = np.asarray(s1.delta)
    out["mesh_drop_sum_delta"] = float(np.abs(d[act].sum(0)).max())
    out["mesh_drop_n_active"] = float(np.asarray(s1.member.n_active))
    print(json.dumps(out))
""")


def test_masked_sync_is_still_one_all_reduce():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # membership must not add a collective: one all-reduce, total — the
    # masked mean's divisor comes from state, not a second reduction
    assert out["sync_all_reduce"] == 1, out
    assert out["round_all_reduce"] == 1, out
    # the out-of-round repair needs a bounded handful of collectives
    # (consensus + recenters), far from per-leaf
    assert out["repair_all_reduce"] <= 8, out
    assert out["mesh_full_mask_bitwise"] is True, out
    assert out["mesh_drop_sum_delta"] < 1e-5, out
    assert out["mesh_drop_n_active"] == 6.0, out
