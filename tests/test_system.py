"""End-to-end system test: non-iid VRL-SGD training -> checkpoint ->
restore -> serve the averaged model with the batched engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import registry
from repro.configs.base import VRLConfig
from repro.data import lm_token_stream
from repro.serve.engine import Engine
from repro.train.train_loop import make_train_step


def test_end_to_end_train_checkpoint_serve(tmp_path):
    w, batch, seq, steps = 4, 4, 32, 30
    cfg = registry.smoke_arch("granite-3-2b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=5, learning_rate=0.3,
                    weight_decay=0.0, warmup=True)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), w)
    data = lm_token_stream(w, seq, 64, steps=steps, batch=batch,
                           alpha=0.05, seed=3)
    step = jax.jit(bundle.train_step)
    first = last = None
    for t in range(steps):
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, loss = step(state, toks, labels)
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first  # training works

    # checkpoint + restore
    ckpt.save(str(tmp_path / "run"), state, meta={"step": int(state.step)})
    restored = ckpt.restore(str(tmp_path / "run"), state)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(restored.params)[0]),
        np.asarray(jax.tree.leaves(state.params)[0]))

    # serve the averaged model (bundle.average_model is backend-appropriate
    # — the default "auto" backend carries flat-buffer engine state)
    model = bundle.average_model(restored)
    eng = Engine(cfg, model, max_len=64)
    prompt = jnp.asarray(data[0, 0, :2, :8])        # (2, 8) prompt
    out = eng.generate(prompt, steps=6)
    assert out.shape == (2, 14)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

    # sampled generation too
    out2 = eng.generate(prompt, steps=4, temperature=0.8,
                        key=jax.random.PRNGKey(1))
    assert out2.shape == (2, 12)
