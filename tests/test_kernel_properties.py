"""Hypothesis property tests for the Pallas kernels (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, ssd_ref


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 2), s=st.integers(3, 96), h=st.integers(1, 3),
       kv_ratio=st.sampled_from([1, 2]), d=st.sampled_from([16, 32]),
       window=st.sampled_from([None, 16]),
       seed=st.integers(0, 1000))
def test_mha_flash_matches_reference(b, s, h, kv_ratio, d, window, seed):
    """Arbitrary (non-aligned!) shapes: the wrapper pads to block multiples
    and must still match plain softmax attention exactly."""
    nh = h * kv_ratio
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, nh, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = ops.mha_flash(q, k, v, window=window, block_q=32, block_k=32)
    kk = jnp.repeat(k, kv_ratio, axis=2)
    vv = jnp.repeat(v, kv_ratio, axis=2)
    ref = flash_attention_ref(
        jnp.moveaxis(q, 2, 1).reshape(b * nh, s, d),
        jnp.moveaxis(kk, 2, 1).reshape(b * nh, s, d),
        jnp.moveaxis(vv, 2, 1).reshape(b * nh, s, d), window=window)
    ref = jnp.moveaxis(ref.reshape(b, nh, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), nc=st.integers(1, 4), h=st.integers(1, 3),
       p=st.sampled_from([8, 16]), n=st.sampled_from([4, 8]),
       seed=st.integers(0, 1000))
def test_ssd_scan_matches_recurrence(b, nc, h, p, n, seed):
    """Chunked SSD == sequential recurrence for arbitrary chunk counts."""
    chunk = 16
    l = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, l, n)) * 0.3
    y = ops.ssd_chunk_scan(x, dt, a_log, bb, cc, chunk=chunk)
    yr = ssd_ref(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-3, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 500), lr=st.floats(1e-4, 1.0),
       seed=st.integers(0, 1000))
def test_vrl_update_arbitrary_sizes(n, lr, seed):
    """The fused update handles any flattened size via padding."""
    from repro.kernels.ref import vrl_update_ref
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    d = jax.random.normal(ks[2], (n,))
    out = ops.vrl_local_update_tree({"w": p}, {"w": g}, {"w": d}, lr=lr)
    ref = vrl_update_ref(p, g, d, lr)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
