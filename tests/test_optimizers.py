"""Inner-optimizer unit tests (built from scratch, no optax)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, momentum, sgd


def _minimize(opt, steps=200):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": 2 * params["w"]}         # f = ||w||^2
        params, state = opt.update(params, grads, state)
    return float(jnp.linalg.norm(params["w"]))


def test_sgd_minimizes():
    assert _minimize(sgd(0.05)) < 1e-3


def test_momentum_minimizes():
    assert _minimize(momentum(0.02, 0.9)) < 1e-3


def test_adam_minimizes():
    assert _minimize(adam(0.05)) < 1e-2


def test_sgd_matches_closed_form():
    opt = sgd(0.1)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    p2, _ = opt.update(p, {"w": jnp.array([0.5])}, s)
    np.testing.assert_allclose(float(p2["w"][0]), 1.0 - 0.1 * 0.5)


def test_weight_decay_decoupled():
    opt = sgd(0.1, weight_decay=0.01)
    p = {"w": jnp.array([1.0])}
    p2, _ = opt.update(p, {"w": jnp.array([0.0])}, opt.init(p))
    np.testing.assert_allclose(float(p2["w"][0]), 1.0 - 0.1 * 0.01 * 1.0)


def test_momentum_accumulates():
    opt = momentum(0.1, 0.9)
    p = {"w": jnp.array([0.0])}
    s = opt.init(p)
    g = {"w": jnp.array([1.0])}
    p, s = opt.update(p, g, s)      # m=1, step -0.1
    p, s = opt.update(p, g, s)      # m=1.9, step -0.19
    np.testing.assert_allclose(float(p["w"][0]), -0.29, rtol=1e-6)


def test_bf16_params_fp32_math():
    opt = sgd(0.1)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, _ = opt.update(p, {"w": jnp.full((4,), 0.5, jnp.bfloat16)},
                       opt.init(p))
    assert p2["w"].dtype == jnp.bfloat16
