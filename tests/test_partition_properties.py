"""Property-based tests (hypothesis) for the data partitioners and the
client-cohort sampler.

Own module (the ``test_schedule_properties.py`` pattern) so the
module-level ``importorskip`` skips ONLY the randomized properties when
hypothesis is absent — the deterministic partition tests in
``test_data.py`` and the cohort tests in ``test_clients.py`` always run.

The properties are the federated-scale correctness contracts:

* every partitioner covers the index set EXACTLY once (no loss, no
  duplication) with int64 arrays and at least one index per unit — the
  two bugs (float64-from-empty-bucket, fresh-split-on-resume) were both
  violations of this family;
* ``repartition`` preserves the index multiset across ANY unit-count
  change, which is what makes resharded resume data-lossless;
* cohorts are distinct, sorted, in-range, and seed-deterministic.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clients import sample_cohort  # noqa: E402
from repro.data.partition import (  # noqa: E402
    assignment_from_meta,
    assignment_to_meta,
    contiguous_assignment,
    dirichlet_partition,
    iid_partition,
    repartition,
)


def _assert_exact_cover(parts, n, num_units):
    assert len(parts) == num_units
    for p in parts:
        assert p.dtype == np.int64          # never a float64 empty array
        assert len(p) >= 1                  # every unit holds something
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert set(allidx.tolist()) == set(range(n))


@settings(max_examples=60, deadline=None)
@given(n_classes=st.integers(1, 8), n=st.integers(1, 200),
       workers=st.integers(1, 12),
       alpha=st.floats(0.01, 10.0), seed=st.integers(0, 2 ** 16))
def test_dirichlet_partition_covers_exactly_once(n_classes, n, workers,
                                                 alpha, seed):
    labels = np.arange(n) % n_classes
    if n < workers:
        with pytest.raises(ValueError,
                           match="cannot give every worker an index"):
            dirichlet_partition(labels, workers, alpha=alpha, seed=seed)
        return
    parts = dirichlet_partition(labels, workers, alpha=alpha, seed=seed)
    _assert_exact_cover(parts, n, workers)
    again = dirichlet_partition(labels, workers, alpha=alpha, seed=seed)
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(a, b)     # seed-deterministic


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 200), w0=st.integers(1, 12),
       w1=st.integers(1, 12), seed=st.integers(0, 2 ** 10))
def test_repartition_preserves_the_index_multiset(n, w0, w1, seed):
    if n < w0:
        return
    parts = iid_partition(n, w0, seed=seed)
    if n < w1:
        with pytest.raises(ValueError,
                           match="cannot give every worker an index"):
            repartition(parts, w1)
        return
    re = repartition(parts, w1)
    _assert_exact_cover(re, n, w1)
    # worker-order concatenation is preserved verbatim (contiguity is
    # what keeps each unit's non-iid structure through a reshard)
    np.testing.assert_array_equal(np.concatenate(re),
                                  np.concatenate(parts))


@settings(max_examples=60, deadline=None)
@given(shards=st.integers(1, 64), units=st.integers(1, 64))
def test_contiguous_assignment_covers_in_order(shards, units):
    if shards < units:
        with pytest.raises(ValueError,
                           match="cannot give every unit a shard"):
            contiguous_assignment(shards, units)
        return
    parts = contiguous_assignment(shards, units)
    _assert_exact_cover(parts, shards, units)
    np.testing.assert_array_equal(np.concatenate(parts),
                                  np.arange(shards))


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 100), w=st.integers(1, 12),
       seed=st.integers(0, 2 ** 10))
def test_assignment_meta_roundtrip(n, w, seed):
    if n < w:
        return
    parts = iid_partition(n, w, seed=seed)
    back = assignment_from_meta(assignment_to_meta(parts))
    assert len(back) == len(parts)
    for a, b in zip(parts, back):
        assert b.dtype == np.int64
        np.testing.assert_array_equal(np.asarray(a, np.int64), b)


@settings(max_examples=80, deadline=None)
@given(m=st.integers(1, 64), w=st.integers(1, 64),
       r=st.integers(0, 1000), seed=st.integers(0, 2 ** 16))
def test_cohorts_are_distinct_sorted_in_range(m, w, r, seed):
    if not 0 < w <= m:
        with pytest.raises(ValueError, match="cohort_size must be in"):
            sample_cohort(m, w, r, seed)
        return
    c = sample_cohort(m, w, r, seed)
    assert c.dtype == np.int64 and c.shape == (w,)
    assert (np.diff(c) > 0).all() if w > 1 else True
    assert c.min() >= 0 and c.max() < m
    np.testing.assert_array_equal(c, sample_cohort(m, w, r, seed))
    if m == w:
        np.testing.assert_array_equal(c, np.arange(m))
