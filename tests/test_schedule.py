"""Adaptive communication schedules (beyond-paper): correctness with the
exact k_eff Δ update, and communication savings."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VRLConfig
from repro.core import get_algorithm
from repro.core.schedule import const_schedule, sqrt_schedule, total_syncs


def run_scheduled(sched, steps=4000, lr=0.02, b=5.0):
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=1, learning_rate=lr,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    local = jax.jit(lambda s, g: alg.local_step(cfg, s, g))
    sync = jax.jit(lambda s: alg.sync(cfg, s))
    syncs = 0
    for t in range(steps):
        x = state.params["x"]
        grads = {"x": jnp.stack([2 * (x[0] + 2 * b), 4 * (x[1] - b)])}
        state = local(state, grads)
        if sched.should_sync(int(state.step), int(state.last_sync)):
            state = sync(state)
            syncs += 1
    return abs(float(alg.average_model(state)["x"][0])), syncs


def test_sqrt_schedule_converges_with_fewer_syncs():
    dist_c, syncs_c = run_scheduled(const_schedule(8, warmup=False))
    dist_s, syncs_s = run_scheduled(sqrt_schedule(c=0.5, k_max=64))
    assert dist_s < 1e-3            # still converges on the non-iid quadratic
    assert syncs_s < 0.6 * syncs_c  # with substantially less communication


def test_sqrt_period_grows():
    s = sqrt_schedule(c=1.0, k_max=50, warmup=True)
    assert s.period_at(1) == 1          # warm-up (Remark 5.3)
    assert s.period_at(100) == 10
    assert s.period_at(10_000) == 50    # capped


def test_total_syncs_matches_complexity_shape():
    """sqrt schedule gives O(sqrt(T)) rounds — the paper's Table 1 rate."""
    s = sqrt_schedule(c=1.0, k_max=10**9, warmup=False)
    r1 = total_syncs(s, 1_000)
    r2 = total_syncs(s, 4_000)
    # 4x the horizon -> ~2x the rounds (within 20%)
    assert 1.6 < r2 / r1 < 2.4, (r1, r2)


# --------------------------------------- CommSchedule (deterministic part;
# randomized properties live in tests/test_schedule_properties.py so a
# missing hypothesis never skips this module)
import pytest  # noqa: E402

from repro.core.schedule import (  # noqa: E402
    CommSchedule,
    const_comm,
    custom_stages,
    parse_schedule,
)


def test_const_comm_is_single_stage():
    sched = const_comm(7)
    assert sched.round_sizes(22) == [7, 7, 7]
    assert sched.period_starting_at(0) == sched.period_starting_at(700) == 7


def test_parse_schedule_forms():
    assert parse_schedule("const", 9).stages == ((9, 1),)
    assert parse_schedule("const:5").stages == ((5, 1),)
    assert (parse_schedule("stagewise:1:2:8", 20).stages
            == ((1, 2), (2, 2), (4, 2), (8, 2)))
    assert (parse_schedule("custom:1x2,4x3").stages
            == custom_stages([(1, 2), (4, 3)]).stages)
    with pytest.raises(ValueError, match="comm-schedule"):
        parse_schedule("bogus", 4)
    with pytest.raises(ValueError, match="at least one stage"):
        CommSchedule(stages=())
