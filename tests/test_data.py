"""Data pipeline tests: partitioners, skew metric, loader determinism."""
import numpy as np

from repro.data import (
    WorkerLoader,
    class_shard_partition,
    dirichlet_partition,
    gaussian_classification,
    iid_partition,
    label_skew,
    lm_token_stream,
)


def test_class_shard_partition_disjoint_classes():
    data = gaussian_classification(n=2000, num_classes=10, seed=0)
    parts = class_shard_partition(data.y, 5, seed=0)
    assert sum(len(p) for p in parts) == 2000
    class_sets = [set(np.unique(data.y[p])) for p in parts]
    for i in range(5):
        for j in range(i + 1, 5):
            assert not (class_sets[i] & class_sets[j])


def test_skew_ordering():
    """class-shard >> dirichlet(0.1) > iid in label skew."""
    data = gaussian_classification(n=4000, num_classes=10, seed=1)
    s_cs = label_skew(data.y, class_shard_partition(data.y, 5, seed=0))
    s_dir = label_skew(data.y, dirichlet_partition(data.y, 5, 0.3, seed=0))
    s_iid = label_skew(data.y, iid_partition(len(data.y), 5, seed=0))
    assert s_cs > s_dir > s_iid
    assert s_cs > 0.7 and s_iid < 0.1


def test_loader_determinism_and_shapes():
    data = gaussian_classification(n=1000, num_classes=10, seed=2)
    l1 = iter(WorkerLoader(data, 4, 8, seed=7))
    l2 = iter(WorkerLoader(data, 4, 8, seed=7))
    for _ in range(3):
        x1, y1 = next(l1)
        x2, y2 = next(l2)
        assert x1.shape == (4, 8, 64) and y1.shape == (4, 8)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)


def test_loader_worker_sees_only_its_classes():
    data = gaussian_classification(n=2000, num_classes=10, seed=3)
    loader = WorkerLoader(data, 5, 16, partition="class_shard", seed=0)
    allowed = [set(np.unique(data.y[p])) for p in loader.parts]
    it = iter(loader)
    for _ in range(5):
        _, ys = next(it)
        for w in range(5):
            assert set(np.unique(ys[w])) <= allowed[w]


def test_lm_token_stream_noniid_vs_iid():
    s_non = lm_token_stream(4, 32, 64, steps=2, batch=4, alpha=0.05, seed=0)
    s_iid = lm_token_stream(4, 32, 64, steps=2, batch=4, identical=True, seed=0)
    assert s_non.shape == (2, 4, 4, 32)
    # per-worker unigram dists should differ strongly in the non-iid case
    def worker_hists(s):
        return [np.bincount(s[:, w].ravel(), minlength=64) / s[:, w].size
                for w in range(4)]
    h_non = worker_hists(s_non)
    h_iid = worker_hists(s_iid)
    tv_non = max(0.5 * np.abs(h_non[i] - h_non[j]).sum()
                 for i in range(4) for j in range(i + 1, 4))
    tv_iid = max(0.5 * np.abs(h_iid[i] - h_iid[j]).sum()
                 for i in range(4) for j in range(i + 1, 4))
    assert tv_non > 0.5 > tv_iid
