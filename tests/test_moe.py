"""MoE routing/dispatch unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import moe


def _cfg(**kw):
    base = registry.smoke_arch("phi3.5-moe-42b-a6.6b")
    import dataclasses
    return dataclasses.replace(base, **kw)


def test_router_weights_normalized():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, cfg.d_model))
    router = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.d_model, cfg.num_experts)) * 0.1
    w, ids, aux = moe.route(cfg, router, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert ids.shape == (64, cfg.experts_per_token)
    assert bool((ids < cfg.num_experts).all())


def test_moe_capacity_drops_only_overflow():
    """With capacity_factor high enough nothing is dropped: the MoE output
    must equal a dense per-token expert evaluation."""
    cfg = _cfg(capacity_factor=8.0, num_shared_experts=0)
    key = jax.random.PRNGKey(0)
    params = {
        "router": jax.random.normal(key, (cfg.d_model, cfg.num_experts)) * 0.1,
        "w_gate": jax.random.normal(jax.random.PRNGKey(1),
                                    (cfg.num_experts, cfg.d_model, cfg.moe_d_ff)) * 0.05,
        "w_up": jax.random.normal(jax.random.PRNGKey(2),
                                  (cfg.num_experts, cfg.d_model, cfg.moe_d_ff)) * 0.05,
        "w_down": jax.random.normal(jax.random.PRNGKey(3),
                                    (cfg.num_experts, cfg.moe_d_ff, cfg.d_model)) * 0.05,
    }
    x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.d_model))
    y, aux = moe.moe_mlp(cfg, params, x)

    # dense reference: evaluate every expert on every token, combine top-k
    w, ids, _ = moe.route(cfg, params["router"], x)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", x, params["w_up"])
    all_out = jnp.einsum("tef,efd->ted", h, params["w_down"])
    ref = jnp.zeros_like(x)
    for j in range(cfg.experts_per_token):
        ref = ref + w[:, j:j + 1] * jnp.take_along_axis(
            all_out, ids[:, j][:, None, None], axis=1)[:, 0]
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


def test_moe_capacity_one_drops_tokens():
    cfg = _cfg(capacity_factor=0.01, num_shared_experts=0)
    params_key = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {
        "router": jax.random.normal(params_key[0], (cfg.d_model, cfg.num_experts)) * 0.1,
        "w_gate": jax.random.normal(params_key[1], (cfg.num_experts, cfg.d_model, cfg.moe_d_ff)) * 0.05,
        "w_up": jax.random.normal(params_key[2], (cfg.num_experts, cfg.d_model, cfg.moe_d_ff)) * 0.05,
        "w_down": jax.random.normal(params_key[3], (cfg.num_experts, cfg.moe_d_ff, cfg.d_model)) * 0.05,
    }
    x = jax.random.normal(jax.random.PRNGKey(4), (256, cfg.d_model))
    y, _ = moe.moe_mlp(cfg, params, x)
    assert bool(jnp.isfinite(y).all())
    # some tokens must have been dropped to zero contribution
    norms = jnp.linalg.norm(y, axis=-1)
    assert float((norms < 1e-9).mean()) > 0.1


def test_aux_loss_uniform_router_is_one():
    """Switch LB loss == 1.0 for a perfectly uniform router."""
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(0), (512, cfg.d_model))
    router = jnp.zeros((cfg.d_model, cfg.num_experts))
    # zero logits -> uniform probs; top-1 ties broken deterministically, so
    # f_e collapses — perturb tiny bit for realistic tie-breaking
    router = router + 1e-6 * jax.random.normal(jax.random.PRNGKey(1),
                                               router.shape)
    _, _, aux = moe.route(cfg, router, x)
    assert 0.5 < float(aux) < 2.5
