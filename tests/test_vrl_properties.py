"""Property-based tests (hypothesis) for the VRL-SGD invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import VRLConfig
from repro.core import get_algorithm


def _run_random(seed, n_workers, k, lr, steps, dim=3):
    """Drive VRL-SGD with arbitrary random gradient sequences."""
    rng = np.random.RandomState(seed)
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=k, learning_rate=lr,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"w": jnp.zeros((dim,))}, n_workers)
    xhat_manual = np.zeros(dim, np.float64)
    for _ in range(steps):
        g = rng.randn(n_workers, dim).astype(np.float32)
        xhat_manual -= lr * g.mean(axis=0)
        state = alg.train_step(cfg, state, {"w": jnp.asarray(g)})
    return alg, state, xhat_manual


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_workers=st.integers(2, 6),
       k=st.integers(1, 7), lr=st.floats(1e-3, 0.5))
def test_delta_sum_zero_invariant(seed, n_workers, k, lr):
    """Σ_i Δ_i = 0 holds for ANY gradient sequence (paper §4.1)."""
    steps = k * 3
    _, state, _ = _run_random(seed, n_workers, k, lr, steps)
    total = np.asarray(jnp.sum(state.delta["w"], axis=0))
    np.testing.assert_allclose(total, 0.0, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_workers=st.integers(2, 5),
       k=st.integers(1, 6), lr=st.floats(1e-3, 0.3),
       steps=st.integers(1, 20))
def test_average_model_is_exact_sgd(seed, n_workers, k, lr, steps):
    """eq. (8): the worker-average follows plain SGD on mean gradients,
    for any step count (mid-period included)."""
    alg, state, xhat_manual = _run_random(seed, n_workers, k, lr, steps)
    xhat = np.asarray(alg.average_model(state)["w"])
    np.testing.assert_allclose(xhat, xhat_manual, rtol=2e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 6))
def test_params_equal_after_sync(seed, k):
    """All workers hold x̂ right after a sync."""
    alg, state, _ = _run_random(seed, 4, k, 0.05, k * 2)
    w = np.asarray(state.params["w"])
    np.testing.assert_allclose(w, np.broadcast_to(w[:1], w.shape), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_k1_trajectory_matches_ssgd(seed):
    rng = np.random.RandomState(seed)
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=1, learning_rate=0.1,
                    weight_decay=0.0, warmup=False)
    a1, a2 = get_algorithm("vrl_sgd"), get_algorithm("ssgd")
    s1 = a1.init(cfg, {"w": jnp.zeros((2,))}, 3)
    s2 = a2.init(cfg, {"w": jnp.zeros((2,))}, 3)
    for _ in range(10):
        g = jnp.asarray(rng.randn(3, 2).astype(np.float32))
        s1 = a1.train_step(cfg, s1, {"w": g})
        s2 = a2.train_step(cfg, s2, {"w": g})
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), rtol=1e-4,
                               atol=1e-5)
