"""The engine's headline systems property, verified on compiled HLO:

On a multi-device mesh the fused engine's SYNC step contains EXACTLY ONE
all-reduce — over the flat (R, C) buffer, not one per parameter leaf — its
LOCAL step contains none, and a whole ROUND (k scanned local steps + sync,
one compilation unit) still contains exactly one, on both the Pallas and
xla executors.  This is the communication event the paper's
O(T^{1/2}N^{3/2}) complexity counts, now visible in the compiled program.

The per-algorithm sweep derives its list from the ``ALGO_SPECS`` registry
(NOT a hard-coded name list), so every new spec is covered automatically —
including the expected counts (S-SGD's all-reduce lives in its local step;
its "sync" is a no-op).  A stagewise schedule additionally lowers the
round at EVERY stage k and each must still show exactly one sync
all-reduce.

Runs in a subprocess because the 8-device placeholder env must be set
before jax initializes (the test process already owns a 1-device jax).
"""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import VRLConfig
    from repro.core import get_algorithm, make_engine

    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="fused")
    eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}
    state = eng.init(p0, 8)

    def shard(x):
        nd = getattr(x, "ndim", 0)
        spec = P("data", None, None) if nd == 3 else P(*([None] * nd))
        return jax.device_put(x, NamedSharding(mesh, spec))

    state = jax.tree.map(shard, state)

    def grads(params, t):
        return jax.tree.map(lambda x: jnp.sin(3.0 * x + t) + 0.1 * x, params)

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    out = {}
    hlo_sync = jax.jit(eng.sync).lower(state).compile().as_text()
    out["sync_all_reduce"] = count_ar(hlo_sync)

    local = lambda s, t: eng.local_step(s, grads(eng.params_tree(s), t))
    hlo_local = jax.jit(local).lower(state, jnp.float32(0)
                                     ).compile().as_text()
    out["local_all_reduce"] = count_ar(hlo_local)

    # the round: k scanned local steps + sync — still exactly ONE sync
    # all-reduce per k steps in the compiled HLO, on both engine executors
    gk = jax.tree.map(lambda x: jnp.stack([jnp.sin(3.0 * x + t) + 0.1 * x
                                           for t in range(4)]),
                      eng.params_tree(state))
    hlo_round = jax.jit(eng.round_step, donate_argnums=(0,)
                        ).lower(state, gk).compile().as_text()
    out["round_all_reduce"] = count_ar(hlo_round)
    import dataclasses
    eng_x = make_engine(dataclasses.replace(cfg, update_backend="xla"),
                        template, mesh=mesh, worker_axes=("data",))
    hlo_round_x = jax.jit(eng_x.round_step, donate_argnums=(0,)
                          ).lower(state, gk).compile().as_text()
    out["round_all_reduce_xla"] = count_ar(hlo_round_x)

    # every flat algorithm in the registry (derived, not hard-coded): the
    # sync is exactly one flat all-reduce (none for sync="none" — S-SGD
    # carries its all-reduce in the local step instead), locals otherwise
    # communication-free.  New AlgoSpecs are covered automatically.
    from repro.core.engine import ALGO_SPECS, flat_algorithms
    per_alg = {}
    for name in flat_algorithms():
        spec = ALGO_SPECS[name]
        c = dataclasses.replace(cfg, algorithm=name)
        e = make_engine(c, template, mesh=mesh, worker_axes=("data",))
        st = jax.tree.map(shard, e.init(p0, 8))
        hlo_s = jax.jit(e.sync).lower(st).compile().as_text()
        loc = lambda s, t: e.local_step(s, grads(e.params_tree(s), t))
        hlo_l = jax.jit(loc).lower(st, jnp.float32(0)).compile().as_text()
        per_alg[name] = {
            "sync": count_ar(hlo_s),
            "sync_expect": 0 if spec.sync == "none" else 1,
            "local": count_ar(hlo_l),
            "local_expect": 1 if spec.grad_all_reduce else 0,
        }
    out["per_alg"] = per_alg

    # stagewise schedule: the compiled round still shows exactly ONE sync
    # all-reduce at EVERY stage k
    from repro.core.schedule import custom_stages
    sch = custom_stages([(1, 1), (2, 1), (4, 1)])
    c = dataclasses.replace(cfg, algorithm="stl_sgd", comm_schedule=sch)
    e = make_engine(c, template, mesh=mesh, worker_axes=("data",))
    st = jax.tree.map(shard, e.init(p0, 8))
    stage_ar = {}
    for k in sch.distinct_periods():
        gk = jax.tree.map(
            lambda x: jnp.stack([jnp.sin(3.0 * x + t) + 0.1 * x
                                 for t in range(k)]), e.params_tree(st))
        hlo_r = jax.jit(e.round_step, donate_argnums=(0,)
                        ).lower(st, gk).compile().as_text()
        stage_ar[str(k)] = count_ar(hlo_r)
    out["stage_round_ar"] = stage_ar

    # compressed sync (repro.comm): compression changes the payload math,
    # not the collective count — the round (and the sync alone) still
    # lower to exactly ONE all-reduce (of the decompressed drift)
    from repro.comm import compressors as cc_mod
    comp_ar = {}
    for comp_name in ("int8", "topk"):
        c = dataclasses.replace(cfg,
                                compress=cc_mod.parse_compressor(comp_name))
        e = make_engine(c, template, mesh=mesh, worker_axes=("data",))
        st = jax.tree.map(shard, e.init(p0, 8))
        hlo_s = jax.jit(e.sync).lower(st).compile().as_text()
        gk = jax.tree.map(lambda x: jnp.stack(
            [jnp.sin(3.0 * x + t) + 0.1 * x for t in range(4)]),
            e.params_tree(st))
        hlo_r = jax.jit(e.round_step, donate_argnums=(0,)
                        ).lower(st, gk).compile().as_text()
        comp_ar[comp_name] = {"sync": count_ar(hlo_s),
                              "round": count_ar(hlo_r)}
    out["compressed_ar"] = comp_ar

    # numerics on the sharded mesh match the single-device reference
    step = jax.jit(lambda s, t: eng.train_step(
        s, grads(eng.params_tree(s), t)))
    alg = get_algorithm("vrl_sgd")
    sref = alg.init(cfg, p0, 8)
    rstep = jax.jit(lambda s, t: alg.train_step(cfg, s, grads(s.params, t)))
    for t in range(9):
        state = step(state, jnp.float32(t))
        sref = rstep(sref, jnp.float32(t))
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(eng.params_tree(state)),
                  jax.tree.leaves(sref.params)))
    out["mesh_vs_reference_err"] = err
    print(json.dumps(out))
""")


SCRIPT_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import json
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat
    from repro.configs.base import EngineConfig, VRLConfig
    from repro.core import make_engine
    from repro.core.engine import state_partition_specs

    # (2 workers x 4 shards) mesh: every engine buffer's row dim splits
    # over "shard", workers over "data" — the round-closing sync must STAY
    # exactly one all-reduce (per-shard, worker axis only)
    mesh = jax.make_mesh((2, 4), ("data", "shard"), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, update_backend="fused",
                    inner_optimizer="adam",
                    engine=EngineConfig(block=8, shards=4))
    eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}

    def place(e, st):
        specs = state_partition_specs(st, ("data",), shard_axis="shard",
                                      shards=4)
        return jax.device_put(st, compat.shardings(mesh, specs))

    state = place(eng, eng.init(p0, 2))
    out = {}
    # the params buffer really is row-sharded: each device holds 1/4 of
    # the rows for its single worker
    w, r, c = state.params.shape
    out["shard_shape"] = list(
        state.params.sharding.shard_shape(state.params.shape))
    out["expect_shard_shape"] = [1, r // 4, c]

    def grads(params, t):
        return jax.tree.map(lambda x: jnp.sin(3.0 * x + t) + 0.1 * x, params)

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    hlo_sync = jax.jit(eng.sync).lower(state).compile().as_text()
    out["sync_all_reduce"] = count_ar(hlo_sync)
    # HLO counts go over the layout-native hot path (pre-flattened,
    # shard-placed grads buffer, as the round benchmark drives it):
    # pytree grads would be unflattened/reflattened across the sharded
    # row dim inside jit, and the SPMD partitioner's resharding of that
    # reshape emits masked all-reduces that are artifacts of the test
    # harness, not engine communication
    gk_buf = jax.device_put(
        jnp.sin(0.01 * jnp.arange(4 * w * r * c, dtype=jnp.float32)
                ).reshape(4, w, r, c),
        NamedSharding(mesh, P(None, "data", "shard", None)))
    hlo_round = jax.jit(eng.round_step_flat, donate_argnums=(0,)
                        ).lower(state, gk_buf).compile().as_text()
    out["round_all_reduce"] = count_ar(hlo_round)
    # the local steps' contribution: the whole round minus the one sync
    out["local_all_reduce"] = out["round_all_reduce"] - out["sync_all_reduce"]

    # trajectory parity: the sharded-mesh run matches the meshless
    # unsharded engine (same config at shards=1; sharding is placement,
    # not math)
    eng0 = make_engine(dataclasses.replace(
        cfg, engine=EngineConfig(block=8, shards=1)), template)
    s0 = eng0.init(p0, 2)
    step = jax.jit(lambda s, t: eng.train_step(
        s, grads(eng.params_tree(s), t)))
    step0 = jax.jit(lambda s, t: eng0.train_step(
        s, grads(eng0.params_tree(s), t)))
    for t in range(9):
        state = step(state, jnp.float32(t))
        s0 = step0(s0, jnp.float32(t))
    out["mesh_vs_unsharded_err"] = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(eng.params_tree(state)),
            jax.tree.leaves(eng0.params_tree(s0))))

    # quantized + factored moments on the sharded mesh: bf16 momentum and
    # the SM3 (row, col) stats place cleanly (col's shard dim splits over
    # "shard"), the sync count holds, and the trajectory matches the
    # meshless xla twin at the SAME shard count (the SM3 cover depends on
    # shards, so like compares with like)
    cfg_q = dataclasses.replace(cfg, moment_dtype="bfloat16", sm3=True)
    eng_q = make_engine(cfg_q, template, mesh=mesh, worker_axes=("data",))
    sq = place(eng_q, eng_q.init(p0, 2))
    out["sm3_col_shard_shape"] = list(
        sq.inner.nu.col.sharding.shard_shape(sq.inner.nu.col.shape))
    hlo_sync_q = jax.jit(eng_q.sync).lower(sq).compile().as_text()
    out["sm3_sync_all_reduce"] = count_ar(hlo_sync_q)
    eng_qx = make_engine(dataclasses.replace(
        cfg_q, update_backend="xla"), template)
    sqx = eng_qx.init(p0, 2)
    stepq = jax.jit(lambda s, t: eng_q.train_step(
        s, grads(eng_q.params_tree(s), t)))
    stepqx = jax.jit(lambda s, t: eng_qx.train_step(
        s, grads(eng_qx.params_tree(s), t)))
    for t in range(9):
        sq = stepq(sq, jnp.float32(t))
        sqx = stepqx(sqx, jnp.float32(t))
    out["sm3_mesh_vs_xla_err"] = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(eng_q.params_tree(sq)),
            jax.tree.leaves(eng_qx.params_tree(sqx))))
    print(json.dumps(out))
""")


def test_fused_sync_is_one_flat_all_reduce():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # the communication event: one all-reduce over the flat buffer, total
    assert out["sync_all_reduce"] == 1, out
    # local steps stay communication-free on the worker axis
    assert out["local_all_reduce"] == 0, out
    # a whole round (k scanned local steps + sync) compiles to exactly ONE
    # sync collective per k steps, on both engine executors
    assert out["round_all_reduce"] == 1, out
    assert out["round_all_reduce_xla"] == 1, out
    # every registry algorithm matches its spec-derived collective counts
    for name, c in out["per_alg"].items():
        assert c["sync"] == c["sync_expect"], (name, c)
        assert c["local"] == c["local_expect"], (name, c)
    # the stagewise round is one sync all-reduce at EVERY stage k
    assert out["stage_round_ar"] == {"1": 1, "2": 1, "4": 1}, out
    # compression changes the payload, not the collective count: one sync
    # all-reduce per round with int8 AND topk on
    for comp_name, c in out["compressed_ar"].items():
        assert c == {"sync": 1, "round": 1}, (comp_name, c)
    # and the sharded trajectory matches the reference path (sum/N vs mean
    # rounding differs, so a slightly looser bound than the 1-device parity)
    assert out["mesh_vs_reference_err"] < 1e-5, out


def test_row_sharded_round_is_one_all_reduce():
    """Model-axis sharding of the engine buffers keeps the collective
    contract: on a (data=2, shard=4) mesh every (W, R, C) buffer's row dim
    splits over "shard", and the compiled round STILL shows exactly one
    sync all-reduce (a per-shard all-reduce over the worker axis only —
    1/shards of the bytes per device, same collective count).  The sharded
    trajectory is placement, not math: it matches the meshless unsharded
    engine, and the quantized variant (bf16 momentum + SM3 factored second
    moment) matches its meshless xla twin at the same shard count."""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT_SHARDED], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # the buffers really are row-sharded, 1/4 of the rows per device
    assert out["shard_shape"] == out["expect_shard_shape"], out
    assert out["sm3_col_shard_shape"] == [1, 1, 256], out
    # the headline property survives sharding: one all-reduce, total
    assert out["sync_all_reduce"] == 1, out
    assert out["local_all_reduce"] == 0, out
    assert out["round_all_reduce"] == 1, out
    assert out["sm3_sync_all_reduce"] == 1, out
    # sharding is placement-only: trajectories match the meshless runs
    assert out["mesh_vs_unsharded_err"] <= 1e-6, out
    assert out["sm3_mesh_vs_xla_err"] <= 1e-5, out


def test_shard_axis_size_mismatch_fails_loudly():
    """A config asking for shards=N against a mesh whose shard axis has a
    different (>1) size must refuse loudly, not silently half-shard.  A
    size-1 (or absent) axis instead degrades to layout-only padding — the
    single-device smoke path — and returns no placement axis."""
    import pytest

    from repro.configs.base import EngineConfig, MeshConfig
    from repro.sharding import specs as sh

    ecfg = EngineConfig(block=8, shards=4, shard_axis="shard")
    bad = MeshConfig(shape=(4, 2), axis_names=("data", "shard"),
                     worker_axes=("data",), tensor_axes=())
    with pytest.raises(ValueError, match="shard"):
        sh.engine_shard_axis(bad, ecfg)
    good = MeshConfig(shape=(2, 4), axis_names=("data", "shard"),
                      worker_axes=("data",), tensor_axes=())
    assert sh.engine_shard_axis(good, ecfg) == "shard"
    # absent axis: layout-only, no placement
    flat = MeshConfig(shape=(8,), axis_names=("data",),
                      worker_axes=("data",), tensor_axes=())
    assert sh.engine_shard_axis(flat, ecfg) is None
    assert sh.engine_shard_axis(good, EngineConfig(shards=1)) is None
