"""Overlapped rounds: the sync collective hidden behind the next round's
local steps (``VRLConfig.overlap``), with straggler-tolerant deadlines
(``VRLConfig.deadline``).

The contract under test:

* ``overlap=False`` is BITWISE the existing blocking round — the overlap
  machinery must be invisible when off (no extra state, no trace change).
* ``overlap=True`` matches a one-round-stale oracle exactly: the round-START
  collective averages the positions each participant transmitted at the
  PREVIOUS boundary, and the fold applies c_i = x̂_stale − pend_i to
  params/Δ (+B) with Δ scaled by the period pend actually covered
  (``pend_k``).  Σ_i c_i = 0, so the mean trajectory is preserved.
* Composition: stagewise schedules (variable k feeds ``pend_k``),
  compression (the capture rides the EF round-trip; a missed deadline
  parks the decompressed payload back in the residual), hierarchy
  (overlap applies to the cross-pod level-2 sync only; sync1 blocking).
* ``deadline=1.0`` degenerates to pure-local training (everyone always
  retransmits x0, so every correction is exactly zero); ``deadline=0.0``
  is bitwise the no-deadline overlap program (trace-time short-circuit).
* Systems: RoundCache still compiles one executable per distinct k, the
  round jit still donates EVERY state buffer (pend included — the stale-Δ
  double buffer must update in place), and on a multi-device mesh the
  overlapped round still lowers to exactly ONE sync all-reduce per k
  steps (the point: same communication, less exposed latency).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import compressors as cc
from repro.configs.base import HierConfig, VRLConfig
from repro.core import RoundCache, make_engine
from repro.core.schedule import custom_stages
from repro.core.types import CommState, OverlapState

W, K = 4, 4

TEMPLATE = {"w": jnp.zeros((8, 3)), "b": jnp.zeros((5,)),
            "deep": {"u": jnp.zeros((2, 2, 4))}}

LR = 0.05


def _params0():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return {"w": jax.random.normal(ks[0], (8, 3)),
            "b": jax.random.normal(ks[1], (5,)),
            "deep": {"u": jax.random.normal(ks[2], (2, 2, 4))}}


def _grads_t(p0, t, lead=(W,)):
    n = int(np.prod(lead))

    def one(x):
        phase = jnp.arange(n, dtype=x.dtype).reshape(lead + (1,) * x.ndim)
        big = jnp.broadcast_to(x, lead + x.shape)
        return jnp.sin(3.0 * big + 0.7 * t + phase) + 0.1 * x

    return jax.tree.map(one, p0)


def _stack(gs):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *gs)


def _cfg(alg, backend, k=K, **kw):
    kw.setdefault("overlap", True)
    return VRLConfig(algorithm=alg, comm_period=k, learning_rate=LR,
                     weight_decay=0.0, warmup=False, update_backend=backend,
                     **kw)


# ------------------------------------------------- the one-round-stale oracle
def _oracle_fold(state, xbar, pend, pend_k, k_eff, *, lr=LR, bvr_beta=0.0,
                 comp=None, resid=None):
    """The overlap fold in numpy: returns (state', pend', pend_k', resid').

    Locals already ran (via the engine's own verified ``local_step``); this
    implements ONLY the boundary math the overlapped round adds."""
    c = xbar[None] - pend
    params = np.asarray(state.params, np.float32) + c
    rep = {"params": jnp.asarray(params).astype(state.params.dtype)}
    inv = 1.0 / (pend_k * lr)
    if isinstance(state.delta, jax.Array):
        delta = np.asarray(state.delta, np.float32) + c * inv
        rep["delta"] = jnp.asarray(delta).astype(state.delta.dtype)
    if bvr_beta and isinstance(state.bias, jax.Array):
        bias = ((1.0 - bvr_beta) * np.asarray(state.bias, np.float32)
                + bvr_beta * c * inv)
        rep["bias"] = jnp.asarray(bias).astype(state.bias.dtype)
    if comp is None:
        new_pend, new_resid = params.copy(), None
    else:
        payload = params - xbar[None] + (resid if resid is not None else 0.0)
        dec, e_out = (np.asarray(a) for a in
                      cc.ef_roundtrip(comp, jnp.asarray(payload,
                                                        jnp.float32)))
        new_pend = xbar[None] + dec
        new_resid = e_out if comp.error_feedback else None
        rep["comm"] = CommState(resid=jnp.asarray(new_resid),
                                ref=jnp.asarray(xbar))
    new_pend_k = np.full_like(pend_k, float(k_eff))
    state = state._replace(
        overlap=OverlapState(pend=jnp.asarray(new_pend, jnp.float32),
                             pend_k=jnp.asarray(new_pend_k, jnp.float32)),
        last_sync=state.step, **rep)
    return state, new_pend, new_pend_k, new_resid


def _run_oracle(eng, p0, round_grads, *, bvr_beta=0.0, comp=None):
    """Drive the overlapped trajectory piecewise: engine local steps +
    numpy fold, starting from the engine's own init."""
    state = eng.init(p0, W)
    local = jax.jit(eng.local_step)
    pend = np.asarray(state.overlap.pend, np.float32)
    pend_k = np.asarray(state.overlap.pend_k, np.float32)
    resid = (np.asarray(state.comm.resid, np.float32)
             if comp is not None and comp.error_feedback else None)
    for gs in round_grads:
        xbar = pend.mean(0)
        for g in gs:
            state = local(state, g)
        k_eff = max(int(state.step) - int(state.last_sync), 1)
        state, pend, pend_k, resid = _oracle_fold(
            state, xbar, pend, pend_k, k_eff, bvr_beta=bvr_beta,
            comp=comp, resid=resid)
    return state


def _assert_state_close(s_eng, s_ora, fields=("params", "delta"),
                        atol=1e-5):
    for name in fields:
        a, b = getattr(s_eng, name), getattr(s_ora, name)
        if not isinstance(a, jax.Array):
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=atol, err_msg=name)
    np.testing.assert_allclose(np.asarray(s_eng.overlap.pend),
                               np.asarray(s_ora.overlap.pend),
                               atol=atol, err_msg="pend")
    np.testing.assert_array_equal(np.asarray(s_eng.overlap.pend_k),
                                  np.asarray(s_ora.overlap.pend_k))


# --------------------------------------------------------------- off = bitwise
@pytest.mark.parametrize("backend", ["xla", "fused"])
def test_overlap_off_is_bitwise_blocking(backend):
    """overlap=False must be the EXISTING blocking round, bit for bit:
    same state layout (no pend buffers), same compiled trajectory."""
    cfg_def = VRLConfig(algorithm="vrl_sgd", comm_period=K,
                        learning_rate=LR, weight_decay=0.0, warmup=False,
                        update_backend=backend)
    cfg_off = dataclasses.replace(cfg_def, overlap=False, deadline=0.0)
    p0 = _params0()
    states = []
    for cfg in (cfg_def, cfg_off):
        eng = make_engine(cfg, TEMPLATE)
        assert eng.round_begin is None and eng.round_fold is None
        s = eng.init(p0, W)
        assert s.overlap == ()
        rstep = jax.jit(eng.round_step)
        for r in range(2):
            s = rstep(s, _stack([_grads_t(p0, r * K + i)
                                 for i in range(K)]))
        states.append(s)
    np.testing.assert_array_equal(np.asarray(states[0].params),
                                  np.asarray(states[1].params))
    np.testing.assert_array_equal(np.asarray(states[0].delta),
                                  np.asarray(states[1].delta))


# ------------------------------------------------------------- oracle parity
@pytest.mark.parametrize("backend", ["xla", "fused"])
@pytest.mark.parametrize("alg", ["vrl_sgd", "local_sgd", "bvr_l_sgd"])
def test_overlap_matches_stale_oracle(alg, backend):
    """3 overlapped rounds == the one-round-stale oracle (engine local
    steps + the fold math in numpy) for a Δ algorithm, an averaging-only
    sync, and the EMA bias variate — on both engine executors."""
    beta = 0.25 if alg == "bvr_l_sgd" else 0.0
    kw = {"bvr_beta": beta} if beta else {}
    cfg = _cfg(alg, backend, **kw)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    grads = [[_grads_t(p0, r * K + i) for i in range(K)] for r in range(3)]

    s_eng = eng.init(p0, W)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for gs in grads:
        s_eng = rstep(s_eng, _stack(gs))
    s_ora = _run_oracle(eng, p0, grads, bvr_beta=beta)
    _assert_state_close(s_eng, s_ora, fields=("params", "delta", "bias"))
    assert int(s_eng.last_sync) == int(s_ora.last_sync) == 3 * K


def test_overlap_stagewise_schedule():
    """Variable-k rounds (stagewise CommSchedule through the RoundCache)
    still match the oracle: pend_k must carry each round's OWN length into
    the next fold's Δ scale."""
    sched = custom_stages([(1, 2), (2, 2), (4, 2)])
    cfg = _cfg("vrl_sgd", "xla", comm_schedule=sched)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    t_total = sched.total_steps()
    gs = [_grads_t(p0, t) for t in range(t_total)]

    s_eng = eng.init(p0, W)
    rcache = RoundCache(eng.round_step)
    t = 0
    rounds = []
    for k in sched.round_sizes(t_total):
        s_eng = rcache(s_eng, _stack(gs[t:t + k]))
        rounds.append(gs[t:t + k])
        t += k
    s_ora = _run_oracle(eng, p0, rounds)
    _assert_state_close(s_eng, s_ora)
    assert float(s_eng.overlap.pend_k[0, 0, 0]) == 4.0   # the last stage's k


def test_overlap_compressed_capture_matches_oracle():
    """int8+EF composition: the captured pend is the TRANSMITTED position
    (x̂_stale + dec), the quantization shortfall stays in the residual, and
    ref re-anchors to the stale mean — all against the numpy oracle built
    on ``comm.compressors.ef_roundtrip``."""
    comp = cc.parse_compressor("int8")
    cfg = _cfg("vrl_sgd", "xla", compress=comp)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    grads = [[_grads_t(p0, r * K + i) for i in range(K)] for r in range(3)]

    s_eng = eng.init(p0, W)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for gs in grads:
        s_eng = rstep(s_eng, _stack(gs))
    s_ora = _run_oracle(eng, p0, grads, comp=cc.resolve(comp))
    _assert_state_close(s_eng, s_ora)
    np.testing.assert_allclose(np.asarray(s_eng.comm.resid),
                               np.asarray(s_ora.comm.resid), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_eng.comm.ref),
                               np.asarray(s_ora.comm.ref), atol=1e-5)


# ------------------------------------------------------------------ deadlines
def test_deadline_all_miss_is_pure_local():
    """deadline=1.0: nobody ever captures, so every participant keeps
    transmitting x0 — all corrections are exactly zero (Δ stays 0, pend
    stays the init broadcast), pend_k stretches by k per round, and the
    params follow the pure-local trajectory bit for bit."""
    cfg = _cfg("vrl_sgd", "xla", deadline=1.0)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    rounds = 3
    s = eng.init(p0, W)
    pend0 = np.asarray(s.overlap.pend).copy()
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(rounds):
        s = rstep(s, _stack([_grads_t(p0, r * K + i) for i in range(K)]))
    assert not np.asarray(s.delta).any()
    np.testing.assert_array_equal(np.asarray(s.overlap.pend), pend0)
    np.testing.assert_array_equal(np.asarray(s.overlap.pend_k),
                                  np.full((W, 1, 1), 1.0 + rounds * K,
                                          np.float32))
    s_loc = eng.init(p0, W)
    local = jax.jit(eng.local_step)
    for t in range(rounds * K):
        s_loc = local(s_loc, _grads_t(p0, t))
    np.testing.assert_array_equal(np.asarray(s.params),
                                  np.asarray(s_loc.params))


def test_deadline_zero_is_bitwise_no_deadline():
    """deadline=0.0 short-circuits at trace time: the program is bitwise
    the plain overlap program (no PRNG, no mask arithmetic)."""
    p0 = _params0()
    outs = []
    for dl in (0.0, None):
        kw = {} if dl is None else {"deadline": dl}
        eng = make_engine(_cfg("vrl_sgd", "xla", **kw), TEMPLATE)
        s = eng.init(p0, W)
        rstep = jax.jit(eng.round_step)
        for r in range(2):
            s = rstep(s, _stack([_grads_t(p0, r * K + i)
                                 for i in range(K)]))
        outs.append(s)
    for name in ("params", "delta"):
        np.testing.assert_array_equal(np.asarray(getattr(outs[0], name)),
                                      np.asarray(getattr(outs[1], name)))
    np.testing.assert_array_equal(np.asarray(outs[0].overlap.pend),
                                  np.asarray(outs[1].overlap.pend))


# ------------------------------------------------------------------ hierarchy
def test_overlap_hier_matches_stale_oracle():
    """Hierarchical overlap: sync1 stays blocking, ONLY the cross-pod
    level-2 sync overlaps.  4 rounds at (k1, k2) = (2, 4) cross two k2
    boundaries; the engine round must match the piecewise oracle (engine
    locals + engine sync1 + the level-2 fold in numpy)."""
    grid = (2, 3)
    cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=LR,
                    weight_decay=0.0, update_backend="xla", overlap=True,
                    hier=HierConfig(k1=2, k2=4, grid=grid))
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    s_eng, s_ora = eng.init(p0, 6), eng.init(p0, 6)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    local, sync1 = jax.jit(eng.local_step), jax.jit(eng.sync1)
    pend = np.asarray(s_ora.overlap.pend, np.float32)    # (P, 1, R, C)
    pend_k = np.asarray(s_ora.overlap.pend_k, np.float32)
    for r in range(4):
        gs = [_grads_t(p0, 2 * r + i, lead=grid) for i in range(2)]
        s_eng = rstep(s_eng, _stack(gs))
        # oracle: level-2 collective at round START (iff the round's end
        # lands on the k2 cadence), locals, blocking sync1, stale fold
        do2 = (int(s_ora.step) + 2 - int(s_ora.last_sync2)) >= 4
        glob = pend.mean(axis=0)[0] if do2 else None
        for g in gs:
            s_ora = local(s_ora, g)
        s_ora = sync1(s_ora)
        if do2:
            k_eff = max(int(s_ora.step) - int(s_ora.last_sync2), 1)
            c = glob[None, None] - pend                  # (P, 1, R, C)
            params = np.asarray(s_ora.params, np.float32) + c
            delta2 = (np.asarray(s_ora.delta2, np.float32)
                      + c / (pend_k * LR))
            pend = params[:, :1].copy()
            pend_k = np.full_like(pend_k, float(k_eff))
            s_ora = s_ora._replace(
                params=jnp.asarray(params).astype(s_ora.params.dtype),
                delta2=jnp.asarray(delta2).astype(s_ora.delta2.dtype),
                overlap=OverlapState(jnp.asarray(pend, jnp.float32),
                                     jnp.asarray(pend_k, jnp.float32)),
                last_sync2=s_ora.step)
    for name in ("params", "delta1", "delta2"):
        np.testing.assert_allclose(np.asarray(getattr(s_eng, name)),
                                   np.asarray(getattr(s_ora, name)),
                                   atol=1e-5, err_msg=name)
    np.testing.assert_allclose(np.asarray(s_eng.overlap.pend),
                               np.asarray(s_ora.overlap.pend), atol=1e-5)
    assert int(s_eng.last_sync2) == int(s_ora.last_sync2) == 8


# ------------------------------------------------------------ systems checks
def test_round_cache_one_executable_per_k_under_overlap():
    """The overlap round keys on k exactly like the blocking one: the
    cache retraces once per distinct k and never on re-feeds."""
    cfg = _cfg("vrl_sgd", "xla")
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    state = eng.init(p0, W)
    rcache = RoundCache(eng.round_step)
    for k in (2, 3, 2, 3, 2):
        state = rcache(state, _stack([_grads_t(p0, i) for i in range(k)]))
    assert rcache.compiles == 2
    assert rcache.cached_ks == (2, 3)


def test_overlap_round_donates_all_state_buffers():
    """The round jit aliases EVERY state array to an output — including
    the pend double buffer (the stale-Δ state must update in place, not
    copy: that buffer is param-sized x W)."""
    cfg = _cfg("vrl_sgd", "xla")
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), W)
    gk = _stack([_grads_t(_params0(), i) for i in range(K)])
    hlo = jax.jit(eng.round_step, donate_argnums=(0,)
                  ).lower(state, gk).compile().as_text()
    n_state_arrays = len(jax.tree.leaves(state))  # p, Δ, step, last, pend(2)
    assert n_state_arrays == 6
    assert "input_output_alias" in hlo
    assert hlo.count("may-alias") + hlo.count("must-alias") >= n_state_arrays


def test_overlap_validation():
    """Config combinations with no defined overlap semantics refuse at
    engine construction, and the reference backend refuses in the train
    loop (it has no double-buffered flat state to overlap)."""
    with pytest.raises(ValueError, match="overlap"):
        make_engine(_cfg("ssgd", "xla"), TEMPLATE)        # sync="none"
    with pytest.raises(ValueError, match="overlap"):
        make_engine(_cfg("easgd", "xla"), TEMPLATE)       # sync="elastic"
    with pytest.raises(ValueError, match="deadline"):
        make_engine(_cfg("vrl_sgd", "xla", overlap=False, deadline=0.5),
                    TEMPLATE)
    with pytest.raises(ValueError, match="deadline"):
        make_engine(_cfg("vrl_sgd", "xla", deadline=1.5), TEMPLATE)
    with pytest.raises(ValueError, match="error.feedback|residual"):
        make_engine(_cfg("vrl_sgd", "xla", deadline=0.5,
                         compress=cc.parse_compressor("int8:noef")),
                    TEMPLATE)

    from repro.configs import registry
    from repro.train.train_loop import make_train_step
    mcfg = registry.smoke_arch("qwen2-0.5b", num_layers=1, d_model=32,
                               d_ff=64, vocab_size=32, num_heads=2,
                               num_kv_heads=1, head_dim=16)
    with pytest.raises(ValueError, match="flat-buffer"):
        make_train_step(mcfg, _cfg("vrl_sgd", "reference"), remat=False)


# --------------------------------------------- collective count on a real mesh
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import json
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import VRLConfig
    from repro.core import make_engine

    mesh = jax.make_mesh((8,), ("data",), devices=jax.devices())
    template = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((33,))}
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (33,))}
    base = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                     weight_decay=0.0, warmup=False, update_backend="fused",
                     overlap=True)

    def count_ar(hlo):
        return len(re.findall(r"all-reduce(?:-start)?\\(", hlo))

    def shard(x):
        nd = getattr(x, "ndim", 0)
        spec = P("data", None, None) if nd == 3 else P(*([None] * nd))
        return jax.device_put(x, NamedSharding(mesh, spec))

    def ar_depends_on_scan(hlo):
        \"\"\"True iff the entry computation's all-reduce (transitively)
        consumes the local-step scan while-loop's output.  Blocking rounds
        must (mean of post-scan positions); overlapped rounds must NOT —
        the collective's operands are previous-boundary state, the dataflow
        independence a latency-hiding scheduler needs to run it
        concurrently with the local steps.\"\"\"
        lines = hlo.splitlines()
        entry = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        defs, whiles, ar_ops = {}, set(), []
        for line in lines[entry:]:
            m = re.match(r"\\s*(?:ROOT\\s+)?%([\\w.-]+)\\s*=\\s*(.*)", line)
            if not m:
                continue
            name, rhs = m.groups()
            defs[name] = re.findall(r"%([\\w.-]+)", rhs)
            if "while(" in rhs:
                whiles.add(name)
            if re.search(r"all-reduce(?:-start)?\\(", rhs):
                ar_ops.extend(defs[name])
        seen, frontier = set(), list(ar_ops)
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            frontier.extend(defs.get(n, []))
        return bool(seen & whiles)

    out = {}
    for label, cfg in [
            ("fused", base),
            ("xla", dataclasses.replace(base, update_backend="xla")),
            ("deadline", dataclasses.replace(base, deadline=0.3)),
            ("blocking", dataclasses.replace(base, overlap=False))]:
        eng = make_engine(cfg, template, mesh=mesh, worker_axes=("data",))
        state = jax.tree.map(shard, eng.init(p0, 8))
        gk = jax.tree.map(lambda x: jnp.stack(
            [jnp.sin(3.0 * x + t) + 0.1 * x for t in range(4)]),
            eng.params_tree(state))
        hlo = jax.jit(eng.round_step, donate_argnums=(0,)
                      ).lower(state, gk).compile().as_text()
        out[label] = count_ar(hlo)
        out[label + "_ar_on_scan"] = ar_depends_on_scan(hlo)
    print(json.dumps(out))
""")


def test_overlap_round_is_one_all_reduce_on_mesh():
    """On an 8-device mesh the OVERLAPPED round still compiles to exactly
    ONE sync all-reduce per k steps — on both executors, with a deadline
    on (the miss mask is axis_index arithmetic, not communication), and
    unchanged for the blocking round it replaces.  Structurally, the
    overlapped program's all-reduce no longer DEPENDS on the local-step
    scan while-loop (its operands are previous-boundary state), which is
    the dataflow independence a latency-hiding scheduler needs to run the
    collective concurrently; the blocking round's all-reduce consumes the
    scan's output."""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    counts = {k: v for k, v in out.items() if not k.endswith("_ar_on_scan")}
    assert counts == {"fused": 1, "xla": 1, "deadline": 1, "blocking": 1}, out
    assert not (out["fused_ar_on_scan"] or out["xla_ar_on_scan"]
                or out["deadline_ar_on_scan"]), out
    assert out["blocking_ar_on_scan"], out
