"""Property-based tests (hypothesis) for the stagewise CommSchedule.

Own module (the ``test_vrl_properties.py`` pattern) so the module-level
``importorskip`` skips ONLY the randomized properties when hypothesis is
absent — the deterministic schedule tests in ``test_schedule.py`` always
run.
"""
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.schedule import (  # noqa: E402
    CommSchedule,
    stagewise_doubling,
    stagewise_total_steps,
)

stages_st = st.lists(
    st.tuples(st.integers(1, 16), st.integers(1, 5)),
    min_size=1, max_size=5).map(tuple)


@settings(max_examples=50, deadline=None)
@given(stages=stages_st)
def test_comm_schedule_boundaries_monotone(stages):
    """Sync steps are strictly increasing and every gap is a stage period."""
    sched = CommSchedule(stages=stages)
    t_total = sched.total_steps() + 3 * stages[-1][0]   # past the stages
    steps = sched.sync_steps(t_total)
    assert steps == sorted(set(steps))
    prev = 0
    for s in steps:
        assert s - prev == sched.period_starting_at(prev)
        prev = s


@settings(max_examples=50, deadline=None)
@given(stages=stages_st)
def test_comm_schedule_round_sizes_sum_to_t(stages):
    """Whole rounds over the schedule's own horizon T tile it exactly:
    total local steps sum to T, with per-stage round counts as declared."""
    sched = CommSchedule(stages=stages)
    t_total = sched.total_steps()
    sizes = sched.round_sizes(t_total)
    assert sum(sizes) == t_total
    # the round sequence is exactly the stage list, expanded
    expect = [k for k, r in stages for _ in range(r)]
    assert sizes == expect
    assert len(sched.distinct_periods(t_total)) <= len(stages)


@settings(max_examples=50, deadline=None)
@given(stages=stages_st,
       probe=st.lists(st.integers(0, 400), min_size=1, max_size=8))
def test_comm_schedule_traced_matches_python(stages, probe):
    """period_starting_at gives identical answers for python ints and
    traced jax ints — the per-step executors and the round drivers must
    agree on every boundary."""
    sched = CommSchedule(stages=stages)
    for t in probe:
        assert (int(sched.period_starting_at(jnp.int32(t)))
                == sched.period_starting_at(t))


@settings(max_examples=30, deadline=None)
@given(k0=st.integers(1, 8), rps=st.integers(1, 6), n=st.integers(1, 7))
def test_stagewise_doubling_matches_closed_form(k0, rps, n):
    """STL-SGD closed form: local steps after n full uncapped doubling
    stages = rps·k0·(2^n − 1), and the periods double stage to stage."""
    k_max = k0 * 2 ** (n - 1)           # exactly n uncapped stages
    sched = stagewise_doubling(k0=k0, k_max=k_max, rounds_per_stage=rps)
    assert len(sched.stages) == n
    assert sched.total_steps() == stagewise_total_steps(k0, rps, n)
    ks = sched.stage_ks
    assert all(b == 2 * a for a, b in zip(ks, ks[1:]))
