"""Gradient clipping substrate + the documented VRL x Adam incompatibility."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VRLConfig
from repro.core import get_algorithm
from repro.train.train_loop import clip_by_global_norm


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}  # norm = 10
    clipped = clip_by_global_norm(g, 5.0)
    norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(clipped))))
    np.testing.assert_allclose(norm, 5.0, rtol=1e-5)
    # under the threshold: untouched
    same = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)


def test_clipping_preserves_delta_invariant():
    """Δ is recovered from actual parameter motion (eq. 4), so clipping the
    gradients does not break Σ Δ_i = 0."""
    cfg = VRLConfig(algorithm="vrl_sgd", comm_period=4, learning_rate=0.05,
                    weight_decay=0.0, warmup=False, clip_norm=0.1)
    alg = get_algorithm("vrl_sgd")
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)
    rng = np.random.RandomState(0)
    for _ in range(12):
        g = jnp.asarray(rng.randn(2, 1).astype(np.float32)) * 10
        # emulate the train-loop's per-worker clipping
        g = jnp.stack([jnp.clip(g[i], -0.1, 0.1) for i in range(2)])
        state = alg.train_step(cfg, state, {"x": g})
    assert abs(float(jnp.sum(state.delta["x"]))) < 1e-5


def test_vrl_adam_incompatibility_documented():
    """Documented limitation (EXPERIMENTS.md): with an Adam inner step the Δ
    correction mis-cancels on STOCHASTIC non-iid tasks (eq. 4 calibrates Δ
    in raw-gradient units; Adam's preconditioning violates the telescoping).
    On the deterministic quadratic both converge — the breakage needs
    gradient noise, so this test uses the non-iid LM task: S-SGD+Adam must
    learn while VRL+Adam stalls."""
    from repro.configs import registry
    from repro.data import lm_token_stream
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=256, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    data = lm_token_stream(4, 64, 256, steps=40, batch=4, alpha=0.02, seed=0)
    finals = {}
    for alg_name in ["ssgd", "vrl_sgd"]:
        vrl = VRLConfig(algorithm=alg_name, comm_period=8,
                        learning_rate=1e-2, warmup=True,
                        inner_optimizer="adam", weight_decay=0.0)
        bundle = make_train_step(cfg, vrl, remat=False)
        state = bundle.init_state(jax.random.PRNGKey(0), 4)
        step = jax.jit(bundle.train_step)
        losses = []
        for t in range(40):
            toks = jnp.asarray(data[t])
            state, loss = step(state, toks, jnp.roll(toks, -1, -1))
            losses.append(float(loss))
        finals[alg_name] = np.mean(losses[-5:])
    assert finals["ssgd"] < finals["vrl_sgd"] - 0.5, finals


def test_chunked_ce_train_step_matches_plain():
    """chunked_ce path produces the same losses/updates as plain CE."""
    from repro.configs import registry
    from repro.data import lm_token_stream
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("gemma-7b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=100)  # non-multiple vocab
    data = lm_token_stream(2, 32, 100, steps=1, batch=2, seed=1)
    outs = {}
    for tag, ck in [("plain", 0), ("chunked", 16)]:
        vrl = VRLConfig(comm_period=2, learning_rate=0.1, warmup=False,
                        weight_decay=0.0)
        bundle = make_train_step(cfg, vrl, remat=False, chunked_ce=ck)
        state = bundle.init_state(jax.random.PRNGKey(0), 2)
        toks = jnp.asarray(data[0])
        state, loss = jax.jit(bundle.train_step)(
            state, toks, jnp.roll(toks, -1, -1))
        outs[tag] = (float(loss), state)
    # one step: identical loss and (up to fp accumulation order) updates;
    # multi-step trajectories diverge chaotically from fp-level grad diffs.
    np.testing.assert_allclose(outs["plain"][0], outs["chunked"][0],
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs["plain"][1].params),
                    jax.tree.leaves(outs["chunked"][1].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
