"""Integration: the paper's headline result on a real (small) LM.

Non-iid token streams across 4 workers, k=10: VRL-SGD must reach a lower
training loss than Local SGD in the same number of iterations, and track
S-SGD closely (paper Fig. 1). The identical case must show all algorithms
equivalent (Fig. 2).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import VRLConfig
from repro.data import lm_token_stream
from repro.train.train_loop import make_train_step

W, BATCH, SEQ, STEPS, K = 4, 8, 32, 150, 20


def _run(alg, data, lr=0.3):
    """Returns the AVERAGE MODEL x̂'s loss per step (the paper's metric —
    mean local loss would reward Local SGD for per-shard overfitting).
    Runs the DEFAULT backend ("auto" — the engine path), so the headline
    convergence result is asserted on the production executor."""
    from repro.models import transformer as T
    from repro.train.loss import cross_entropy_lm
    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm=alg, comm_period=K, learning_rate=lr,
                    weight_decay=0.0, warmup=False)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), W)
    step = jax.jit(bundle.train_step)

    @jax.jit
    def eval_avg(state, toks, labels):
        avg = bundle.average_model(state)
        logits, _ = T.forward(cfg, avg, toks.reshape(-1, SEQ))
        return cross_entropy_lm(logits, labels.reshape(-1, SEQ))

    losses = []
    for t in range(STEPS):
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, _ = step(state, toks, labels)
        losses.append(float(eval_avg(state, toks, labels)))
    return losses


@pytest.fixture(scope="module")
def noniid_data():
    return lm_token_stream(W, SEQ, 64, steps=STEPS, batch=BATCH,
                           alpha=0.02, seed=0)


@pytest.fixture(scope="module")
def iid_data():
    return lm_token_stream(W, SEQ, 64, steps=STEPS, batch=BATCH,
                           identical=True, seed=0)


def test_vrl_beats_local_sgd_noniid(noniid_data):
    l_vrl = _run("vrl_sgd", noniid_data, lr=0.2)
    l_loc = _run("local_sgd", noniid_data, lr=0.2)
    tail_vrl = np.mean(l_vrl[-10:])
    tail_loc = np.mean(l_loc[-10:])
    assert tail_vrl < tail_loc - 0.01, (tail_vrl, tail_loc)


def test_vrl_tracks_ssgd_noniid(noniid_data):
    """VRL-SGD's gap to S-SGD stays small even at k=20 (paper Fig. 1)."""
    l_vrl = _run("vrl_sgd", noniid_data, lr=0.2)
    l_ssgd = _run("ssgd", noniid_data, lr=0.2)
    assert abs(np.mean(l_vrl[-10:]) - np.mean(l_ssgd[-10:])) < 0.15


def test_identical_case_algorithms_match(iid_data):
    """Paper Fig. 2: identical data -> all algorithms converge alike
    (theory-compliant small k regime)."""
    global K
    old_k, K = K, 5
    try:
        tails = {a: np.mean(_run(a, iid_data, lr=0.15)[-10:])
                 for a in ["vrl_sgd", "local_sgd", "ssgd"]}
    finally:
        K = old_k
    vals = list(tails.values())
    assert max(vals) - min(vals) < 0.25, tails


def test_loss_decreases(noniid_data):
    l_vrl = _run("vrl_sgd", noniid_data, lr=0.2)
    assert np.mean(l_vrl[-5:]) < np.mean(l_vrl[:5]) - 0.3
