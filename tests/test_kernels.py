"""Pallas kernel allclose tests: shape/dtype sweeps against pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (
    flash_attention_ref,
    ssd_ref,
    vrl_sync_ref,
    vrl_update_ref,
)
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("bh,s,d", [(2, 256, 64), (4, 128, 128), (1, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(bh, s, d, dtype):
    key = jax.random.PRNGKey(bh * s + d)
    q, k, v = (jax.random.normal(kk, (bh, s, d)).astype(dtype)
               for kk in jax.random.split(key, 3))
    out = flash_attention(q, k, v, block_q=128 if s >= 128 else s,
                          block_k=128 if s >= 128 else s)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) < tol


@pytest.mark.parametrize("window", [None, 64, 128])
def test_flash_attention_window(window):
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (2, 256, 64))
               for kk in jax.random.split(key, 3))
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_attention_block_shape_independence():
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(kk, (2, 256, 64))
               for kk in jax.random.split(key, 3))
    o1 = flash_attention(q, k, v, block_q=64, block_k=128)
    o2 = flash_attention(q, k, v, block_q=128, block_k=64)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32), (1, 256, 1, 64, 128, 64)])
def test_ssd_scan_shapes(b, l, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(l + h), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, l, n)) * 0.3
    y = ops.ssd_chunk_scan(x, dt, a_log, bb, cc, chunk=chunk)
    yr = ssd_ref(x, dt, a_log, bb, cc)
    assert float(jnp.max(jnp.abs(y - yr))) < 5e-3


def test_ssd_scan_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, l, h, p, n = 2, 64, 2, 32, 16
    x = jax.random.normal(ks[0], (b, l, h, p)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.bfloat16)
    a_log = (jax.random.normal(ks[2], (h,)) * 0.5)
    bb = (jax.random.normal(ks[3], (b, l, n)) * 0.3).astype(jnp.bfloat16)
    cc = (jax.random.normal(ks[4], (b, l, n)) * 0.3).astype(jnp.bfloat16)
    y = ops.ssd_chunk_scan(x, dt, a_log, bb, cc, chunk=32)
    yr = ssd_ref(x.astype(jnp.float32), dt.astype(jnp.float32), a_log,
                 bb.astype(jnp.float32), cc.astype(jnp.float32))
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr))) < 0.15


def test_ssd_matches_model_chunked_path():
    """The Pallas kernel and the model's jnp chunked path agree."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, l, h, p, n = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, l, n)) * 0.3
    y1 = ops.ssd_chunk_scan(x, dt, a_log, bb, cc, chunk=32)
    y2 = ssd_chunked(x, dt, a_log, bb, cc, chunk=32)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4


@pytest.mark.parametrize("shape", [(64, 64), (1000,), (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vrl_local_update_tree(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    g = jax.random.normal(ks[1], shape).astype(dtype)
    d = jax.random.normal(ks[2], shape)
    out = ops.vrl_local_update_tree({"w": p}, {"w": g}, {"w": d}, lr=0.03)
    ref = vrl_update_ref(p, g, d, 0.03)
    assert float(jnp.max(jnp.abs(out["w"].astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < 2e-2


def test_vrl_sync_update_tree():
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    p = jax.random.normal(ks[0], (130, 7))
    xb = jax.random.normal(ks[1], (130, 7))
    d = jax.random.normal(ks[2], (130, 7))
    po, do = ops.vrl_sync_update_tree({"w": p}, {"w": xb}, {"w": d},
                                      k=10, lr=0.05)
    rp, rd = vrl_sync_ref(p, xb, d, 1.0 / (10 * 0.05))
    assert float(jnp.max(jnp.abs(po["w"] - rp))) < 1e-6
    assert float(jnp.max(jnp.abs(do["w"] - rd))) < 1e-5
