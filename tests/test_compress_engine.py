"""Compressed-sync subsystem through the engine (repro.comm + core/engine).

The contract, per executor matrix:

  * ``none`` (or topk at rate 1) is BITWISE the uncompressed path for
    every flat AlgoSpec on both flat-buffer executors — same kernels, no
    extra state buffers;
  * ``int8``/``topk`` with error feedback track the UNCOMPRESSED reference
    trajectory within a compression-scale tolerance (lossy by design, EF
    keeps the error bounded instead of accumulating);
  * the xla and fused executors agree BITWISE under compression (same
    formulas, fp32 in-register);
  * rounds and per-step driving sync through identical compressed math;
  * hierarchical syncs compress per level (``compress`` / ``compress2``)
    and S-SGD compresses its per-step gradient all-reduce;
  * compressed states checkpoint with their residual/ref buffers and a
    compressor mismatch on restore fails loudly (see also
    ``tests/test_checkpoint.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.comm import compressors as cc
from repro.configs.base import HierConfig, VRLConfig
from repro.core import (CommState, HierCommState, flat_algorithms,
                        get_algorithm, hierarchical as H, make_engine)

ALGORITHMS = list(flat_algorithms())
W, K, STEPS = 4, 4, 13

TEMPLATE = {"w": jnp.zeros((8, 3)), "b": jnp.zeros((5,)),
            "deep": {"u": jnp.zeros((2, 2, 4))}}


def _params0():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return {"w": jax.random.normal(ks[0], (8, 3)),
            "b": jax.random.normal(ks[1], (5,)),
            "deep": {"u": jax.random.normal(ks[2], (2, 2, 4))}}


def _grads(params, t):
    def one(x):
        w = x.shape[0]
        phase = jnp.arange(w, dtype=x.dtype).reshape((w,) + (1,) * (x.ndim - 1))
        return jnp.sin(3.0 * x + 0.7 * t + phase) + 0.1 * x
    return jax.tree.map(one, params)


def _hier_grads(params, t):
    def one(x):
        p, d = x.shape[:2]
        phase = jnp.arange(p * d, dtype=x.dtype).reshape(
            (p, d) + (1,) * (x.ndim - 2))
        return jnp.sin(3.0 * x + 0.7 * t + phase) + 0.1 * x
    return jax.tree.map(one, params)


def _cfg(alg, *, backend="xla", compress=None, compress2=None, k=K):
    return VRLConfig(algorithm=alg, comm_period=k, learning_rate=0.05,
                     weight_decay=1e-3, warmup=False,
                     update_backend=backend,
                     compress=compress, compress2=compress2)


def _run_engine(cfg, steps=STEPS, workers=W):
    eng = make_engine(cfg, TEMPLATE)
    s = eng.init(_params0(), workers)
    step = jax.jit(lambda s, t: eng.train_step(s, _grads(eng.params_tree(s),
                                                         t)))
    for t in range(steps):
        s = step(s, jnp.float32(t))
    return eng, s


def _max_err(tree_a, tree_b):
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)))


# ----------------------------------------------------- identity reductions
@pytest.mark.parametrize("backend", ["xla", "fused"])
@pytest.mark.parametrize("alg_name", ALGORITHMS)
def test_none_compressor_is_bitwise_uncompressed(alg_name, backend):
    """``none`` (and topk rate 1) resolve to the ORIGINAL path: bitwise
    identical params and NO comm buffers, for every flat AlgoSpec."""
    e0, s0 = _run_engine(_cfg(alg_name, backend=backend), steps=9)
    for comp in [cc.parse_compressor("none"), cc.parse_compressor("topk:1")]:
        e1, s1 = _run_engine(_cfg(alg_name, backend=backend, compress=comp),
                             steps=9)
        assert s1.comm == ()
        np.testing.assert_array_equal(np.asarray(s0.params),
                                      np.asarray(s1.params))


# ------------------------------------------------ lossy-compression bounds
@pytest.mark.parametrize("comp,tol", [("int8", 5e-3), ("topk:4", 0.12)])
@pytest.mark.parametrize("alg_name", ["vrl_sgd", "local_sgd", "bvr_l_sgd",
                                      "easgd"])
def test_compressed_tracks_uncompressed_reference(alg_name, comp, tol):
    """EF-compressed engine trajectories stay within a compression-scale
    tolerance of the UNCOMPRESSED per-leaf reference oracle."""
    cfg0 = _cfg(alg_name)
    alg = get_algorithm(alg_name)
    sref = alg.init(cfg0, _params0(), W)
    rstep = jax.jit(lambda s, t: alg.train_step(cfg0, s, _grads(s.params, t)))
    for t in range(STEPS):
        sref = rstep(sref, jnp.float32(t))
    _, s = _run_engine(_cfg(alg_name, compress=cc.parse_compressor(comp)))
    eng = make_engine(_cfg(alg_name, compress=cc.parse_compressor(comp)),
                      TEMPLATE)
    err = _max_err(eng.params_tree(s), sref.params)
    assert 0.0 < err < tol, err


@pytest.mark.parametrize("comp", ["int8", "topk:4"])
def test_compressed_xla_matches_fused_bitwise(comp):
    """The two flat-buffer executors run the same compression formulas in
    fp32 — trajectories agree bitwise."""
    spec = cc.parse_compressor(comp)
    _, sx = _run_engine(_cfg("vrl_sgd", backend="xla", compress=spec))
    _, sf = _run_engine(_cfg("vrl_sgd", backend="fused", compress=spec))
    np.testing.assert_array_equal(np.asarray(sx.params),
                                  np.asarray(sf.params))
    np.testing.assert_array_equal(np.asarray(sx.comm.resid),
                                  np.asarray(sf.comm.resid))
    np.testing.assert_array_equal(np.asarray(sx.comm.ref),
                                  np.asarray(sf.comm.ref))


def test_compressed_reference_executor_tracks_uncompressed():
    """The per-leaf reference executor supports compression too (row
    grouping is leaf-aligned there, so it is its own trajectory — compared
    against the uncompressed oracle, like the flat executors)."""
    cfg0 = _cfg("vrl_sgd")
    cfgc = dataclasses.replace(cfg0, compress=cc.parse_compressor("int8"))
    alg = get_algorithm("vrl_sgd")
    s0, sc = alg.init(cfg0, _params0(), W), alg.init(cfgc, _params0(), W)
    assert isinstance(sc.comm, CommState)
    step0 = jax.jit(lambda s, t: alg.train_step(cfg0, s, _grads(s.params, t)))
    stepc = jax.jit(lambda s, t: alg.train_step(cfgc, s, _grads(s.params, t)))
    for t in range(STEPS):
        s0 = step0(s0, jnp.float32(t))
        sc = stepc(sc, jnp.float32(t))
    err = _max_err(sc.params, s0.params)
    assert 0.0 < err < 5e-3, err


def test_error_feedback_beats_no_feedback():
    """Dropping error feedback (``:noef``) loses the carried correction:
    the EF trajectory must track the uncompressed oracle at least as well
    on the aggressive top-k compressor."""
    cfg0 = _cfg("vrl_sgd")
    e0, s0 = _run_engine(cfg0)
    _, s_ef = _run_engine(dataclasses.replace(
        cfg0, compress=cc.parse_compressor("topk:8")))
    _, s_no = _run_engine(dataclasses.replace(
        cfg0, compress=cc.parse_compressor("topk:8:noef")))
    err_ef = float(jnp.max(jnp.abs(s_ef.params - s0.params)))
    err_no = float(jnp.max(jnp.abs(s_no.params - s0.params)))
    assert err_ef < err_no, (err_ef, err_no)
    # and the noef state carries no residual buffer
    assert s_no.comm.resid == ()
    assert isinstance(s_ef.comm.resid, jax.Array)


def test_ssgd_gradient_compression():
    """S-SGD's communication is the per-step gradient all-reduce: it
    compresses with ref ≡ 0 and carries a per-step EF residual."""
    cfg0 = _cfg("ssgd")
    _, s0 = _run_engine(cfg0, steps=9)
    _, sc = _run_engine(dataclasses.replace(
        cfg0, compress=cc.parse_compressor("int8")), steps=9)
    assert sc.comm.ref == ()                 # gradient compression: no ref
    assert float(jnp.max(jnp.abs(sc.comm.resid))) > 0.0
    err = float(jnp.max(jnp.abs(sc.params - s0.params)))
    assert 0.0 < err < 5e-2, err


# --------------------------------------------------------- round execution
@pytest.mark.parametrize("backend", ["xla", "fused"])
def test_compressed_round_matches_per_step(backend):
    """One compressed round (k scanned locals + sync, one jit unit) lands
    exactly where k compressed per-step train_steps land."""
    cfg = _cfg("vrl_sgd", backend=backend,
               compress=cc.parse_compressor("int8"))
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    s_round = eng.init(p0, W)
    s_step = eng.init(p0, W)
    gk = jax.tree.map(
        lambda x: jnp.stack([_grads({"x": x}, t)["x"] for t in range(K)]),
        eng.params_tree(s_step))
    s_round = jax.jit(eng.round_step, donate_argnums=(0,))(s_round, gk)
    step = jax.jit(eng.train_step)
    for t in range(K):
        s_step = step(s_step, jax.tree.map(lambda g: g[t], gk))
    np.testing.assert_array_equal(np.asarray(s_round.params),
                                  np.asarray(s_step.params))
    np.testing.assert_array_equal(np.asarray(s_round.comm.resid),
                                  np.asarray(s_step.comm.resid))
    assert int(s_round.last_sync) == int(s_step.last_sync) == K


# ------------------------------------------------------------ hierarchical
def _hier_cfg(compress=None, compress2=None, backend="xla"):
    return VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                     weight_decay=1e-3, warmup=False,
                     update_backend=backend,
                     hier=HierConfig(k1=2, k2=4, grid=(2, 3)),
                     compress=compress, compress2=compress2)


def _run_hier(cfg, steps=STEPS):
    eng = make_engine(cfg, TEMPLATE)
    s = eng.init(_params0(), 6)
    step = jax.jit(lambda s, t: eng.train_step(
        s, _hier_grads(eng.params_tree(s), t)))
    for t in range(steps):
        s = step(s, jnp.float32(t))
    return eng, s


def test_hier_per_level_compressors_track_reference():
    """int8 intra-pod + harder topk cross-pod: per-level state buffers
    exist at their level's shape and the trajectory tracks the
    uncompressed hierarchical reference."""
    cfg0 = _hier_cfg()
    s0 = H.init(cfg0, _params0(), (2, 3))
    step0 = jax.jit(lambda s, t: H.train_step(cfg0, s,
                                              _hier_grads(s.params, t)))
    for t in range(STEPS):
        s0 = step0(s0, jnp.float32(t))
    eng, s = _run_hier(_hier_cfg(compress=cc.parse_compressor("int8"),
                                 compress2=cc.parse_compressor("topk:4")))
    assert isinstance(s.comm, HierCommState)
    r, c = eng.spec.rows, eng.spec.lanes
    assert s.comm.resid1.shape == (2, 3, r, c)
    assert s.comm.ref1.shape == (2, 1, r, c)
    assert s.comm.resid2.shape == (2, 1, r, c)
    assert s.comm.ref2.shape == (r, c)
    err = _max_err(eng.params_tree(s), s0.params)
    assert 0.0 < err < 0.12, err


def test_hier_level2_only_compression():
    """compress2 alone compresses ONLY the cross-pod sync: level-1 buffers
    stay absent and the trajectory tracks the uncompressed reference."""
    eng, s = _run_hier(_hier_cfg(compress2=cc.parse_compressor("int8")))
    assert s.comm.resid1 == () and s.comm.ref1 == ()
    assert isinstance(s.comm.ref2, jax.Array)
    _, s0 = _run_hier(_hier_cfg())
    err = float(jnp.max(jnp.abs(s.params - s0.params)))
    assert 0.0 < err < 5e-3, err


def test_hier_compressed_xla_matches_fused_bitwise():
    c1, c2 = cc.parse_compressor("int8"), cc.parse_compressor("topk:4")
    _, sx = _run_hier(_hier_cfg(compress=c1, compress2=c2, backend="xla"))
    _, sf = _run_hier(_hier_cfg(compress=c1, compress2=c2, backend="fused"))
    np.testing.assert_array_equal(np.asarray(sx.params),
                                  np.asarray(sf.params))
    np.testing.assert_array_equal(np.asarray(sx.comm.resid1),
                                  np.asarray(sf.comm.resid1))
    np.testing.assert_array_equal(np.asarray(sx.comm.resid2),
                                  np.asarray(sf.comm.resid2))


def test_hier_reference_executor_compressed():
    """The per-leaf hierarchical reference executor carries per-level comm
    state and tracks its own uncompressed trajectory."""
    cfg0 = _hier_cfg()
    cfgc = _hier_cfg(compress=cc.parse_compressor("int8"))
    s0 = H.init(cfg0, _params0(), (2, 3))
    sc = H.init(cfgc, _params0(), (2, 3))
    assert isinstance(sc.comm, HierCommState)
    step0 = jax.jit(lambda s, t: H.train_step(cfg0, s,
                                              _hier_grads(s.params, t)))
    stepc = jax.jit(lambda s, t: H.train_step(cfgc, s,
                                              _hier_grads(s.params, t)))
    for t in range(STEPS):
        s0 = step0(s0, jnp.float32(t))
        sc = stepc(sc, jnp.float32(t))
    err = _max_err(sc.params, s0.params)
    assert 0.0 < err < 5e-3, err


# -------------------------------------------------------------- checkpoint
def test_compressed_checkpoint_roundtrip_and_mismatch(tmp_path):
    """Residual/ref buffers persist next to the flat state; restoring into
    an engine with DIFFERENT compressors fails loudly (silently dropping
    the carried error feedback would corrupt the next sync)."""
    cfg = _cfg("vrl_sgd", compress=cc.parse_compressor("topk:4"))
    eng, s = _run_engine(cfg, steps=5)
    meta = cc.pair_meta(eng.compressors)
    ckpt.save_flat_state(str(tmp_path / "c"), s, eng.spec, meta={"step": 5},
                         compressors=meta)
    restored = ckpt.restore_flat_state(str(tmp_path / "c"), s, eng.spec,
                                       compressors=meta)
    np.testing.assert_array_equal(np.asarray(restored.comm.resid),
                                  np.asarray(s.comm.resid))
    np.testing.assert_array_equal(np.asarray(restored.comm.ref),
                                  np.asarray(s.comm.ref))
    # a different compressor (or none at all) must refuse to restore
    other = cc.pair_meta((cc.parse_compressor("int8"), None))
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "c"), s, eng.spec,
                                compressors=other)
    with pytest.raises(ValueError, match="compressor"):
        ckpt.restore_flat_state(str(tmp_path / "c"), s, eng.spec,
                                compressors=None)
