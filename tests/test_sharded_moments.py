"""Parity matrix for the shrunk engine state: bf16 momentum and the SM3
factored second moment across all three executors, layout-only sharding
as a bitwise no-op, and composition smokes with the other round features
(compressed sync, overlapped rounds, the hierarchical engine).

The moment dials change STORAGE, not the algorithm: SM3 at fp32 must
track the reference executor at the repo's fused-parity tolerance, and
bf16 storage adds only rounding noise that stays a small multiple of a
bf16 ulp over a short run.  Sharding never changes math at all — the row
padding it adds is inert (zero lanes), so shards=1 and shards=4 produce
bitwise-identical unflattened trees.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import compressors as cc
from repro.configs.base import EngineConfig, HierConfig, VRLConfig
from repro.core import get_algorithm, make_engine

TEMPLATE = {"w": jnp.zeros((40, 24)), "b": jnp.zeros((17,))}


def _params0():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (40, 24)) * 0.3,
            "b": jax.random.normal(jax.random.PRNGKey(1), (17,)) * 0.3}


def _cfg(**kw):
    kw.setdefault("engine", EngineConfig(block=8))
    return VRLConfig(algorithm="vrl_sgd", comm_period=2, learning_rate=0.05,
                     weight_decay=1e-3, warmup=False,
                     inner_optimizer="adam", **kw)


def _grads(params, t):
    """Per-worker phase so workers drift between syncs (exercises the
    drift correction), matching the engine-parity test's pseudo-grads."""
    def one(x):
        w = x.shape[0]
        phase = jnp.arange(w, dtype=x.dtype).reshape(
            (w,) + (1,) * (x.ndim - 1))
        return jnp.sin(3.0 * x + 0.7 * t + phase) + 0.1 * x
    return jax.tree.map(one, params)


def _run(cfg, steps=7, workers=4):
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), workers)
    step = jax.jit(lambda s, t: eng.train_step(
        s, _grads(eng.params_tree(s), t)))
    for t in range(steps):
        state = step(state, jnp.float32(t))
    return eng, state


def _run_reference(cfg, steps=7, workers=4):
    """The per-leaf tree path (update_backend='reference' in train_loop):
    ``get_algorithm`` driven directly, averaged over the worker axis."""
    alg = get_algorithm(cfg.algorithm)
    state = alg.init(cfg, _params0(), workers)
    step = jax.jit(lambda s, t: alg.train_step(
        cfg, s, _grads(s.params, t)))
    for t in range(steps):
        state = step(state, jnp.float32(t))
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)


def _avg(eng, state):
    return eng.average_model(state)


def _max_diff(a, b):
    return max(float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                     - jnp.asarray(y, jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------- executor parity matrix
def test_sm3_fp32_parity_across_executors():
    """SM3's factored vhat = min(row, col) is the same program on both
    flat executors (fused Pallas and xla twin share the packed-buffer
    cover): parity at the fused-engine tolerance.  The per-leaf reference
    covers each LEAF's own rows/lanes — a different (still upper-bounding)
    cover, so it tracks only at the approximation scale, not to ulps."""
    outs = {"reference": _run_reference(_cfg(update_backend="reference",
                                             sm3=True))}
    for backend in ("xla", "fused"):
        cfg = _cfg(update_backend=backend, sm3=True)
        outs[backend] = _avg(*_run(cfg))
    assert _max_diff(outs["xla"], outs["fused"]) < 1e-5
    assert _max_diff(outs["xla"], outs["reference"]) < 1e-1
    assert _max_diff(outs["fused"], outs["reference"]) < 1e-1


def test_bf16_moments_parity_across_executors():
    """bf16 moment storage (dense nu, no SM3 so all three covers agree)
    rounds at the same program points everywhere; executors may land on
    adjacent bf16 values (their pre-rounding ULP-level differences can
    straddle a rounding boundary), so the bound is a few bf16 ulps
    through the lr, not fp32-tight."""
    outs = {"reference": _run_reference(
        _cfg(update_backend="reference", moment_dtype="bfloat16"))}
    for backend in ("xla", "fused"):
        cfg = _cfg(update_backend=backend, moment_dtype="bfloat16")
        outs[backend] = _avg(*_run(cfg))
    assert _max_diff(outs["xla"], outs["fused"]) < 1e-3
    assert _max_diff(outs["xla"], outs["reference"]) < 1e-3
    assert _max_diff(outs["fused"], outs["reference"]) < 1e-3


def test_bf16_trajectory_tracks_fp32():
    """Quantized moments stay on the fp32 trajectory over a multi-round
    run — the drift bound the sharded benchmark gates on."""
    base = _avg(*_run(_cfg(update_backend="xla"), steps=9))
    bf16 = _avg(*_run(_cfg(update_backend="xla",
                           moment_dtype="bfloat16"), steps=9))
    sm3 = _avg(*_run(_cfg(update_backend="xla", moment_dtype="bfloat16",
                          sm3=True), steps=9))
    assert 0.0 < _max_diff(bf16, base) < 5e-2
    assert _max_diff(sm3, base) < 2e-1  # factored vhat is an approximation


# ------------------------------------------------- layout-only sharding
def test_sharded_layout_is_bitwise():
    """shards=N without a mesh only grows the inert row padding: the
    unflattened trees are BITWISE those of shards=1 at the same block, on
    both flat executors."""
    for backend in ("xla", "fused"):
        e1, s1 = _run(_cfg(update_backend=backend,
                           engine=EngineConfig(block=8, shards=1)))
        e4, s4 = _run(_cfg(update_backend=backend,
                           engine=EngineConfig(block=8, shards=4)))
        assert s4.params.shape[-2] % 4 == 0
        assert s4.params.shape[-2] >= s1.params.shape[-2]
        for a, b in zip(jax.tree.leaves(_avg(e1, s1)),
                        jax.tree.leaves(_avg(e4, s4))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_moment_state_shapes():
    """The moment dials actually shrink the buffers: bf16 halves mu/nu,
    SM3 replaces nu's (W, R, C) with a row stat plus one col row per
    shard."""
    cfg = _cfg(moment_dtype="bfloat16", sm3=True,
               engine=EngineConfig(block=8, shards=4))
    eng, state = _run(cfg, steps=2)
    w, r, c = state.params.shape
    assert state.inner.mu.dtype == jnp.bfloat16
    assert state.inner.nu.row.shape == (w, r, 1)
    assert state.inner.nu.col.shape == (w, 4, c)
    dense = w * r * c * 4
    sm3_bytes = (state.inner.nu.row.nbytes + state.inner.nu.col.nbytes)
    # exactly (R + shards*C)/(R*C) of the dense fp32 buffer — at real
    # model rows (R >> shards, C = 256 lanes) that's >100x; even at this
    # toy R=32 it's several-fold
    assert sm3_bytes == 4 * (w * r + w * 4 * c)
    assert sm3_bytes < dense / 4


# ------------------------------------------------- composition smokes
def test_compose_with_compressed_sync():
    """Sharded + quantized engine under top-k compressed sync: runs, sync
    fires (error-feedback residual is non-trivial), params stay finite."""
    cfg = _cfg(update_backend="xla", moment_dtype="bfloat16", sm3=True,
               compress=cc.parse_compressor("topk:8"),
               engine=EngineConfig(block=8, shards=4))
    eng, state = _run(cfg, steps=5)
    assert float(jnp.max(jnp.abs(state.comm.resid))) > 0.0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(_avg(eng, state)))


def test_compose_with_overlapped_rounds():
    """Sharded + quantized engine under overlapped rounds: the stale-fold
    round runs and stays finite through several boundaries."""
    cfg = _cfg(update_backend="xla", moment_dtype="bfloat16",
               overlap=True, engine=EngineConfig(block=8, shards=4))
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), 4)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for t in range(4):
        gk = jax.tree.map(
            lambda x: jnp.stack([jnp.sin(3.0 * x + 0.7 * (2 * t + i))
                                 + 0.1 * x for i in range(2)]),
            eng.params_tree(state))
        state = rstep(state, gk)
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(_avg(eng, state)))


def test_compose_with_hierarchical_engine():
    """The two-level (P, D, R, C) engine takes the same dials: sharded
    rows + bf16/SM3 moments, fused-vs-xla parity at the bf16 bound."""
    outs = {}
    for backend in ("xla", "fused"):
        cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                        weight_decay=1e-3, warmup=False,
                        inner_optimizer="adam", update_backend=backend,
                        moment_dtype="bfloat16", sm3=True,
                        hier=HierConfig(k1=2, k2=4, grid=(2, 2)),
                        engine=EngineConfig(block=8, shards=2))
        eng = make_engine(cfg, TEMPLATE)
        state = eng.init(_params0(), 4)
        step = jax.jit(lambda s, t, e=eng: e.train_step(
            s, _grads(e.params_tree(s), t)))
        for t in range(9):      # crosses both sync levels
            state = step(state, jnp.float32(t))
        assert state.inner.mu.dtype == jnp.bfloat16
        outs[backend] = _avg(eng, state)
    assert _max_diff(outs["fused"], outs["xla"]) < 1e-3
