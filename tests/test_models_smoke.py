"""Per-architecture smoke tests: REDUCED same-family variants (2 layers,
d_model<=512, <=4 experts) run one forward + one train step + one decode
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import VRLConfig
from repro.models import transformer as T
from repro.train.train_loop import make_train_step

ALL_ARCHS = registry.list_archs()


def make_inputs(cfg, batch, seq, key):
    if cfg.frontend == "codec":
        return jax.random.normal(key, (batch, seq, cfg.frontend_dim))
    return jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.smoke_arch(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    inp = make_inputs(cfg, 2, 32, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, x: T.forward(cfg, p, x))(params, inp)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = registry.smoke_arch(arch)
    vrl = VRLConfig(comm_period=2, learning_rate=0.01, warmup=False)
    bundle = make_train_step(cfg, vrl, remat=False)
    state = bundle.init_state(jax.random.PRNGKey(0), num_workers=2)
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "codec":
        tokens = jax.random.normal(key, (2, 2, 32, cfg.frontend_dim))
    else:
        tokens = jax.random.randint(key, (2, 2, 32), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 2, 32), 0,
                                cfg.vocab_size)
    new_state, loss = jax.jit(bundle.train_step)(state, tokens, labels)
    assert bool(jnp.isfinite(loss))
    assert int(new_state.step) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(new_state.params),
                        jax.tree.leaves(state.params)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_decode_step(arch):
    cfg = registry.smoke_arch(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32)
    tok = make_inputs(cfg, 2, 1, jax.random.PRNGKey(1))
    logits, new_cache = jax.jit(
        lambda p, c, t: T.decode_step(cfg, p, c, t, 0))(params, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["granite-3-2b", "hymba-1.5b", "mamba2-370m"])
def test_prefill_then_decode_continuation(arch):
    """prefill() cache must continue exactly like step-by-step decode."""
    cfg = registry.smoke_arch(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    cache_len = 16
    logits_pf, cache_pf = T.prefill(cfg, params, toks, cache_len)

    cache = T.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
    for i in range(8):
        logits_st, cache = T.decode_step(cfg, params, cache, toks[:, i:i + 1], i)
    err = float(jnp.max(jnp.abs(logits_pf[:, -1] - logits_st[:, 0])))
    assert err < 5e-4, err
    # continue one token from both caches: must agree
    nxt = jnp.argmax(logits_st[:, -1:], -1).astype(jnp.int32)
    l1, _ = T.decode_step(cfg, params, cache_pf, nxt, 8)
    l2, _ = T.decode_step(cfg, params, cache, nxt, 8)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 5e-4
