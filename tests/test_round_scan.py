"""Round-based execution: one compilation unit per communication period.

``Engine.round_step`` (k scan-fused local steps + the round-closing sync)
must match k sequential ``local_step`` dispatches + ``sync`` exactly, on
both engine executors, for every flat algorithm in the registry and the
hierarchical (k1, k2) cadence (whose oracle is the per-step
``train_step``).  The train-loop-level ``StepBundle.round_step`` must
reproduce the per-step trajectory through a real LM forward/backward.  And
the round jit must donate the flat state buffers — the compiled HLO
carries an input/output alias for every state array, extending the
kernels' per-call ``input_output_aliases`` guarantee to the whole scanned
round.

Variable-k schedules: rounds sized by a stagewise ``CommSchedule`` must
reproduce the per-step ``train_step`` oracle (which reads the same
schedule through ``should_sync``), and a whole stagewise run compiles
exactly ``len(stages)`` round executables through the ``RoundCache``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HierConfig, VRLConfig
from repro.core import RoundCache, flat_algorithms, make_engine
from repro.core.schedule import custom_stages

W, K = 4, 4

TEMPLATE = {"w": jnp.zeros((8, 3)), "b": jnp.zeros((5,)),
            "deep": {"u": jnp.zeros((2, 2, 4))}}


def _params0():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return {"w": jax.random.normal(ks[0], (8, 3)),
            "b": jax.random.normal(ks[1], (5,)),
            "deep": {"u": jax.random.normal(ks[2], (2, 2, 4))}}


def _grads_t(p0, t, lead=(W,)):
    """Deterministic state-independent pseudo-gradients (the round consumes
    a pre-supplied grads stack, so both paths must see the same inputs);
    the phase differs per worker so workers drift apart between syncs."""
    n = int(np.prod(lead))

    def one(x):
        phase = jnp.arange(n, dtype=x.dtype).reshape(lead + (1,) * x.ndim)
        big = jnp.broadcast_to(x, lead + x.shape)
        return jnp.sin(3.0 * big + 0.7 * t + phase) + 0.1 * x

    return jax.tree.map(one, p0)


def _stack(gs):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *gs)


def _cfg(alg, backend, inner="sgd", k=K):
    return VRLConfig(algorithm=alg, comm_period=k, learning_rate=0.05,
                     weight_decay=1e-3, inner_optimizer=inner,
                     momentum=0.9 if inner == "momentum" else 0.0,
                     warmup=False, update_backend=backend)


@pytest.mark.parametrize("backend", ["xla", "fused"])
@pytest.mark.parametrize("alg", flat_algorithms())
def test_round_matches_sequential_flat(alg, backend):
    """round_step over k steps == k local_step calls + sync (2 rounds) —
    for every flat algorithm in the registry."""
    cfg = _cfg(alg, backend)
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    s_seq, s_rnd = eng.init(p0, W), eng.init(p0, W)
    local, sync = jax.jit(eng.local_step), jax.jit(eng.sync)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(2):
        gs = [_grads_t(p0, r * K + i) for i in range(K)]
        for g in gs:
            s_seq = local(s_seq, g)
        s_seq = sync(s_seq)
        s_rnd = rstep(s_rnd, _stack(gs))
    np.testing.assert_allclose(np.asarray(s_seq.params),
                               np.asarray(s_rnd.params), atol=1e-6)
    if alg in ("vrl_sgd", "bvr_l_sgd"):
        np.testing.assert_allclose(np.asarray(s_seq.delta),
                                   np.asarray(s_rnd.delta), atol=1e-6)
    if alg == "bvr_l_sgd":
        np.testing.assert_allclose(np.asarray(s_seq.bias),
                                   np.asarray(s_rnd.bias), atol=1e-6)
    assert int(s_rnd.step) == 2 * K
    assert int(s_rnd.last_sync) == int(s_seq.last_sync)


@pytest.mark.parametrize("backend", ["xla", "fused"])
def test_round_matches_per_step_hier(backend):
    """Hierarchical rounds are one k1 period each and nest the level-2
    k2 cadence: 4 rounds at (k1, k2) = (2, 4) cross two k2 boundaries and
    must match the per-step train_step oracle exactly."""
    grid = (2, 3)
    cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                    weight_decay=1e-3, update_backend=backend,
                    hier=HierConfig(k1=2, k2=4, grid=grid))
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    s_seq, s_rnd = eng.init(p0, 6), eng.init(p0, 6)
    tstep = jax.jit(eng.train_step)
    rstep = jax.jit(eng.round_step, donate_argnums=(0,))
    for r in range(4):
        gs = [_grads_t(p0, 2 * r + i, lead=grid) for i in range(2)]
        for g in gs:
            s_seq = tstep(s_seq, g)
        s_rnd = rstep(s_rnd, _stack(gs))
    for name in ("params", "delta1", "delta2"):
        np.testing.assert_allclose(np.asarray(getattr(s_seq, name)),
                                   np.asarray(getattr(s_rnd, name)),
                                   atol=1e-6, err_msg=name)
    assert int(s_rnd.last_sync1) == int(s_seq.last_sync1) == 8
    assert int(s_rnd.last_sync2) == int(s_seq.last_sync2) == 8


def test_round_requires_divisible_hier_periods():
    """k2 % k1 != 0 cannot be expressed as whole k1 rounds — refuse."""
    cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.05,
                    update_backend="xla",
                    hier=HierConfig(k1=2, k2=5, grid=(2, 3)))
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), 6)
    gk = _stack([_grads_t(_params0(), i, lead=(2, 3)) for i in range(2)])
    with pytest.raises(ValueError, match="k2 % k1"):
        eng.round_step(state, gk)
    with pytest.raises(ValueError, match="k2 % k1"):
        eng.round_end(state)


@pytest.mark.parametrize("backend", ["auto", "reference"])
def test_train_loop_round_matches_per_step(backend):
    """StepBundle.round_step through a real LM fwd/bwd: two k=3 rounds
    reproduce six per-step train_step calls — same per-step losses, same
    final parameters — on the engine ("auto") and reference backends."""
    from repro.configs import registry
    from repro.train.train_loop import make_train_step

    cfg = registry.smoke_arch("qwen2-0.5b", num_layers=2, d_model=64,
                              d_ff=128, vocab_size=64, num_heads=4,
                              num_kv_heads=2, head_dim=16)
    vrl = VRLConfig(algorithm="vrl_sgd", comm_period=3, learning_rate=0.2,
                    weight_decay=0.0, warmup=False, update_backend=backend)
    w, b, s, k, rounds = 2, 2, 16, 3, 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (k * rounds, w, b, s),
                              0, 64)
    labels = jnp.roll(toks, -1, -1)

    bundle = make_train_step(cfg, vrl, remat=False)
    s_seq = bundle.init_state(jax.random.PRNGKey(0), w)
    s_rnd = bundle.init_state(jax.random.PRNGKey(0), w)
    step = jax.jit(bundle.train_step)
    rstep = jax.jit(bundle.round_step, donate_argnums=(0,))

    seq_losses = []
    for t in range(k * rounds):
        s_seq, loss = step(s_seq, toks[t], labels[t])
        seq_losses.append(float(loss))
    rnd_losses = []
    for r in range(rounds):
        sl = slice(r * k, (r + 1) * k)
        s_rnd, losses = rstep(s_rnd, toks[sl], labels[sl])
        rnd_losses.extend(float(x) for x in losses)

    np.testing.assert_allclose(seq_losses, rnd_losses, atol=1e-6)
    for a, b_ in zip(jax.tree.leaves(bundle.average_model(s_seq)),
                     jax.tree.leaves(bundle.average_model(s_rnd))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_round_jit_donates_flat_state():
    """The round jit's compiled HLO aliases EVERY flat state buffer to an
    output (no per-round state copy) — the scan-level extension of the
    kernels' input_output_aliases donation."""
    cfg = _cfg("vrl_sgd", "xla", inner="momentum")
    eng = make_engine(cfg, TEMPLATE)
    state = eng.init(_params0(), W)
    gk = _stack([_grads_t(_params0(), i) for i in range(K)])
    hlo = jax.jit(eng.round_step, donate_argnums=(0,)
                  ).lower(state, gk).compile().as_text()
    n_state_arrays = len(jax.tree.leaves(state))     # p, Δ, m, step, last
    assert n_state_arrays == 5
    assert "input_output_alias" in hlo
    assert hlo.count("may-alias") + hlo.count("must-alias") >= n_state_arrays


def test_round_flat_matches_round_tree():
    """round_step_flat over the pre-flattened buffer (the bench hot path)
    equals round_step over the grads pytree."""
    from repro.core import flat

    cfg = _cfg("vrl_sgd", "xla")
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    gs = _stack([_grads_t(p0, i) for i in range(K)])
    gk = jax.vmap(lambda t: flat.flatten_stacked(eng.spec, t,
                                                 dtype=eng.spec.dtype))(gs)
    s1 = jax.jit(eng.round_step)(eng.init(p0, W), gs)
    s2 = jax.jit(eng.round_step_flat)(eng.init(p0, W), gk)
    np.testing.assert_array_equal(np.asarray(s1.params),
                                  np.asarray(s2.params))
    np.testing.assert_array_equal(np.asarray(s1.delta),
                                  np.asarray(s2.delta))


# ------------------------------------------- variable-k stagewise rounds
SCHED = custom_stages([(1, 2), (2, 2), (4, 2)])     # T = 14, 3 distinct ks


def _scheduled_cfg(alg, backend):
    import dataclasses

    return dataclasses.replace(_cfg(alg, backend), comm_schedule=SCHED)


@pytest.mark.parametrize("alg", ["stl_sgd", "bvr_l_sgd"])
def test_stagewise_rounds_match_per_step_oracle(alg):
    """Rounds sized by the stagewise schedule reproduce the per-step
    train_step oracle (which reads the SAME schedule through should_sync):
    identical params and identical sync steps across every stage."""
    cfg = _scheduled_cfg(alg, "xla")
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    s_seq, s_rnd = eng.init(p0, W), eng.init(p0, W)
    tstep = jax.jit(eng.train_step)
    rcache = RoundCache(eng.round_step)
    t_total = SCHED.total_steps()
    gs = [_grads_t(p0, t) for t in range(t_total)]
    for g in gs:
        s_seq = tstep(s_seq, g)
    t = 0
    for k in SCHED.round_sizes(t_total):
        s_rnd = rcache(s_rnd, _stack(gs[t:t + k]))
        t += k
    np.testing.assert_allclose(np.asarray(s_seq.params),
                               np.asarray(s_rnd.params), atol=1e-6)
    assert int(s_rnd.step) == int(s_seq.step) == t_total
    assert int(s_rnd.last_sync) == int(s_seq.last_sync) == t_total


def test_round_cache_compiles_one_executable_per_stage():
    """A stagewise run compiles exactly len(stages) distinct round
    executables — later rounds of the same k reuse theirs (the compiled-
    round cache contract), including past the explicit stages."""
    cfg = _scheduled_cfg("stl_sgd", "xla")
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    state = eng.init(p0, W)
    rcache = RoundCache(eng.round_step)
    t_total = SCHED.total_steps() + 2 * 4   # 2 extra rounds at the final k
    t = 0
    n_rounds = 0
    for k in SCHED.round_sizes(t_total):
        state = rcache(state, _stack([_grads_t(p0, t + i)
                                      for i in range(k)]))
        t += k
        n_rounds += 1
    assert n_rounds == 8                    # 2 + 2 + 2 stage rounds + 2 tail
    assert rcache.compiles == len(SCHED.stages) == 3
    assert rcache.cached_ks == tuple(SCHED.distinct_periods()) == (1, 2, 4)


def test_round_cache_counts_retraces():
    """The cache keys on the round length k: re-feeding an already-seen k
    never retraces, and ``compiles`` counts trace events exactly."""
    cfg = _cfg("vrl_sgd", "xla")
    eng = make_engine(cfg, TEMPLATE)
    p0 = _params0()
    state = eng.init(p0, W)
    rcache = RoundCache(eng.round_step)
    for k in (2, 3, 2, 3, 2):
        state = rcache(state, _stack([_grads_t(p0, i) for i in range(k)]))
    assert rcache.compiles == 2
    assert rcache.cached_ks == (2, 3)
