"""Logical-axis -> PartitionSpec rules.

ParamDef axes (see models/param.py) are mapped onto mesh axes according to
the MeshConfig role assignment. The same rules build specs for worker-stacked
algorithm state (params, Δ, momentum all share the param layout).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig
from repro.models.param import ParamDef, is_def


def make_mesh(mesh_cfg: MeshConfig) -> Mesh:
    return jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)


def axis_rules(cfg: ModelConfig, mesh_cfg: MeshConfig) -> dict:
    tensor = tuple(mesh_cfg.tensor_axes)
    fsdp = tuple(mesh_cfg.fsdp_axes)
    worker = tuple(mesh_cfg.worker_axes)
    t = mesh_cfg.tensor_size
    experts_sharded = bool(cfg.num_experts) and cfg.num_experts % t == 0
    rules = {
        "layers": None,
        "worker": worker if worker else None,
        "vocab": tensor,
        "embed": fsdp if fsdp else None,
        "heads": tensor if cfg.num_heads and cfg.num_heads % t == 0 else None,
        "kv_heads": tensor if cfg.num_kv_heads and cfg.num_kv_heads % t == 0 else None,
        # expert-parallel: the expert dim takes the tensor axis, so expert
        # (and shared-expert) ff stays unsharded to avoid a duplicate axis.
        "ff": None if experts_sharded else tensor,
        "experts": tensor if experts_sharded else None,
        # expert weights 2D: (experts -> tensor, d -> fsdp); the activation
        # constraint in models/moe.py decides gather-vs-partial-sum by
        # capacity (see EXPERIMENTS.md §Perf pair C).
        "expert_embed": fsdp if fsdp else None,
        "expert_ff": None,
        "ssm_inner": tensor if cfg.ssm_state and cfg.ssm_d_inner % t == 0 else None,
        None: None,
    }
    return rules


def _norm(r):
    """() or None -> None; 1-tuple -> name; n-tuple stays a tuple."""
    if not r:
        return None
    if isinstance(r, tuple) and len(r) == 1:
        return r[0]
    return r


def spec_for(d: ParamDef, rules: dict) -> P:
    return P(*[_norm(rules.get(ax, None)) for ax in d.axes])


def partition_specs(defs, cfg: ModelConfig, mesh_cfg: MeshConfig):
    """Pytree of PartitionSpec mirroring a ParamDef pytree."""
    rules = axis_rules(cfg, mesh_cfg)
    return jax.tree.map(lambda d: spec_for(d, rules), defs, is_leaf=is_def)


def shardings(defs, cfg: ModelConfig, mesh_cfg: MeshConfig, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        partition_specs(defs, cfg, mesh_cfg),
        is_leaf=lambda x: isinstance(x, P))


def worker_stacked_spec(spec: P, mesh_cfg: MeshConfig) -> P:
    """Prepend the worker axis to an existing spec."""
    return P(_norm(tuple(mesh_cfg.worker_axes)), *spec)


def engine_shard_axis(mesh_cfg: MeshConfig, ecfg) -> Optional[str]:
    """Resolve the engine-state row-shard axis against a MeshConfig.

    The flat-buffer engine shards every (W, R, C) buffer's row dim over
    ``ecfg.shard_axis`` (``EngineConfig``); on the production mesh that
    axis REUSES the tensor axis "model" — engine rows and model tensor
    dims shard over the same devices, so neither replicates across the
    other's axis.  Returns None when sharding is off (``shards <= 1``) or
    the mesh simply lacks the axis (host smoke meshes), and raises when
    the axis exists at the WRONG size — a silent half-shard would desync
    the per-shard all-reduce.
    """
    if getattr(ecfg, "shards", 1) <= 1:
        return None
    sizes = dict(zip(mesh_cfg.axis_names, mesh_cfg.shape))
    ax = ecfg.shard_axis
    if ax not in sizes:
        return None
    if sizes[ax] != ecfg.shards:
        raise ValueError(
            f"engine shards={ecfg.shards} but mesh axis {ax!r} has size "
            f"{sizes[ax]} — the row-shard count must equal the mesh axis "
            f"backing it")
    return ax


def batch_spec(mesh_cfg: MeshConfig, *, worker_stacked: bool, extra_dims: int) -> P:
    """Spec for (W, local_batch, ...) train batches or (batch, ...) serve."""
    w = tuple(mesh_cfg.worker_axes)
    f = tuple(mesh_cfg.fsdp_axes)
    if worker_stacked:
        return P(_norm(w), _norm(f), *([None] * extra_dims))
    # serving: batch over all data-like axes
    return P(_norm(w + f), *([None] * extra_dims))


from repro.sharding.constrain import maybe_constrain  # noqa: F401,E402
