"""Runtime activation-sharding helpers (no model imports — cycle-free)."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh


def maybe_constrain(x, *spec_parts):
    """with_sharding_constraint iff an ambient mesh with a "model" axis is
    set (no-op in single-device tests). Divisibility-guarded."""
    m = get_abstract_mesh()
    if m.empty or "model" not in m.axis_names:
        return x
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    off = x.ndim - len(spec_parts)
    for i, part in enumerate(spec_parts):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        need = 1
        for a in axes:
            if a not in sizes:
                return x
            need *= sizes[a]
        if x.shape[off + i] % need:
            return x
    return jax.lax.with_sharding_constraint(x, P(*spec_parts))


def axis_size(name: str) -> int:
    m = get_abstract_mesh()
    if m.empty or name not in m.axis_names:
        return 1 << 30
    return dict(zip(m.axis_names, m.axis_sizes))[name]
