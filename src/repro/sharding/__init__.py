from repro.sharding.specs import (  # noqa: F401
    axis_rules,
    batch_spec,
    make_mesh,
    partition_specs,
    shardings,
    spec_for,
    worker_stacked_spec,
)
