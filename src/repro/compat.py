"""jax version compatibility shims (single home for every API probe).

The repo targets the modern mesh API (``jax.set_mesh``, ``jax.sharding
.AxisType``, ``jax.sharding.get_abstract_mesh``); the pinned container ships
jax 0.4.37 where the ambient mesh is the legacy ``with mesh:`` thread-local
and ``jit`` only accepts concrete ``Sharding`` objects.  Everything that
touches the ambient mesh goes through this module so the rest of the code
reads as if only one jax existed.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicit (Auto) axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                                 axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def set_mesh(mesh):
    """Ambient-mesh context manager.

    New jax: ``jax.set_mesh(mesh)``.  Old jax: ``Mesh`` is itself a context
    manager that installs the thread-local physical mesh (the thing
    ``with_sharding_constraint`` and shard_map resolve against).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh, with ``.empty`` / ``.axis_names`` / ``.axis_sizes``.

    Falls back to the legacy thread-local physical mesh (set by
    ``with mesh:``) when ``jax.sharding.get_abstract_mesh`` is missing.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` / ``jax.experimental.shard_map.shard_map``.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); None
    leaves the library default.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy_sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def shardings(mesh, spec_tree: Any):
    """PartitionSpec pytree -> NamedSharding pytree.

    ``jit(in_shardings=...)`` on old jax rejects bare PartitionSpecs even
    under an ambient mesh; wrapping is portable across every version.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))
