from repro.optim.optimizers import Optimizer, adam, make_inner, momentum, sgd  # noqa: F401
