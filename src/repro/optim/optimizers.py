"""Inner optimizers built from scratch (no optax): SGD, momentum, Adam.

All of them are pytree transforms with the interface
    opt.init(params) -> state
    opt.update(params, grads, state) -> (new_params, new_state)
Weight decay is decoupled (AdamW-style) and applied by every optimizer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def sgd(lr: float, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(params, grads, state):
        def upd(x, g):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * x.astype(jnp.float32)
            return (x.astype(jnp.float32) - lr * g).astype(x.dtype)
        return jax.tree.map(upd, params, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)

    def _g(x, g):
        g = g.astype(jnp.float32)
        return g + weight_decay * x.astype(jnp.float32) if weight_decay else g

    def update(params, grads, bufs):
        new_m = jax.tree.map(lambda x, g, m: beta * m + _g(x, g),
                             params, grads, bufs)
        def upd(x, g, m):
            step_dir = _g(x, g) + beta * m if nesterov else m
            return (x.astype(jnp.float32) - lr * step_dir).astype(x.dtype)
        new_p = jax.tree.map(upd, params, grads, new_m)
        return new_p, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda x: jnp.zeros_like(x, jnp.float32)
        return AdamState(jax.tree.map(z, params), jax.tree.map(z, params),
                         jnp.zeros((), jnp.int32))

    def update(params, grads, state):
        count = state.count + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        new_mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        new_nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)

        def upd(x, m, v):
            step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * x.astype(jnp.float32)
            return (x.astype(jnp.float32) - step).astype(x.dtype)

        new_p = jax.tree.map(upd, params, new_mu, new_nu)
        return new_p, AdamState(new_mu, new_nu, count)

    return Optimizer(init, update)


def make_inner(cfg) -> Optimizer:
    """Build the inner optimizer from a VRLConfig."""
    if cfg.inner_optimizer == "sgd":
        if cfg.momentum:
            return momentum(cfg.learning_rate, cfg.momentum, cfg.weight_decay)
        return sgd(cfg.learning_rate, cfg.weight_decay)
    if cfg.inner_optimizer == "momentum":
        return momentum(cfg.learning_rate, cfg.momentum or 0.9, cfg.weight_decay)
    if cfg.inner_optimizer == "adam":
        return adam(cfg.learning_rate, weight_decay=cfg.weight_decay)
    raise ValueError(cfg.inner_optimizer)
