"""Inner optimizers built from scratch (no optax): SGD, momentum, Adam.

All of them are pytree transforms with the interface
    opt.init(params) -> state
    opt.update(params, grads, state) -> (new_params, new_state)
Weight decay is decoupled (AdamW-style) and applied by every optimizer.

Moment storage is decoupled from moment math: ``moment_dtype`` controls
only what persists between steps (bf16 halves moment HBM); every update
reads the stored moments up to fp32, computes in fp32, and casts the
result back down.  ``float32`` is bitwise the original path.

``sm3=True`` switches Adam's second moment to the SM3 factored form
(Anil et al. 2019): per matrix-like leaf, nu's full buffer is replaced by
a row-max and a lane-max statistic over the trailing 2D face, with
v̂ = min(row, lane) bounding nu from above — the fused engine applies the
same construction to its (R, C) flat buffers.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def sgd(lr: float, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(params, grads, state):
        def upd(x, g):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * x.astype(jnp.float32)
            return (x.astype(jnp.float32) - lr * g).astype(x.dtype)
        return jax.tree.map(upd, params, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False,
             moment_dtype: Any = jnp.float32) -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        return jax.tree.map(lambda x: jnp.zeros_like(x, mdt), params)

    def _g(x, g):
        g = g.astype(jnp.float32)
        return g + weight_decay * x.astype(jnp.float32) if weight_decay else g

    def update(params, grads, bufs):
        new_m = jax.tree.map(
            lambda x, g, m: (beta * m.astype(jnp.float32)
                             + _g(x, g)).astype(mdt),
            params, grads, bufs)
        def upd(x, g, m):
            m = m.astype(jnp.float32)
            step_dir = _g(x, g) + beta * m if nesterov else m
            return (x.astype(jnp.float32) - lr * step_dir).astype(x.dtype)
        new_p = jax.tree.map(upd, params, grads, new_m)
        return new_p, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


class SM3Pair(NamedTuple):
    """Factored second-moment statistics for one matrix-like leaf: ``row``
    is the max over the last (lane) dim, ``col`` the max over the
    second-to-last (row) dim — ``min(row, col)`` bounds the dense nu from
    above.  Always fp32 (the stats are ~(R + C)/(R·C) of the dense buffer,
    so quantizing them buys nothing)."""

    row: jax.Array
    col: jax.Array


def _sm3_factored(x) -> bool:
    return getattr(x, "ndim", 0) >= 2


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, moment_dtype: Any = jnp.float32,
         sm3: bool = False) -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        mu = jax.tree.map(lambda x: jnp.zeros_like(x, mdt), params)
        if sm3:
            def stats(x):
                if not _sm3_factored(x):
                    return jnp.zeros_like(x, jnp.float32)
                return SM3Pair(
                    row=jnp.zeros(x.shape[:-1] + (1,), jnp.float32),
                    col=jnp.zeros(x.shape[:-2] + (1, x.shape[-1]),
                                  jnp.float32))
            nu = jax.tree.map(stats, params)
        else:
            nu = jax.tree.map(lambda x: jnp.zeros_like(x, mdt), params)
        return AdamState(mu, nu, jnp.zeros((), jnp.int32))

    def _upd_one(x, g, m_old, nu_old, c1, c2):
        g = g.astype(jnp.float32)
        m = b1 * m_old.astype(jnp.float32) + (1 - b1) * g
        if sm3 and isinstance(nu_old, SM3Pair):
            vhat = jnp.minimum(nu_old.row, nu_old.col)
            v = b2 * vhat + (1 - b2) * jnp.square(g)
            nu_new = SM3Pair(row=jnp.max(v, axis=-1, keepdims=True),
                             col=jnp.max(v, axis=-2, keepdims=True))
        else:
            v = (b2 * nu_old.astype(jnp.float32)
                 + (1 - b2) * jnp.square(g))
            nu_new = v.astype(mdt)
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * x.astype(jnp.float32)
        return ((x.astype(jnp.float32) - step).astype(x.dtype),
                m.astype(mdt), nu_new)

    def update(params, grads, state):
        count = state.count + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        p_leaves, tdef = jax.tree_util.tree_flatten(params)
        g_leaves = jax.tree_util.tree_leaves(grads)
        mu_leaves = jax.tree_util.tree_leaves(state.mu)
        nu_leaves = jax.tree.leaves(
            state.nu, is_leaf=lambda n: isinstance(n, SM3Pair))
        new_p, new_mu, new_nu = [], [], []
        for x, g, m, v in zip(p_leaves, g_leaves, mu_leaves, nu_leaves):
            xp, mp, vp = _upd_one(x, g, m, v, c1, c2)
            new_p.append(xp)
            new_mu.append(mp)
            new_nu.append(vp)
        return (jax.tree_util.tree_unflatten(tdef, new_p),
                AdamState(jax.tree_util.tree_unflatten(tdef, new_mu),
                          jax.tree_util.tree_unflatten(tdef, new_nu),
                          count))

    return Optimizer(init, update)


def make_inner(cfg) -> Optimizer:
    """Build the inner optimizer from a VRLConfig."""
    mdt = getattr(cfg, "moment_dtype", "float32")
    if cfg.inner_optimizer == "sgd":
        if cfg.momentum:
            return momentum(cfg.learning_rate, cfg.momentum, cfg.weight_decay,
                            moment_dtype=mdt)
        return sgd(cfg.learning_rate, cfg.weight_decay)
    if cfg.inner_optimizer == "momentum":
        return momentum(cfg.learning_rate, cfg.momentum or 0.9,
                        cfg.weight_decay, moment_dtype=mdt)
    if cfg.inner_optimizer == "adam":
        return adam(cfg.learning_rate, weight_decay=cfg.weight_decay,
                    moment_dtype=mdt, sm3=getattr(cfg, "sm3", False))
    raise ValueError(cfg.inner_optimizer)
