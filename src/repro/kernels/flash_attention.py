"""Flash attention forward (causal + sliding window) as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * grid = (batch*heads, q_blocks, k_blocks) — the k-block axis is the
    minor-most grid dimension, which Pallas TPU executes sequentially per
    (bh, qb), so the online-softmax running state (m, l, acc) lives in VMEM
    scratch that persists across k iterations.
  * BlockSpecs tile q/k/v into (block_q|block_k, head_dim) VMEM slabs; the
    MXU sees (block_q x d) @ (d x block_k) matmuls with blocks kept at
    multiples of 128 where the model allows.
  * Softmax statistics are fp32; the p@v accumulation is fp32 and cast on the
    final k block.

VMEM budget per program instance (bf16 inputs, fp32 scratch):
  q: block_q*d*2 + k,v: 2*block_k*d*2 + acc: block_q*d*4 + o: block_q*d*2
  = ~128*128*(2+4+4+2) B ≈ 197 KiB at the default 128/128 blocks, d=128.

Validated in interpret mode on CPU against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, num_kb: int,
                  causal: bool, window: Optional[int]):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kb == num_kb - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D) -> (BH, S, D).

    Sequence length must be divisible by the block sizes (ops.py pads).
    """
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    num_qb = s // block_q
    num_kb = s // block_k
    kernel = functools.partial(
        _flash_kernel, scale=d ** -0.5, block_q=block_q, block_k=block_k,
        num_kb=num_kb, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(bh, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
