"""Fused VRL-SGD update kernels (the paper's eq. 4-6 as single HBM passes).

The paper's math is elementwise over model-sized buffers, so on TPU it is
purely HBM-bandwidth-bound. Unfused, the local step reads p, g, Δ and writes
v then p (5 model-sized transfers); the fused kernel reads 3 and writes 1.
The sync step fuses the Δ update with the parameter broadcast the same way.

  local:  p' = p − γ·(g − Δ)                          (eq. 5 + 6)
  sync:   Δ' = Δ + (x̂ − p)/(kγ);  p' = x̂             (eq. 4 + line 6)

Both operate on 2D row-major tiles of the flattened parameter leaf; ops.py
handles flatten/pad/unflatten.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _local_kernel(p_ref, g_ref, d_ref, o_ref, *, lr: float):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    o_ref[...] = (p - lr * (g - d)).astype(o_ref.dtype)


def _sync_kernel(p_ref, xbar_ref, d_ref, po_ref, do_ref, *, inv_kg: float):
    p = p_ref[...].astype(jnp.float32)
    xb = xbar_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    do_ref[...] = (d + (xb - p) * inv_kg).astype(do_ref.dtype)
    po_ref[...] = xb.astype(po_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "block", "interpret"))
def vrl_local_update(p: jax.Array, g: jax.Array, delta: jax.Array, *,
                     lr: float, block: int = 1024,
                     interpret: bool = True) -> jax.Array:
    """p, g, delta: (R, C) with R % block == 0 -> updated p."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_local_kernel, lr=lr),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), p.dtype),
        interpret=interpret,
    )(p, g, delta)


@functools.partial(jax.jit, static_argnames=("inv_kg", "block", "interpret"))
def vrl_sync_update(p: jax.Array, xbar: jax.Array, delta: jax.Array, *,
                    inv_kg: float, block: int = 1024,
                    interpret: bool = True):
    """Returns (p', Δ')."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sync_kernel, inv_kg=inv_kg),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), p.dtype),
                   jax.ShapeDtypeStruct((r, c), delta.dtype)],
        interpret=interpret,
    )(p, xbar, delta)
