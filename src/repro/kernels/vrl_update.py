"""Fused VRL-SGD update kernels (the paper's eq. 4-6 as single HBM passes).

The paper's math is elementwise over model-sized buffers, so on TPU it is
purely HBM-bandwidth-bound. Unfused, the local step reads p, g, Δ and writes
v then p (5 model-sized transfers); the fused kernel reads 3 and writes 1.
The sync step fuses the Δ update with the parameter broadcast the same way.

  local:  p' = p − γ·(g − Δ)                          (eq. 5 + 6)
  sync:   Δ' = Δ + (x̂ − p)/(kγ);  p' = x̂             (eq. 4 + line 6)

Two families live here:

  * ``vrl_local_update`` / ``vrl_sync_update`` — the original per-leaf 2D
    tile kernels (used by ``ops.py``'s tree wrappers and their tests).
  * ``fused_local_{sgd,momentum,adam}`` / ``fused_sync_vrl`` /
    ``fused_sync_easgd`` — the engine's worker-stacked (W, R, C) kernels.
    One grid step per (worker, row-tile); the inner-optimizer moment update
    is fused into the same HBM pass, and dynamic scalars (Adam bias
    correction, the sync-time k_eff·γ) ride in as a tiny (1, n) operand so
    the compiled kernel never retraces per step.  All math is fp32
    in-register with per-buffer output casts, matching the reference tree
    path bit-for-bit in fp32.
  * ``fused_hier_local_{sgd,momentum,adam}`` / ``fused_sync_hier{1,2}`` —
    the two-level hierarchical engine's pod-major (P, D, R, C) kernels.
    The local step subtracts BOTH corrections (v = g − Δ1 − Δ2) in the same
    pass; Δ2 is carried as a per-pod (P, 1, R, C) buffer whose blocks are
    broadcast over the intra-pod axis by the index map, never materialized
    at (P, D) size in HBM.

State buffers are donated (``input_output_aliases``) so every update is
in-place: the kernels read each block exactly once before overwriting it,
and XLA falls back to a copy when a donated buffer has another consumer.

``block``/``interpret`` come from the engine config (``configs.base
.EngineConfig``); the (R, C) layout and auto block choice from
``core/flat.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm import compressors as cc


def default_interpret() -> bool:
    """Interpret-mode (python body) everywhere Pallas cannot compile —
    i.e. anything but real TPU/GPU backends.  Interpret mode is orders of
    magnitude slower than compiled code; ``update_backend="auto"`` picks
    the XLA executor (``kernels/xla_update``) on such backends instead."""
    return jax.default_backend() not in ("tpu", "gpu")


def _local_kernel(p_ref, g_ref, d_ref, o_ref, *, lr: float):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    o_ref[...] = (p - lr * (g - d)).astype(o_ref.dtype)


def _sync_kernel(p_ref, xbar_ref, d_ref, po_ref, do_ref, *, inv_kg: float):
    p = p_ref[...].astype(jnp.float32)
    xb = xbar_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    do_ref[...] = (d + (xb - p) * inv_kg).astype(do_ref.dtype)
    po_ref[...] = xb.astype(po_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "block", "interpret"))
def vrl_local_update(p: jax.Array, g: jax.Array, delta: jax.Array, *,
                     lr: float, block: int = 1024,
                     interpret: bool = True) -> jax.Array:
    """p, g, delta: (R, C) with R % block == 0 -> updated p."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_local_kernel, lr=lr),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), p.dtype),
        interpret=interpret,
    )(p, g, delta)


@functools.partial(jax.jit, static_argnames=("inv_kg", "block", "interpret"))
def vrl_sync_update(p: jax.Array, xbar: jax.Array, delta: jax.Array, *,
                    inv_kg: float, block: int = 1024,
                    interpret: bool = True):
    """Returns (p', Δ')."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sync_kernel, inv_kg=inv_kg),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), p.dtype),
                   jax.ShapeDtypeStruct((r, c), delta.dtype)],
        interpret=interpret,
    )(p, xbar, delta)


# ===================================================================== engine
# Worker-stacked (W, R, C) kernels for core/engine.py.  Grid = (W, R/block);
# every buffer streams through VMEM exactly once per step.

def _grid_specs(w: int, r: int, c: int, block: int, n: int):
    """n identical (1, block, C) specs over a (W, R/block) grid."""
    del w, r
    return [pl.BlockSpec((1, block, c), lambda wi, i: (wi, i, 0))
            for _ in range(n)]


def _scal_spec(n: int):
    """(1, n) fp32 dynamic-scalar operand, same tile for every grid step."""
    return pl.BlockSpec((1, n), lambda wi, i: (0, 0))


def _f32(ref):
    return ref[...].astype(jnp.float32)


def _correction(refs, start: int, use_delta: bool, use_bias: bool):
    """v = g − [Δ] − [B] for the local kernels: the optional corrections sit
    at ``refs[start:]`` in (Δ, B) order.  Returns (v, next ref index)."""
    v = _f32(refs[1])
    i = start
    if use_delta:
        v = v - _f32(refs[i])
        i += 1
    if use_bias:
        v = v - _f32(refs[i])
        i += 1
    return v, i


def _fused_sgd_kernel(*refs, lr, wd, use_delta, use_bias):
    v, _ = _correction(refs, 2, use_delta, use_bias)
    p = _f32(refs[0])
    if wd:
        v = v + wd * p
    o_ref = refs[-1]
    o_ref[...] = (p - lr * v).astype(o_ref.dtype)


def fused_local_sgd(p, g, d=None, *, lr: float, wd: float = 0.0,
                    block: int = 1024, interpret=None, b=None):
    """p' = p − γ((g − Δ − B) + wd·p) on (W, R, C) buffers.

    d=None ⇒ Δ ≡ 0; b (BVR-L-SGD's bias variate) =None ⇒ B ≡ 0."""
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta, use_bias = d is not None, b is not None
    ins = (p, g) + ((d,) if use_delta else ()) + ((b,) if use_bias else ())
    specs = _grid_specs(w, r, c, block, len(ins))
    return pl.pallas_call(
        functools.partial(_fused_sgd_kernel, lr=lr, wd=wd,
                          use_delta=use_delta, use_bias=use_bias),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=specs[0],
        out_shape=jax.ShapeDtypeStruct((w, r, c), p.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(*ins)


def _fused_momentum_kernel(*refs, lr, beta, wd, nesterov, use_delta,
                           use_bias):
    v, i = _correction(refs, 2, use_delta, use_bias)
    m_ref, po_ref, mo_ref = refs[i], refs[-2], refs[-1]
    p = _f32(refs[0])
    if wd:
        v = v + wd * p
    m_new = beta * _f32(m_ref) + v
    step_dir = v + beta * m_new if nesterov else m_new
    po_ref[...] = (p - lr * step_dir).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)


def fused_local_momentum(p, g, d, m, *, lr: float, beta: float,
                         wd: float = 0.0, nesterov: bool = False,
                         block: int = 1024, interpret=None, b=None):
    """Momentum inner step fused with the corrections; returns (p', m')."""
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta, use_bias = d is not None, b is not None
    ins = ((p, g) + ((d,) if use_delta else ())
           + ((b,) if use_bias else ()) + (m,))
    specs = _grid_specs(w, r, c, block, len(ins))
    return pl.pallas_call(
        functools.partial(_fused_momentum_kernel, lr=lr, beta=beta, wd=wd,
                          nesterov=nesterov, use_delta=use_delta,
                          use_bias=use_bias),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=[specs[0], specs[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), m.dtype)],
        input_output_aliases={0: 0, len(ins) - 1: 1},
        interpret=interpret,
    )(*ins)


def _fused_adam_kernel(*refs, lr, b1, b2, eps, wd, use_delta, use_bias):
    v, i = _correction(refs, 2, use_delta, use_bias)
    mu_ref, nu_ref, s_ref = refs[i], refs[i + 1], refs[i + 2]
    po, muo, nuo = refs[-3], refs[-2], refs[-1]
    p = _f32(refs[0])
    c1 = s_ref[0, 0]    # 1 − b1^t  (dynamic: depends on the step count)
    c2 = s_ref[0, 1]    # 1 − b2^t
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * v
    nu = b2 * _f32(nu_ref) + (1.0 - b2) * v * v
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p
    po[...] = (p - step).astype(po.dtype)
    muo[...] = mu.astype(muo.dtype)
    nuo[...] = nu.astype(nuo.dtype)


def fused_local_adam(p, g, d, mu, nu, scal, *, lr: float, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0,
                     block: int = 1024, interpret=None, b=None):
    """Adam inner step fused with the corrections.

    ``scal``: (1, 2) fp32 = [1 − b1^t, 1 − b2^t] (bias-correction terms are
    traced values, so they enter as data, not as static compile-time args).
    Returns (p', mu', nu').
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta, use_bias = d is not None, b is not None
    ins = ((p, g) + ((d,) if use_delta else ())
           + ((b,) if use_bias else ()) + (mu, nu))
    specs = _grid_specs(w, r, c, block, len(ins)) + [_scal_spec(2)]
    return pl.pallas_call(
        functools.partial(_fused_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                          wd=wd, use_delta=use_delta, use_bias=use_bias),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=[specs[0], specs[0], specs[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), mu.dtype),
                   jax.ShapeDtypeStruct((w, r, c), nu.dtype)],
        input_output_aliases={0: 0, len(ins) - 2: 1, len(ins) - 1: 2},
        interpret=interpret,
    )(*ins, scal)


def _fused_adam_sm3_kernel(*refs, lr, b1, b2, eps, wd, tps, use_delta,
                           use_bias):
    """SM3-factored Adam: nu is never materialized at (W, R, C) — it is
    rebuilt per tile from the row stat (W, R, 1) and the per-shard lane
    stat (W, S, C) via v̂ = min(row, col), updated, and re-factored.

    The lane stat's output block is revisited by the ``tps`` consecutive
    row tiles of its shard (grid is row-major), so it is NOT donated —
    aliasing it would feed tile i+1 the partially-accumulated stat through
    the min() above.  First visit initializes, later visits max-accumulate;
    fp32 max is exact and order-free, so the result is bitwise the xla
    twin's single max over the shard's rows.
    """
    v, i = _correction(refs, 2, use_delta, use_bias)
    mu_ref, row_ref, col_ref, s_ref = refs[i], refs[i + 1], refs[i + 2], \
        refs[i + 3]
    po, muo, rowo, colo = refs[-4], refs[-3], refs[-2], refs[-1]
    p = _f32(refs[0])
    c1 = s_ref[0, 0]
    c2 = s_ref[0, 1]
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * v
    vhat = jnp.minimum(_f32(row_ref), _f32(col_ref))
    nu = b2 * vhat + (1.0 - b2) * v * v
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p
    po[...] = (p - step).astype(po.dtype)
    muo[...] = mu.astype(muo.dtype)
    rowo[...] = jnp.max(nu, axis=-1, keepdims=True).astype(rowo.dtype)
    tile_col = jnp.max(nu, axis=-2, keepdims=True).astype(colo.dtype)
    ti = pl.program_id(len(colo.shape) - 2)   # row-tile grid index
    first = (ti % tps) == 0

    @pl.when(first)
    def _init():
        colo[...] = tile_col

    @pl.when(jnp.logical_not(first))
    def _acc():
        colo[...] = jnp.maximum(colo[...], tile_col)


def fused_local_adam_sm3(p, g, d, mu, row, col, scal, *, lr: float,
                         b1: float = 0.9, b2: float = 0.999,
                         eps: float = 1e-8, wd: float = 0.0,
                         block: int = 1024, interpret=None, b=None):
    """SM3-factored Adam inner step fused with the corrections.

    ``row``: (W, R, 1) fp32 row-max stat; ``col``: (W, S, C) fp32 lane-max
    stat, one row per model shard's row span (S=1 ⇒ classic SM3 over the
    whole buffer).  Per-shard spans keep the stat update local under
    row-block sharding — a finer cover is still a valid upper bound.
    Returns (p', mu', row', col'); p/mu/row donated, col not (see kernel).
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    shards = col.shape[-2]
    assert (r // block) % shards == 0, (r, block, shards)
    tps = (r // block) // shards
    use_delta, use_bias = d is not None, b is not None
    ins = ((p, g) + ((d,) if use_delta else ())
           + ((b,) if use_bias else ()) + (mu, row, col))
    n3 = len(ins) - 2                   # (W, R, C) operands
    specs = _grid_specs(w, r, c, block, n3)
    row_spec = pl.BlockSpec((1, block, 1), lambda wi, i: (wi, i, 0))
    col_spec = pl.BlockSpec((1, 1, c), lambda wi, i: (wi, i // tps, 0))
    return pl.pallas_call(
        functools.partial(_fused_adam_sm3_kernel, lr=lr, b1=b1, b2=b2,
                          eps=eps, wd=wd, tps=tps, use_delta=use_delta,
                          use_bias=use_bias),
        grid=(w, r // block),
        in_specs=specs + [row_spec, col_spec, _scal_spec(2)],
        out_specs=[specs[0], specs[0], row_spec, col_spec],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), mu.dtype),
                   jax.ShapeDtypeStruct(row.shape, jnp.float32),
                   jax.ShapeDtypeStruct(col.shape, jnp.float32)],
        input_output_aliases={0: 0, len(ins) - 3: 1, len(ins) - 2: 2},
        interpret=interpret,
    )(*ins, scal)


def _fused_sync_kernel(p_ref, xb_ref, d_ref, s_ref, po_ref, do_ref):
    p = _f32(p_ref)
    xb = _f32(xb_ref)[None]     # (block, C) broadcast over the worker dim
    kg = s_ref[0, 0]            # k_eff · γ  (k_eff is traced)
    do_ref[...] = (_f32(d_ref) + (xb - p) / kg).astype(do_ref.dtype)
    po_ref[...] = jnp.broadcast_to(xb, po_ref.shape).astype(po_ref.dtype)


def fused_sync_vrl(p, xbar, d, scal, *, block: int = 1024, interpret=None):
    """Δ' = Δ + (x̂ − p)/(k_eff γ); p' = x̂ — one pass, (W, R, C) buffers.

    ``xbar``: (R, C) — each worker's grid step reads the same x̂ tile, so the
    broadcast never materializes W copies in HBM.  ``scal``: (1, 1) fp32
    holding k_eff·γ (division matches the reference path's rounding exactly).
    Returns (p', Δ').
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    s3 = _grid_specs(w, r, c, block, 2)
    xb_spec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    return pl.pallas_call(
        _fused_sync_kernel,
        grid=(w, r // block),
        in_specs=[s3[0], xb_spec, s3[1], _scal_spec(1)],
        out_specs=[s3[0], s3[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), d.dtype)],
        input_output_aliases={0: 0, 2: 1},
        interpret=interpret,
    )(p, xbar, d, scal)


def _fused_sync_bvr_kernel(p_ref, xb_ref, d_ref, b_ref, s_ref, po_ref,
                           do_ref, bo_ref, *, beta: float):
    p = _f32(p_ref)
    xb = _f32(xb_ref)[None]     # (block, C) broadcast over the worker dim
    kg = s_ref[0, 0]            # k_eff · γ  (k_eff is traced)
    u = (xb - p) / kg           # realized drift this round
    do_ref[...] = (_f32(d_ref) + u).astype(do_ref.dtype)
    bo_ref[...] = ((1.0 - beta) * _f32(b_ref) + beta * u
                   ).astype(bo_ref.dtype)
    po_ref[...] = jnp.broadcast_to(xb, po_ref.shape).astype(po_ref.dtype)


def fused_sync_bvr(p, xbar, d, b, scal, *, beta: float, block: int = 1024,
                   interpret=None):
    """BVR-L-SGD sync: the VRL Δ update plus the bias-variate EMA, one pass.

      u  = (x̂ − p)/(k_eff γ)        Δ' = Δ + u
      B' = (1−β)·B + β·u            p' = x̂

    Same operand contract as ``fused_sync_vrl`` with the extra (W, R, C)
    bias buffer ``b``; β is static config.  Returns (p', Δ', B') with all
    three state buffers donated.
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    s3 = _grid_specs(w, r, c, block, 3)
    xb_spec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fused_sync_bvr_kernel, beta=beta),
        grid=(w, r // block),
        in_specs=[s3[0], xb_spec, s3[1], s3[2], _scal_spec(1)],
        out_specs=[s3[0], s3[0], s3[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), d.dtype),
                   jax.ShapeDtypeStruct((w, r, c), b.dtype)],
        input_output_aliases={0: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(p, xbar, d, b, scal)


# ====================================================== overlapped-round fold
# The overlapped round issues its sync all-reduce at round START over the
# positions every worker TRANSMITTED at the previous boundary (the ``pend``
# buffer of ``core.types.OverlapState``), so the collective runs concurrently
# with the round's local steps.  These kernels apply the one-round-stale
# result at round END, in one HBM pass:
#
#   c_i = x̂_stale − pend_i        the stale correction toward the mean
#   p'  = p + c_i                  fold into the live (scanned) params
#   Δ'  = Δ + c_i / (pend_k_i γ)   eq. 4 over the period pend covers
#   pend'_i = km_i·pend_i + (1−km_i)·p'     capture for the NEXT collective
#
# Σ_i c_i = 0, so the worker-mean trajectory is untouched and ΣΔ stays 0.
# ``wscal`` is a per-worker (W, 2) fp32 operand: column 0 = 1/(pend_k_i·γ)
# (pend_k differs per worker once deadlines are missed), column 1 = km_i,
# the miss mask (1 ⇒ the worker missed the capture deadline and keeps its
# last transmitted position; its shortfall transmits whole next time).
# ``capture=False`` drops the pend' output — the compressed-sync path
# captures outside the kernel via the EF round-trip instead.

def _wscal_spec(n: int):
    """(1, n) per-worker row of a (W, n) operand, one row per grid worker."""
    return pl.BlockSpec((1, n), lambda wi, i: (wi, 0))


def _fold_overlap_kernel(*refs, use_delta: bool, use_bias: bool,
                         beta: float, capture: bool):
    p_ref, xb_ref, pend_ref = refs[0], refs[1], refs[2]
    i = 3
    d_ref = b_ref = None
    if use_delta:
        d_ref = refs[i]
        i += 1
    if use_bias:
        b_ref = refs[i]
        i += 1
    s_ref = refs[i]
    outs = list(refs[i + 1:])
    pend = _f32(pend_ref)
    c = _f32(xb_ref)[None] - pend    # stale correction x̂_stale − pend_i
    pnew = _f32(p_ref) + c
    po_ref = outs.pop(0)
    po_ref[...] = pnew.astype(po_ref.dtype)
    if use_delta:
        inv = s_ref[0, 0]            # 1/(pend_k_i · γ)
        do_ref = outs.pop(0)
        do_ref[...] = (_f32(d_ref) + c * inv).astype(do_ref.dtype)
    if use_bias:
        inv = s_ref[0, 0]
        bo_ref = outs.pop(0)
        bo_ref[...] = ((1.0 - beta) * _f32(b_ref) + beta * c * inv
                       ).astype(bo_ref.dtype)
    if capture:
        km = s_ref[0, 1]             # 1 ⇒ missed deadline: keep old pend
        pendo_ref = outs.pop(0)
        pendo_ref[...] = (km * pend + (1.0 - km) * pnew
                          ).astype(pendo_ref.dtype)


def _fold_call(p, xbar, pend, d, b, wscal, *, beta, capture, block,
               interpret):
    """Shared pallas_call builder for the flat overlapped-round folds."""
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta, use_bias = d is not None, b is not None
    ins = ((p, xbar, pend) + ((d,) if use_delta else ())
           + ((b,) if use_bias else ()))
    n3 = len(ins) - 1               # (W, R, C) operands (all but xbar)
    s3 = _grid_specs(w, r, c, block, n3)
    xb_spec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    in_specs = [s3[0], xb_spec] + s3[1:] + [_wscal_spec(2)]
    n_out = 1 + use_delta + use_bias + capture
    out_shape = [jax.ShapeDtypeStruct((w, r, c), p.dtype)]
    if use_delta:
        out_shape.append(jax.ShapeDtypeStruct((w, r, c), d.dtype))
    if use_bias:
        out_shape.append(jax.ShapeDtypeStruct((w, r, c), b.dtype))
    if capture:
        out_shape.append(jax.ShapeDtypeStruct((w, r, c), pend.dtype))
    # donate every state buffer onto its output: p→p', Δ→Δ', B→B',
    # pend→pend' (operand index: xbar sits at 1, pend at 2)
    aliases = {0: 0}
    oi = 1
    if use_delta:
        aliases[3] = oi
        oi += 1
    if use_bias:
        aliases[3 + use_delta] = oi
        oi += 1
    if capture:
        aliases[2] = oi
    return pl.pallas_call(
        functools.partial(_fold_overlap_kernel, use_delta=use_delta,
                          use_bias=use_bias, beta=beta, capture=capture),
        grid=(w, r // block),
        in_specs=in_specs,
        out_specs=[s3[0]] * n_out,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*ins, wscal)


def fused_fold_overlap(p, xbar, pend, d, wscal, *, capture: bool = True,
                       block: int = 1024, interpret=None):
    """Stale-sync fold for the VRL algorithms, one pass over (W, R, C).

      c = x̂_stale − pend;  p' = p + c;  Δ' = Δ + c/(pend_k γ);
      pend' = km·pend + (1−km)·p'

    ``xbar``: (R, C) — the round-start all-reduce over pend (stale by one
    round).  ``wscal``: (W, 2) fp32 [1/(pend_k_i·γ), km_i] per worker.
    Returns (p', Δ', pend'), all donated; ``capture=False`` returns
    (p', Δ') and leaves the capture to the caller (compressed sync).
    """
    return _fold_call(p, xbar, pend, d, None, wscal, beta=0.0,
                      capture=capture, block=block, interpret=interpret)


def fused_fold_overlap_bvr(p, xbar, pend, d, b, wscal, *, beta: float,
                           capture: bool = True, block: int = 1024,
                           interpret=None):
    """BVR-L-SGD stale fold: the VRL fold plus the bias-variate EMA
    B' = (1−β)B + β·c/(pend_k γ).  Returns (p', Δ', B'[, pend'])."""
    return _fold_call(p, xbar, pend, d, b, wscal, beta=beta,
                      capture=capture, block=block, interpret=interpret)


def fused_fold_overlap_avg(p, xbar, pend, wscal, *, capture: bool = True,
                           block: int = 1024, interpret=None):
    """Average-sync stale fold (local_sgd / stl_sgd): p' = p + c only —
    no Δ.  Returns (p'[, pend'])."""
    return _fold_call(p, xbar, pend, None, None, wscal, beta=0.0,
                      capture=capture, block=block, interpret=interpret)


def _fold_overlap_hier2_kernel(*refs, capture: bool):
    p_ref, g_ref, pend_ref, d2_ref, s_ref = refs[:5]
    po_ref, do_ref = refs[5], refs[6]
    pend = _f32(pend_ref)
    c = _f32(g_ref)[None] - pend     # stale cross-pod correction per pod
    pnew = _f32(p_ref) + c
    po_ref[...] = pnew.astype(po_ref.dtype)
    inv = s_ref[0, 0]                # 1/(pend_k2_p · γ)
    do_ref[...] = (_f32(d2_ref) + c * inv).astype(do_ref.dtype)
    if capture:
        km = s_ref[0, 1]
        pendo_ref = refs[7]
        pendo_ref[...] = (km * pend + (1.0 - km) * pnew
                          ).astype(pendo_ref.dtype)


def fused_fold_overlap_hier2(p, glob, pend2, d2, wscal, *,
                             capture: bool = True, block: int = 1024,
                             interpret=None):
    """Level-2 stale fold: c = x̂_stale − pend2_p folded into every worker
    of pod p, Δ2' = Δ2 + c/(pend_k2 γ), pend2' captured per pod.

    Assumes a level-1 sync at the same step (like ``fused_sync_hier2``),
    so every worker's folded params equal its pod average and the per-pod
    outputs are well-defined.  ``glob``: (R, C) stale cross-pod mean;
    ``wscal``: (P, 2).  The intra-pod grid dim is innermost; the D
    revisits of each Δ2'/pend2' block write identical values, so those
    buffers are NOT donated (aliasing would feed revisit di+1 the
    already-updated block).  Returns (p', Δ2'[, pend2']) with p donated.
    """
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    wspec = pl.BlockSpec((1, 1, block, c), lambda pi, i, di: (pi, di, i, 0))
    podspec = pl.BlockSpec((1, 1, block, c), lambda pi, i, di: (pi, 0, i, 0))
    gspec = pl.BlockSpec((block, c), lambda pi, i, di: (i, 0))
    sspec = pl.BlockSpec((1, 2), lambda pi, i, di: (pi, 0))
    out_specs = [wspec, podspec] + ([podspec] if capture else [])
    out_shape = [jax.ShapeDtypeStruct(p.shape, p.dtype),
                 jax.ShapeDtypeStruct(d2.shape, d2.dtype)] \
        + ([jax.ShapeDtypeStruct(pend2.shape, pend2.dtype)]
           if capture else [])
    return pl.pallas_call(
        functools.partial(_fold_overlap_hier2_kernel, capture=capture),
        grid=(pp, r // block, dd),
        in_specs=[wspec, gspec, podspec, podspec, sspec],
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={0: 0},
        interpret=interpret,
    )(p, glob, pend2, d2, wscal)


def _easgd_worker_kernel(p_ref, c_ref, po_ref, *, a: float):
    p = _f32(p_ref)
    c = _f32(c_ref)[None]       # (block, C) broadcast over the worker dim
    po_ref[...] = (p - a * (p - c)).astype(po_ref.dtype)


def _easgd_center_kernel(c_ref, xb_ref, co_ref, *, na: float):
    co_ref[...] = ((1.0 - na) * _f32(c_ref)
                   + na * _f32(xb_ref)).astype(co_ref.dtype)


def fused_sync_easgd(p, xbar, center, *, a: float, na: float,
                     block: int = 1024, interpret=None):
    """Elastic sync (Zhang et al.) fused on flat buffers; returns (p', c').

      p' = p − a·(p − x̃)            a  = easgd_alpha / N
      c' = (1 − N·a)·x̃ + N·a·x̂     na = N·a

    ``p``: (W, R, C); ``xbar``/``center``: (R, C) fp32 (x̂ is the worker
    mean — THE all-reduce — computed by the caller before this pass).  Two
    single-pass kernels so both p and x̃ can be donated; the p' pass reads
    the OLD center, so XLA's alias analysis orders it before (or copies
    around) the in-place center update.
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    pspec = _grid_specs(w, r, c, block, 1)[0]
    cspec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    new_p = pl.pallas_call(
        functools.partial(_easgd_worker_kernel, a=a),
        grid=(w, r // block),
        in_specs=[pspec, cspec],
        out_specs=pspec,
        out_shape=jax.ShapeDtypeStruct((w, r, c), p.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(p, center)
    flat2 = pl.BlockSpec((block, c), lambda i: (i, 0))
    new_c = pl.pallas_call(
        functools.partial(_easgd_center_kernel, na=na),
        grid=(r // block,),
        in_specs=[flat2, flat2],
        out_specs=flat2,
        out_shape=jax.ShapeDtypeStruct((r, c), center.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(center, xbar)
    return new_p, new_c


# ==================================================== compressed-sync kernels
# EF round-trips of the sync payload's drift (repro.comm): one HBM pass
# builds payload = p − ref + resid, quantizes / sparsifies it, and emits the
# decompressed payload (what the single flat all-reduce then carries) plus
# the new error-feedback residual, with the residual donated in place.  Row
# statistics (the int8 per-row scale, the top-k per-row threshold) stay
# entirely inside one (block, C) tile because tiles split rows, never lanes.
#
# The math mirrors ``repro.comm.compressors.ef_int8`` / ``ef_topk`` exactly
# (same formulas, fp32 in-register) so the three executors agree; the wire
# REPRESENTATION (int8 values + scales / fixed-k values + indices) is built
# by ``repro.comm.compressors.compress`` for byte measurement — the engine hot
# path only ever needs the decompressed payload and the residual.
#
# Note on top-k selection: the kernel body uses ``jax.lax.top_k`` over the
# lane axis for the per-row threshold (kth magnitude).  Interpret mode
# (CPU) executes it directly; on compiled TPU backends a Mosaic without
# lane-axis top_k support would need a bitonic network here — the jnp
# executor (``kernels/xla_update``) is the drop-in fallback either way.

def _ef_kernel(*refs, mode: str, k: int, use_ref: bool, use_ef: bool):
    # the round-trip math is the CANONICAL repro.comm implementation —
    # its jnp ops trace inside the kernel body, so the executors cannot
    # drift apart formula-wise
    x = _f32(refs[0])
    i = 1
    if use_ref:
        x = x - _f32(refs[i])
        i += 1
    if use_ef:
        x = x + _f32(refs[i])
        i += 1
    dec, resid = (cc.ef_int8(x) if mode == "int8" else cc.ef_topk(x, k))
    dec_ref = refs[i]
    dec_ref[...] = dec.astype(dec_ref.dtype)
    if use_ef:
        eo_ref = refs[i + 1]
        eo_ref[...] = resid.astype(eo_ref.dtype)


def _ef_call(p, ref, e, *, mode: str, k: int, block: int, interpret,
             grid_kind: str):
    """Shared pallas_call builder for the flat (W, R, C) and pod-major
    (P, D, R, C) EF round-trips.  Returns (dec fp32, resid' | None); the
    residual aliases its input buffer (donated in place)."""
    if interpret is None:
        interpret = default_interpret()
    use_ref, use_ef = ref is not None, e is not None
    c = p.shape[-1]
    if grid_kind == "flat":
        w, r, _ = p.shape
        grid = (w, r // block)
        wspec = pl.BlockSpec((1, block, c), lambda wi, i: (wi, i, 0))
        # shared (R, C) reference: every worker's step reads the same tile
        rspec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    else:
        pp, dd, r, _ = p.shape
        grid = (pp, dd, r // block)
        wspec = pl.BlockSpec((1, 1, block, c),
                             lambda pi, di, i: (pi, di, i, 0))
        # per-pod (P, 1, R, C) reference: broadcast over the intra-pod dim
        rspec = pl.BlockSpec((1, 1, block, c),
                             lambda pi, di, i: (pi, 0, i, 0))
    ins = (p,) + ((ref,) if use_ref else ()) + ((e,) if use_ef else ())
    in_specs = [wspec] + ([rspec] if use_ref else []) \
        + ([wspec] if use_ef else [])
    out_specs = [wspec] + ([wspec] if use_ef else [])
    out_shape = [jax.ShapeDtypeStruct(p.shape, jnp.float32)] \
        + ([jax.ShapeDtypeStruct(e.shape, e.dtype)] if use_ef else [])
    aliases = {len(ins) - 1: 1} if use_ef else {}
    out = pl.pallas_call(
        functools.partial(_ef_kernel, mode=mode, k=k, use_ref=use_ref,
                          use_ef=use_ef),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*ins)
    if use_ef:
        return out[0], out[1]
    return out[0], None


def fused_ef_int8(p, ref, e, *, block: int = 1024, interpret=None):
    """Per-row-scaled int8 EF round-trip on (W, R, C) buffers.

    ``ref``: (R, C) shared drift reference or None (S-SGD gradient
    compression); ``e``: (W, R, C) error-feedback residual or None.
    Returns (decompressed payload fp32, resid'), resid' donated in place
    and None when ``e`` is None.
    """
    return _ef_call(p, ref, e, mode="int8", k=0, block=block,
                    interpret=interpret, grid_kind="flat")


def fused_ef_topk(p, ref, e, *, k: int, block: int = 1024, interpret=None):
    """Top-k (k lanes kept per row) EF round-trip on (W, R, C) buffers;
    same operand contract as ``fused_ef_int8``."""
    return _ef_call(p, ref, e, mode="topk", k=k, block=block,
                    interpret=interpret, grid_kind="flat")


def fused_ef_int8_grid(p, ref, e, *, block: int = 1024, interpret=None):
    """Pod-major twin: p/e (P, D, R, C), ref (P, 1, R, C) per-pod
    reference whose blocks broadcast over the intra-pod grid dim."""
    return _ef_call(p, ref, e, mode="int8", k=0, block=block,
                    interpret=interpret, grid_kind="grid")


def fused_ef_topk_grid(p, ref, e, *, k: int, block: int = 1024,
                       interpret=None):
    return _ef_call(p, ref, e, mode="topk", k=k, block=block,
                    interpret=interpret, grid_kind="grid")


# ================================================== hierarchical (P, D, R, C)
# Pod-major worker-grid kernels for the two-level engine.  Grid =
# (P, D, R/block); per-worker buffers stream as (1, 1, block, C) tiles while
# the per-pod Δ2 / pod-average tiles are broadcast over the intra-pod grid
# dim by their index map (one HBM read, no (P, D)-sized materialization).

def _grid4_specs(block: int, c: int, n: int):
    return [pl.BlockSpec((1, 1, block, c), lambda pi, di, i: (pi, di, i, 0))
            for _ in range(n)]


def _pod4_spec(block: int, c: int):
    """(P, 1, R, C) operand: every worker in pod pi reads block (pi, 0, i)."""
    return pl.BlockSpec((1, 1, block, c), lambda pi, di, i: (pi, 0, i, 0))


def _scal4_spec(n: int):
    return pl.BlockSpec((1, n), lambda pi, di, i: (0, 0))


def _hier_sgd_kernel(p_ref, g_ref, d1_ref, d2_ref, o_ref, *, lr, wd):
    v = _f32(g_ref) - _f32(d1_ref) - _f32(d2_ref)
    p = _f32(p_ref)
    if wd:
        v = v + wd * p
    o_ref[...] = (p - lr * v).astype(o_ref.dtype)


def fused_hier_local_sgd(p, g, d1, d2, *, lr: float, wd: float = 0.0,
                         block: int = 1024, interpret=None):
    """p' = p − γ((g − Δ1 − Δ2) + wd·p) on (P, D, R, C) buffers."""
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    specs = _grid4_specs(block, c, 3)
    return pl.pallas_call(
        functools.partial(_hier_sgd_kernel, lr=lr, wd=wd),
        grid=(pp, dd, r // block),
        in_specs=[specs[0], specs[1], specs[2], _pod4_spec(block, c)],
        out_specs=specs[0],
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(p, g, d1, d2)


def _hier_momentum_kernel(p_ref, g_ref, d1_ref, d2_ref, m_ref, po_ref,
                          mo_ref, *, lr, beta, wd, nesterov):
    v = _f32(g_ref) - _f32(d1_ref) - _f32(d2_ref)
    p = _f32(p_ref)
    if wd:
        v = v + wd * p
    m_new = beta * _f32(m_ref) + v
    step_dir = v + beta * m_new if nesterov else m_new
    po_ref[...] = (p - lr * step_dir).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)


def fused_hier_local_momentum(p, g, d1, d2, m, *, lr: float, beta: float,
                              wd: float = 0.0, nesterov: bool = False,
                              block: int = 1024, interpret=None):
    """Momentum inner step with both Δ corrections; returns (p', m')."""
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    specs = _grid4_specs(block, c, 4)
    return pl.pallas_call(
        functools.partial(_hier_momentum_kernel, lr=lr, beta=beta, wd=wd,
                          nesterov=nesterov),
        grid=(pp, dd, r // block),
        in_specs=[specs[0], specs[1], specs[2], _pod4_spec(block, c),
                  specs[3]],
        out_specs=[specs[0], specs[3]],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(m.shape, m.dtype)],
        input_output_aliases={0: 0, 4: 1},
        interpret=interpret,
    )(p, g, d1, d2, m)


def _hier_adam_kernel(p_ref, g_ref, d1_ref, d2_ref, mu_ref, nu_ref, s_ref,
                      po, muo, nuo, *, lr, b1, b2, eps, wd):
    v = _f32(g_ref) - _f32(d1_ref) - _f32(d2_ref)
    p = _f32(p_ref)
    c1 = s_ref[0, 0]
    c2 = s_ref[0, 1]
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * v
    nu = b2 * _f32(nu_ref) + (1.0 - b2) * v * v
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p
    po[...] = (p - step).astype(po.dtype)
    muo[...] = mu.astype(muo.dtype)
    nuo[...] = nu.astype(nuo.dtype)


def fused_hier_local_adam(p, g, d1, d2, mu, nu, scal, *, lr: float,
                          b1: float = 0.9, b2: float = 0.999,
                          eps: float = 1e-8, wd: float = 0.0,
                          block: int = 1024, interpret=None):
    """Adam inner step with both Δ corrections; returns (p', mu', nu')."""
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    specs = _grid4_specs(block, c, 5)
    return pl.pallas_call(
        functools.partial(_hier_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                          wd=wd),
        grid=(pp, dd, r // block),
        in_specs=[specs[0], specs[1], specs[2], _pod4_spec(block, c),
                  specs[3], specs[4], _scal4_spec(2)],
        out_specs=[specs[0], specs[3], specs[4]],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(mu.shape, mu.dtype),
                   jax.ShapeDtypeStruct(nu.shape, nu.dtype)],
        input_output_aliases={0: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(p, g, d1, d2, mu, nu, scal)


def _hier_adam_sm3_kernel(p_ref, g_ref, d1_ref, d2_ref, mu_ref, row_ref,
                          col_ref, s_ref, po, muo, rowo, colo, *, lr, b1,
                          b2, eps, wd, tps):
    """Pod-major SM3 Adam — same factored construction as
    ``_fused_adam_sm3_kernel`` with v = g − Δ1 − Δ2; the innermost grid
    dim is the row tile, so the lane stat's ``tps`` revisits stay
    consecutive (col NOT donated, same aliasing hazard)."""
    v = _f32(g_ref) - _f32(d1_ref) - _f32(d2_ref)
    p = _f32(p_ref)
    c1 = s_ref[0, 0]
    c2 = s_ref[0, 1]
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * v
    vhat = jnp.minimum(_f32(row_ref), _f32(col_ref))
    nu = b2 * vhat + (1.0 - b2) * v * v
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p
    po[...] = (p - step).astype(po.dtype)
    muo[...] = mu.astype(muo.dtype)
    rowo[...] = jnp.max(nu, axis=-1, keepdims=True).astype(rowo.dtype)
    tile_col = jnp.max(nu, axis=-2, keepdims=True).astype(colo.dtype)
    first = (pl.program_id(2) % tps) == 0

    @pl.when(first)
    def _init():
        colo[...] = tile_col

    @pl.when(jnp.logical_not(first))
    def _acc():
        colo[...] = jnp.maximum(colo[...], tile_col)


def fused_hier_local_adam_sm3(p, g, d1, d2, mu, row, col, scal, *,
                              lr: float, b1: float = 0.9, b2: float = 0.999,
                              eps: float = 1e-8, wd: float = 0.0,
                              block: int = 1024, interpret=None):
    """SM3-factored Adam with both Δ corrections on (P, D, R, C) buffers.

    ``row``: (P, D, R, 1); ``col``: (P, D, S, C) per-shard lane stats.
    Returns (p', mu', row', col'); p/mu/row donated, col not.
    """
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    shards = col.shape[-2]
    assert (r // block) % shards == 0, (r, block, shards)
    tps = (r // block) // shards
    specs = _grid4_specs(block, c, 4)
    row_spec = pl.BlockSpec((1, 1, block, 1),
                            lambda pi, di, i: (pi, di, i, 0))
    col_spec = pl.BlockSpec((1, 1, 1, c),
                            lambda pi, di, i: (pi, di, i // tps, 0))
    return pl.pallas_call(
        functools.partial(_hier_adam_sm3_kernel, lr=lr, b1=b1, b2=b2,
                          eps=eps, wd=wd, tps=tps),
        grid=(pp, dd, r // block),
        in_specs=[specs[0], specs[1], specs[2], _pod4_spec(block, c),
                  specs[3], row_spec, col_spec, _scal4_spec(2)],
        out_specs=[specs[0], specs[3], row_spec, col_spec],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(mu.shape, mu.dtype),
                   jax.ShapeDtypeStruct(row.shape, jnp.float32),
                   jax.ShapeDtypeStruct(col.shape, jnp.float32)],
        input_output_aliases={0: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(p, g, d1, d2, mu, row, col, scal)


def _hier_sync1_kernel(p_ref, xb_ref, d_ref, s_ref, po_ref, do_ref):
    p = _f32(p_ref)
    xb = _f32(xb_ref)
    kg = s_ref[0, 0]            # k1_eff · γ  (k1_eff is traced)
    do_ref[...] = (_f32(d_ref) + (xb - p) / kg).astype(do_ref.dtype)
    po_ref[...] = xb.astype(po_ref.dtype)


def fused_sync_hier1(p, xbar_pod, d1, scal, *, block: int = 1024,
                     interpret=None):
    """Level-1 (intra-pod) sync: Δ1' = Δ1 + (x̂_pod − p)/(k1γ); p' = x̂_pod.

    ``xbar_pod``: (P, 1, R, C) — the pod average the caller produced with
    the single intra-pod all-reduce.  One pass over (P, D, R, C); p and Δ1
    are donated.  Returns (p', Δ1').
    """
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    specs = _grid4_specs(block, c, 2)
    return pl.pallas_call(
        _hier_sync1_kernel,
        grid=(pp, dd, r // block),
        in_specs=[specs[0], _pod4_spec(block, c), specs[1], _scal4_spec(1)],
        out_specs=[specs[0], specs[1]],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(d1.shape, d1.dtype)],
        input_output_aliases={0: 0, 2: 1},
        interpret=interpret,
    )(p, xbar_pod, d1, scal)


def _hier_sync2_kernel(p_ref, g_ref, d2_ref, s_ref, po_ref, do_ref):
    pod = _f32(p_ref)           # own params == pod average (post level-1)
    glob = _f32(g_ref)[None]
    kg = s_ref[0, 0]            # k2_eff · γ
    do_ref[...] = (_f32(d2_ref) + (glob - pod) / kg).astype(do_ref.dtype)
    po_ref[...] = jnp.broadcast_to(glob, po_ref.shape).astype(po_ref.dtype)


def fused_sync_hier2(p, glob, d2, scal, *, block: int = 1024,
                     interpret=None):
    """Level-2 (cross-pod) sync: Δ2' = Δ2 + (x̂ − x̂_pod)/(k2γ); p' = x̂.

    Assumes a level-1 sync at the same step, so every worker's params ARE
    its pod average — each grid step reads its OWN (pi, di) block as x̂_pod
    (never a block another step may have overwritten in-place).  ``glob``:
    (R, C) — produced by the caller's single cross-pod all-reduce.  The
    intra-pod grid dim is innermost so the D revisits of each Δ2' block are
    consecutive; every revisit writes the same value (Δ2 itself is NOT
    donated — aliasing it would feed step di+1 the already-updated block).
    Returns (p', Δ2') with p donated.
    """
    if interpret is None:
        interpret = default_interpret()
    pp, dd, r, c = p.shape
    wspec = pl.BlockSpec((1, 1, block, c), lambda pi, i, di: (pi, di, i, 0))
    podspec = pl.BlockSpec((1, 1, block, c), lambda pi, i, di: (pi, 0, i, 0))
    gspec = pl.BlockSpec((block, c), lambda pi, i, di: (i, 0))
    return pl.pallas_call(
        _hier_sync2_kernel,
        grid=(pp, r // block, dd),
        in_specs=[wspec, gspec, podspec, _scal4_spec(1)],
        out_specs=[wspec, podspec],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(d2.shape, d2.dtype)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(p, glob, d2, scal)
