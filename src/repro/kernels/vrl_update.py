"""Fused VRL-SGD update kernels (the paper's eq. 4-6 as single HBM passes).

The paper's math is elementwise over model-sized buffers, so on TPU it is
purely HBM-bandwidth-bound. Unfused, the local step reads p, g, Δ and writes
v then p (5 model-sized transfers); the fused kernel reads 3 and writes 1.
The sync step fuses the Δ update with the parameter broadcast the same way.

  local:  p' = p − γ·(g − Δ)                          (eq. 5 + 6)
  sync:   Δ' = Δ + (x̂ − p)/(kγ);  p' = x̂             (eq. 4 + line 6)

Two families live here:

  * ``vrl_local_update`` / ``vrl_sync_update`` — the original per-leaf 2D
    tile kernels (used by ``ops.py``'s tree wrappers and their tests).
  * ``fused_local_{sgd,momentum,adam}`` / ``fused_sync_vrl`` — the engine's
    worker-stacked (W, R, C) kernels.  One grid step per (worker, row-tile);
    the inner-optimizer moment update is fused into the same HBM pass, and
    dynamic scalars (Adam bias correction, the sync-time k_eff·γ) ride in as
    a tiny (1, n) operand so the compiled kernel never retraces per step.
    All math is fp32 in-register with per-buffer output casts, matching the
    reference tree path bit-for-bit in fp32.

``block``/``interpret`` come from the engine config (``configs.base
.EngineConfig``); the (R, C) layout and auto block choice from
``core/flat.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    """Interpret-mode (python body) everywhere but real TPU backends."""
    return jax.default_backend() != "tpu"


def _local_kernel(p_ref, g_ref, d_ref, o_ref, *, lr: float):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    o_ref[...] = (p - lr * (g - d)).astype(o_ref.dtype)


def _sync_kernel(p_ref, xbar_ref, d_ref, po_ref, do_ref, *, inv_kg: float):
    p = p_ref[...].astype(jnp.float32)
    xb = xbar_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    do_ref[...] = (d + (xb - p) * inv_kg).astype(do_ref.dtype)
    po_ref[...] = xb.astype(po_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "block", "interpret"))
def vrl_local_update(p: jax.Array, g: jax.Array, delta: jax.Array, *,
                     lr: float, block: int = 1024,
                     interpret: bool = True) -> jax.Array:
    """p, g, delta: (R, C) with R % block == 0 -> updated p."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_local_kernel, lr=lr),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), p.dtype),
        interpret=interpret,
    )(p, g, delta)


@functools.partial(jax.jit, static_argnames=("inv_kg", "block", "interpret"))
def vrl_sync_update(p: jax.Array, xbar: jax.Array, delta: jax.Array, *,
                    inv_kg: float, block: int = 1024,
                    interpret: bool = True):
    """Returns (p', Δ')."""
    r, c = p.shape
    assert r % block == 0, (r, block)
    spec = pl.BlockSpec((block, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sync_kernel, inv_kg=inv_kg),
        grid=(r // block,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), p.dtype),
                   jax.ShapeDtypeStruct((r, c), delta.dtype)],
        interpret=interpret,
    )(p, xbar, delta)


# ===================================================================== engine
# Worker-stacked (W, R, C) kernels for core/engine.py.  Grid = (W, R/block);
# every buffer streams through VMEM exactly once per step.

def _grid_specs(w: int, r: int, c: int, block: int, n: int):
    """n identical (1, block, C) specs over a (W, R/block) grid."""
    del w, r
    return [pl.BlockSpec((1, block, c), lambda wi, i: (wi, i, 0))
            for _ in range(n)]


def _scal_spec(n: int):
    """(1, n) fp32 dynamic-scalar operand, same tile for every grid step."""
    return pl.BlockSpec((1, n), lambda wi, i: (0, 0))


def _f32(ref):
    return ref[...].astype(jnp.float32)


def _fused_sgd_kernel(*refs, lr, wd, use_delta):
    if use_delta:
        p_ref, g_ref, d_ref, o_ref = refs
        v = _f32(g_ref) - _f32(d_ref)
    else:
        p_ref, g_ref, o_ref = refs
        v = _f32(g_ref)
    p = _f32(p_ref)
    if wd:
        v = v + wd * p
    o_ref[...] = (p - lr * v).astype(o_ref.dtype)


def fused_local_sgd(p, g, d=None, *, lr: float, wd: float = 0.0,
                    block: int = 1024, interpret=None):
    """p' = p − γ((g − Δ) + wd·p) on (W, R, C) buffers.  d=None ⇒ Δ ≡ 0."""
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta = d is not None
    ins = (p, g, d) if use_delta else (p, g)
    specs = _grid_specs(w, r, c, block, len(ins))
    return pl.pallas_call(
        functools.partial(_fused_sgd_kernel, lr=lr, wd=wd,
                          use_delta=use_delta),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=specs[0],
        out_shape=jax.ShapeDtypeStruct((w, r, c), p.dtype),
        interpret=interpret,
    )(*ins)


def _fused_momentum_kernel(*refs, lr, beta, wd, nesterov, use_delta):
    if use_delta:
        p_ref, g_ref, d_ref, m_ref, po_ref, mo_ref = refs
        v = _f32(g_ref) - _f32(d_ref)
    else:
        p_ref, g_ref, m_ref, po_ref, mo_ref = refs
        v = _f32(g_ref)
    p = _f32(p_ref)
    if wd:
        v = v + wd * p
    m_new = beta * _f32(m_ref) + v
    step_dir = v + beta * m_new if nesterov else m_new
    po_ref[...] = (p - lr * step_dir).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)


def fused_local_momentum(p, g, d, m, *, lr: float, beta: float,
                         wd: float = 0.0, nesterov: bool = False,
                         block: int = 1024, interpret=None):
    """Momentum inner step fused with the Δ correction; returns (p', m')."""
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta = d is not None
    ins = (p, g, d, m) if use_delta else (p, g, m)
    specs = _grid_specs(w, r, c, block, len(ins))
    return pl.pallas_call(
        functools.partial(_fused_momentum_kernel, lr=lr, beta=beta, wd=wd,
                          nesterov=nesterov, use_delta=use_delta),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=[specs[0], specs[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), m.dtype)],
        interpret=interpret,
    )(*ins)


def _fused_adam_kernel(*refs, lr, b1, b2, eps, wd, use_delta):
    if use_delta:
        p_ref, g_ref, d_ref, mu_ref, nu_ref, s_ref, po, muo, nuo = refs
        v = _f32(g_ref) - _f32(d_ref)
    else:
        p_ref, g_ref, mu_ref, nu_ref, s_ref, po, muo, nuo = refs
        v = _f32(g_ref)
    p = _f32(p_ref)
    c1 = s_ref[0, 0]    # 1 − b1^t  (dynamic: depends on the step count)
    c2 = s_ref[0, 1]    # 1 − b2^t
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * v
    nu = b2 * _f32(nu_ref) + (1.0 - b2) * v * v
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p
    po[...] = (p - step).astype(po.dtype)
    muo[...] = mu.astype(muo.dtype)
    nuo[...] = nu.astype(nuo.dtype)


def fused_local_adam(p, g, d, mu, nu, scal, *, lr: float, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0,
                     block: int = 1024, interpret=None):
    """Adam inner step fused with the Δ correction.

    ``scal``: (1, 2) fp32 = [1 − b1^t, 1 − b2^t] (bias-correction terms are
    traced values, so they enter as data, not as static compile-time args).
    Returns (p', mu', nu').
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    use_delta = d is not None
    ins = (p, g, d, mu, nu) if use_delta else (p, g, mu, nu)
    specs = _grid_specs(w, r, c, block, len(ins)) + [_scal_spec(2)]
    return pl.pallas_call(
        functools.partial(_fused_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                          wd=wd, use_delta=use_delta),
        grid=(w, r // block),
        in_specs=specs,
        out_specs=[specs[0], specs[0], specs[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), mu.dtype),
                   jax.ShapeDtypeStruct((w, r, c), nu.dtype)],
        interpret=interpret,
    )(*ins, scal)


def _fused_sync_kernel(p_ref, xb_ref, d_ref, s_ref, po_ref, do_ref):
    p = _f32(p_ref)
    xb = _f32(xb_ref)[None]     # (block, C) broadcast over the worker dim
    kg = s_ref[0, 0]            # k_eff · γ  (k_eff is traced)
    do_ref[...] = (_f32(d_ref) + (xb - p) / kg).astype(do_ref.dtype)
    po_ref[...] = jnp.broadcast_to(xb, po_ref.shape).astype(po_ref.dtype)


def fused_sync_vrl(p, xbar, d, scal, *, block: int = 1024, interpret=None):
    """Δ' = Δ + (x̂ − p)/(k_eff γ); p' = x̂ — one pass, (W, R, C) buffers.

    ``xbar``: (R, C) — each worker's grid step reads the same x̂ tile, so the
    broadcast never materializes W copies in HBM.  ``scal``: (1, 1) fp32
    holding k_eff·γ (division matches the reference path's rounding exactly).
    Returns (p', Δ').
    """
    if interpret is None:
        interpret = default_interpret()
    w, r, c = p.shape
    s3 = _grid_specs(w, r, c, block, 2)
    xb_spec = pl.BlockSpec((block, c), lambda wi, i: (i, 0))
    return pl.pallas_call(
        _fused_sync_kernel,
        grid=(w, r // block),
        in_specs=[s3[0], xb_spec, s3[1], _scal_spec(1)],
        out_specs=[s3[0], s3[0]],
        out_shape=[jax.ShapeDtypeStruct((w, r, c), p.dtype),
                   jax.ShapeDtypeStruct((w, r, c), d.dtype)],
        interpret=interpret,
    )(p, xbar, d, scal)
