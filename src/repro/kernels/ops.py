"""jit'd user-facing wrappers around the Pallas kernels.

These handle layout munging (head flattening, padding to block multiples,
pytree flattening for the optimizer kernels) so callers use natural shapes.
``interpret`` defaults to True on CPU (kernel body runs in Python for
correctness validation) and False on TPU.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssd
from repro.kernels import vrl_update as vu


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ attention op
def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              block_q: int = 128, block_k: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KVH, D) -> (B, S, H, D).

    Repeats kv heads to match q (GQA) and pads S to a block multiple.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    blk = math.gcd(block_q, block_k)
    pad = (-s) % max(block_q, block_k)
    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad), (0, 0)))
    out = fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    out = out[:, :s].reshape(b, h, s, d)
    return jnp.moveaxis(out, 1, 2)


# ------------------------------------------------------------------ ssd op
def ssd_chunk_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                   b: jax.Array, c: jax.Array, *, chunk: int = 256,
                   interpret: Optional[bool] = None) -> jax.Array:
    """x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b, c: (B, L, N)."""
    if interpret is None:
        interpret = _default_interpret()
    bsz, l, h, p = x.shape
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    xt = jnp.moveaxis(x, 2, 1).reshape(bsz * h, lp, p)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(bsz * h, lp)
    alog = jnp.tile(a_log[None, :], (bsz, 1)).reshape(bsz * h, 1)
    y = ssd.ssd_scan(xt, dtt, alog, b, c, chunk=chunk, num_heads=h,
                     interpret=interpret)
    y = y[:, :l].reshape(bsz, h, l, p)
    return jnp.moveaxis(y, 1, 2)


# ------------------------------------------------- fused optimizer updates
_LANES = 256


def _leaf_tile(n: int, block: int) -> tuple[int, int]:
    """(block, lanes) for an n-element leaf: auto block unless forced.

    Auto mode pads rows toward 1024-row multiples but caps padding waste
    (core.flat.choose_block) — a 4 KiB bias vector no longer pads to a
    megabyte tile the way the old hardcoded block did.
    """
    from repro.core.flat import choose_block
    rows = -(-n // _LANES)
    return (block or choose_block(rows)), _LANES


def _to_2d(x: jax.Array, block: int, c: int = _LANES):
    flat = x.reshape(-1)
    pad = (-flat.size) % (c * block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, c), x.shape, pad


def vrl_local_update_tree(params, grads, delta, *, lr: float,
                          block: int = 0,
                          interpret: Optional[bool] = None):
    """Fused p' = p − γ(g − Δ) over a whole pytree.

    ``block=0`` auto-sizes the per-leaf tile; pass an explicit block (and
    ``interpret``) to pin the layout — both are surfaced through
    ``configs.base.EngineConfig`` for the flat-buffer engine, which is the
    preferred path (one kernel for the whole model instead of one per leaf).
    """
    if interpret is None:
        interpret = _default_interpret()

    def one(p, g, d):
        blk, c = _leaf_tile(p.size, block)
        p2, shp, _ = _to_2d(p, blk, c)
        g2, _, _ = _to_2d(g, blk, c)
        d2, _, _ = _to_2d(d.astype(p.dtype), blk, c)
        out = vu.vrl_local_update(p2, g2, d2, lr=lr, block=blk,
                                  interpret=interpret)
        return out.reshape(-1)[:p.size].reshape(shp)

    return jax.tree.map(one, params, grads, delta)


def vrl_sync_update_tree(params, xbar, delta, *, k: int, lr: float,
                         block: int = 0,
                         interpret: Optional[bool] = None):
    """Fused Δ' = Δ + (x̂−p)/(kγ); p' = x̂ over a whole pytree.

    Tiling as in ``vrl_local_update_tree`` (auto unless ``block`` given).
    """
    if interpret is None:
        interpret = _default_interpret()
    inv_kg = 1.0 / (k * lr)

    def one(p, xb, d):
        blk, c = _leaf_tile(p.size, block)
        p2, shp, _ = _to_2d(p, blk, c)
        x2, _, _ = _to_2d(jnp.broadcast_to(xb, p.shape), blk, c)
        d2, dshp, _ = _to_2d(d, blk, c)
        po, do = vu.vrl_sync_update(p2, x2, d2, inv_kg=inv_kg, block=blk,
                                    interpret=interpret)
        return (po.reshape(-1)[:p.size].reshape(shp),
                do.reshape(-1)[:d.size].reshape(dshp))

    outs = jax.tree.map(one, params, xbar, delta)
    new_p = jax.tree.map(lambda t: t[0], outs,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_d = jax.tree.map(lambda t: t[1], outs,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_d
