"""XLA executor for the engine's fused update math — plain jnp twins of
every ``kernels/vrl_update.fused_*`` Pallas kernel.

The engine's update math is a short chain of elementwise ops over flat
(W, R, C) / (P, D, R, C) buffers.  On TPU the Pallas kernels win by
controlling HBM traffic explicitly; on backends where Pallas would fall
back to interpret mode (CPU today — see ``vrl_update.default_interpret``)
the same chain expressed as jnp is fused by XLA into one loop anyway, with
none of the interpret-mode python-per-block overhead that made the "fused"
default ~30x slower than the reference path (BENCH_engine.json, PR 1-2).

Every function here mirrors its ``vrl_update`` namesake exactly: same
signature (``block``/``interpret`` accepted and ignored so the engine can
dispatch on a module object), same fp32-in-register math, same output
casts.  Parity with the reference tree path is asserted in
``tests/test_engine_parity.py``; round-scan parity in
``tests/test_round_scan.py``.

In-place updates come from the jit boundary instead of
``input_output_aliases``: the round jit donates the state buffers
(``donate_argnums``) and ``lax.scan`` reuses the carry, which XLA lowers
to the same no-copy behaviour the Pallas path gets from kernel aliasing
(asserted on compiled HLO in ``tests/test_round_scan.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.comm import compressors as cc


def _f32(x):
    return x.astype(jnp.float32)


# ================================================== flat (W, R, C) executors
def _corrected(g, d, b):
    """v = g − [Δ] − [B] (same association order as the Pallas kernels)."""
    v = _f32(g)
    if d is not None:
        v = v - _f32(d)
    if b is not None:
        v = v - _f32(b)
    return v


def fused_local_sgd(p, g, d=None, *, lr: float, wd: float = 0.0,
                    block: int = 0, interpret=None, b=None):
    """p' = p − γ((g − Δ − B) + wd·p) on (W, R, C) buffers.

    d=None ⇒ Δ ≡ 0; b (BVR-L-SGD's bias variate) =None ⇒ B ≡ 0."""
    del block, interpret
    v = _corrected(g, d, b)
    p32 = _f32(p)
    if wd:
        v = v + wd * p32
    return (p32 - lr * v).astype(p.dtype)


def fused_local_momentum(p, g, d, m, *, lr: float, beta: float,
                         wd: float = 0.0, nesterov: bool = False,
                         block: int = 0, interpret=None, b=None):
    """Momentum inner step fused with the corrections; returns (p', m')."""
    del block, interpret
    v = _corrected(g, d, b)
    p32 = _f32(p)
    if wd:
        v = v + wd * p32
    m_new = beta * _f32(m) + v
    step_dir = v + beta * m_new if nesterov else m_new
    return (p32 - lr * step_dir).astype(p.dtype), m_new.astype(m.dtype)


def fused_local_adam(p, g, d, mu, nu, scal, *, lr: float, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0,
                     block: int = 0, interpret=None, b=None):
    """Adam inner step fused with the corrections; returns (p', mu', nu').

    ``scal``: (1, 2) fp32 = [1 − b1^t, 1 − b2^t] (traced bias corrections).
    """
    del block, interpret
    v = _corrected(g, d, b)
    p32 = _f32(p)
    c1 = scal[0, 0]
    c2 = scal[0, 1]
    mu_new = b1 * _f32(mu) + (1.0 - b1) * v
    nu_new = b2 * _f32(nu) + (1.0 - b2) * v * v
    step = lr * (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
    if wd:
        step = step + lr * wd * p32
    return ((p32 - step).astype(p.dtype), mu_new.astype(mu.dtype),
            nu_new.astype(nu.dtype))


def _sm3_second_moment(v, row, col, b2):
    """v̂ = min(row, col) rebuilt per element, EMA'd with the fresh g²,
    re-factored into the two stats.  ``row``: (..., R, 1); ``col``:
    (..., S, C) with one lane-stat row per shard's row span.  fp32 max is
    exact and order-free, so the per-span max matches the Pallas kernel's
    tile-by-tile accumulation bitwise."""
    shards = col.shape[-2]
    r = v.shape[-2]
    span = r // shards
    col_b = jnp.repeat(_f32(col), span, axis=-2)     # (..., R, C)
    vhat = jnp.minimum(_f32(row), col_b)
    nu = b2 * vhat + (1.0 - b2) * v * v
    new_row = jnp.max(nu, axis=-1, keepdims=True)
    spanned = nu.reshape(nu.shape[:-2] + (shards, span, nu.shape[-1]))
    new_col = jnp.max(spanned, axis=-2)
    return nu, new_row, new_col


def fused_local_adam_sm3(p, g, d, mu, row, col, scal, *, lr: float,
                         b1: float = 0.9, b2: float = 0.999,
                         eps: float = 1e-8, wd: float = 0.0,
                         block: int = 0, interpret=None, b=None):
    """SM3-factored Adam twin of ``vrl_update.fused_local_adam_sm3``:
    ``row`` (W, R, 1) / ``col`` (W, S, C) fp32 stats replace the dense nu.
    Returns (p', mu', row', col')."""
    del block, interpret
    v = _corrected(g, d, b)
    p32 = _f32(p)
    c1 = scal[0, 0]
    c2 = scal[0, 1]
    mu_new = b1 * _f32(mu) + (1.0 - b1) * v
    nu, new_row, new_col = _sm3_second_moment(v, row, col, b2)
    step = lr * (mu_new / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p32
    return ((p32 - step).astype(p.dtype), mu_new.astype(mu.dtype),
            new_row, new_col)


def fused_sync_vrl(p, xbar, d, scal, *, block: int = 0, interpret=None):
    """Δ' = Δ + (x̂ − p)/(k_eff γ); p' = x̂ on (W, R, C) buffers.

    ``xbar``: (R, C); ``scal``: (1, 1) fp32 holding k_eff·γ.
    Returns (p', Δ').
    """
    del block, interpret
    xb = _f32(xbar)[None]
    kg = scal[0, 0]
    new_d = (_f32(d) + (xb - _f32(p)) / kg).astype(d.dtype)
    new_p = jnp.broadcast_to(xb, p.shape).astype(p.dtype)
    return new_p, new_d


def fused_sync_bvr(p, xbar, d, b, scal, *, beta: float, block: int = 0,
                   interpret=None):
    """BVR-L-SGD sync: the VRL Δ update plus the bias-variate EMA.

      u = (x̂ − p)/(k_eff γ);  Δ' = Δ + u;  B' = (1−β)·B + β·u;  p' = x̂

    Returns (p', Δ', B').  Math and operand contract identical to
    ``vrl_update.fused_sync_bvr``.
    """
    del block, interpret
    xb = _f32(xbar)[None]
    kg = scal[0, 0]
    u = (xb - _f32(p)) / kg
    new_d = (_f32(d) + u).astype(d.dtype)
    new_b = ((1.0 - beta) * _f32(b) + beta * u).astype(b.dtype)
    new_p = jnp.broadcast_to(xb, p.shape).astype(p.dtype)
    return new_p, new_d, new_b


def fused_sync_easgd(p, xbar, center, *, a: float, na: float,
                     block: int = 0, interpret=None):
    """Elastic sync (Zhang et al.); returns (p', c').  Math and operand
    contract identical to ``vrl_update.fused_sync_easgd``."""
    del block, interpret
    p32 = _f32(p)
    c = _f32(center)[None]
    new_p = (p32 - a * (p32 - c)).astype(p.dtype)
    new_c = ((1.0 - na) * _f32(center) + na * _f32(xbar)
             ).astype(center.dtype)
    return new_p, new_c


# ====================================================== overlapped-round fold
# Twins of the ``vrl_update.fused_fold_overlap*`` kernels: apply the
# round-START all-reduce's one-round-stale result at round END.
#   c = x̂_stale − pend;  p' = p + c;  Δ' = Δ + c/(pend_k γ);
#   pend' = km·pend + (1−km)·p'
# ``wscal``: per-participant (W, 2) fp32 [1/(pend_k·γ), miss mask km].

def fused_fold_overlap(p, xbar, pend, d, wscal, *, capture: bool = True,
                       block: int = 0, interpret=None):
    """Stale-sync fold for the VRL algorithms on (W, R, C) buffers.
    Returns (p', Δ', pend'); ``capture=False`` returns (p', Δ') and
    leaves the pend capture to the caller (compressed sync)."""
    del block, interpret
    pend32 = _f32(pend)
    c = _f32(xbar)[None] - pend32
    pnew = _f32(p) + c
    inv = wscal[:, 0][:, None, None]
    new_d = (_f32(d) + c * inv).astype(d.dtype)
    if not capture:
        return pnew.astype(p.dtype), new_d
    km = wscal[:, 1][:, None, None]
    new_pend = (km * pend32 + (1.0 - km) * pnew).astype(pend.dtype)
    return pnew.astype(p.dtype), new_d, new_pend


def fused_fold_overlap_bvr(p, xbar, pend, d, b, wscal, *, beta: float,
                           capture: bool = True, block: int = 0,
                           interpret=None):
    """BVR-L-SGD stale fold: the VRL fold plus B' = (1−β)B + β·c/(pend_k γ).
    Returns (p', Δ', B'[, pend'])."""
    del block, interpret
    pend32 = _f32(pend)
    c = _f32(xbar)[None] - pend32
    pnew = _f32(p) + c
    inv = wscal[:, 0][:, None, None]
    new_d = (_f32(d) + c * inv).astype(d.dtype)
    new_b = ((1.0 - beta) * _f32(b) + beta * c * inv).astype(b.dtype)
    if not capture:
        return pnew.astype(p.dtype), new_d, new_b
    km = wscal[:, 1][:, None, None]
    new_pend = (km * pend32 + (1.0 - km) * pnew).astype(pend.dtype)
    return pnew.astype(p.dtype), new_d, new_b, new_pend


def fused_fold_overlap_avg(p, xbar, pend, wscal, *, capture: bool = True,
                           block: int = 0, interpret=None):
    """Average-sync stale fold (local_sgd / stl_sgd): p' = p + c only.
    Returns (p'[, pend'])."""
    del block, interpret
    pend32 = _f32(pend)
    c = _f32(xbar)[None] - pend32
    pnew = _f32(p) + c
    if not capture:
        return (pnew.astype(p.dtype),)
    km = wscal[:, 1][:, None, None]
    new_pend = (km * pend32 + (1.0 - km) * pnew).astype(pend.dtype)
    return pnew.astype(p.dtype), new_pend


def fused_fold_overlap_hier2(p, glob, pend2, d2, wscal, *,
                             capture: bool = True, block: int = 0,
                             interpret=None):
    """Level-2 stale fold on (P, D, R, C) buffers; assumes a level-1 sync
    at the same step (worker params equal their pod average, read off
    worker 0 like ``fused_sync_hier2``).  ``glob``: (R, C); ``pend2``/
    ``d2``: (P, 1, R, C); ``wscal``: (P, 2).  Returns (p', Δ2'[, pend2'])."""
    del block, interpret
    pend32 = _f32(pend2)
    c = _f32(glob)[None, None] - pend32          # (P, 1, R, C) per pod
    pnew = _f32(p) + c                           # broadcast over D
    inv = wscal[:, 0][:, None, None, None]
    new_d2 = (_f32(d2) + c * inv).astype(d2.dtype)
    if not capture:
        return pnew.astype(p.dtype), new_d2
    km = wscal[:, 1][:, None, None, None]
    pod_new = _f32(p[:, :1]) + c                 # per-pod folded position
    new_pend = (km * pend32 + (1.0 - km) * pod_new).astype(pend2.dtype)
    return pnew.astype(p.dtype), new_d2, new_pend


# ==================================================== compressed-sync twins
# EF round-trips of the sync payload's drift (repro.comm): payload =
# p − ref + resid, compressed and decompressed in one fused chain; the
# residual is the literal subtraction so resid' + dec == payload bitwise.
# ``ref``/``e`` may be None (S-SGD gradient compression has no ref; EF off
# carries no residual) — then the matching output is None too.

def _ef_payload(p, ref, e):
    x = _f32(p)
    if ref is not None:
        x = x - _f32(ref)
    if e is not None:
        x = x + _f32(e)
    return x


def fused_ef_int8(p, ref, e, *, block: int = 0, interpret=None):
    """Per-row-scaled int8 EF round-trip on (W, R, C) buffers.

    ``ref``: (R, C) shared drift reference (broadcast over workers) or
    None; ``e``: (W, R, C) residual or None.  Returns (dec fp32, resid'),
    resid' None when e is None.  Math: ``repro.comm.compressors.ef_int8``.
    """
    del block, interpret
    x = _ef_payload(p, ref, e)
    dec, res = cc.ef_int8(x)
    return dec, (res if e is not None else None)


def fused_ef_topk(p, ref, e, *, k: int, block: int = 0, interpret=None):
    """Top-k (k lanes/row) EF round-trip on (W, R, C) buffers; same operand
    contract as ``fused_ef_int8``.  Math: ``compress.ef_topk``."""
    del block, interpret
    x = _ef_payload(p, ref, e)
    dec, res = cc.ef_topk(x, k)
    return dec, (res if e is not None else None)


def fused_ef_int8_grid(p, ref, e, *, block: int = 0, interpret=None):
    """Pod-major twin: p/e (P, D, R, C), ref (P, 1, R, C) per-pod
    reference (broadcast over the intra-pod axis)."""
    return fused_ef_int8(p, ref, e)


def fused_ef_topk_grid(p, ref, e, *, k: int, block: int = 0,
                       interpret=None):
    return fused_ef_topk(p, ref, e, k=k)


# ========================================== hierarchical (P, D, R, C) twins
def fused_hier_local_sgd(p, g, d1, d2, *, lr: float, wd: float = 0.0,
                         block: int = 0, interpret=None):
    """p' = p − γ((g − Δ1 − Δ2) + wd·p); Δ2 (P, 1, R, C) broadcasts."""
    del block, interpret
    v = _f32(g) - _f32(d1) - _f32(d2)
    p32 = _f32(p)
    if wd:
        v = v + wd * p32
    return (p32 - lr * v).astype(p.dtype)


def fused_hier_local_momentum(p, g, d1, d2, m, *, lr: float, beta: float,
                              wd: float = 0.0, nesterov: bool = False,
                              block: int = 0, interpret=None):
    del block, interpret
    v = _f32(g) - _f32(d1) - _f32(d2)
    p32 = _f32(p)
    if wd:
        v = v + wd * p32
    m_new = beta * _f32(m) + v
    step_dir = v + beta * m_new if nesterov else m_new
    return (p32 - lr * step_dir).astype(p.dtype), m_new.astype(m.dtype)


def fused_hier_local_adam(p, g, d1, d2, mu, nu, scal, *, lr: float,
                          b1: float = 0.9, b2: float = 0.999,
                          eps: float = 1e-8, wd: float = 0.0,
                          block: int = 0, interpret=None):
    del block, interpret
    v = _f32(g) - _f32(d1) - _f32(d2)
    p32 = _f32(p)
    c1 = scal[0, 0]
    c2 = scal[0, 1]
    mu_new = b1 * _f32(mu) + (1.0 - b1) * v
    nu_new = b2 * _f32(nu) + (1.0 - b2) * v * v
    step = lr * (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
    if wd:
        step = step + lr * wd * p32
    return ((p32 - step).astype(p.dtype), mu_new.astype(mu.dtype),
            nu_new.astype(nu.dtype))


def fused_hier_local_adam_sm3(p, g, d1, d2, mu, row, col, scal, *,
                              lr: float, b1: float = 0.9, b2: float = 0.999,
                              eps: float = 1e-8, wd: float = 0.0,
                              block: int = 0, interpret=None):
    """Pod-major SM3 Adam twin: ``row`` (P, D, R, 1) / ``col`` (P, D, S, C).
    Returns (p', mu', row', col')."""
    del block, interpret
    v = _f32(g) - _f32(d1) - _f32(d2)
    p32 = _f32(p)
    c1 = scal[0, 0]
    c2 = scal[0, 1]
    mu_new = b1 * _f32(mu) + (1.0 - b1) * v
    nu, new_row, new_col = _sm3_second_moment(v, row, col, b2)
    step = lr * (mu_new / c1) / (jnp.sqrt(nu / c2) + eps)
    if wd:
        step = step + lr * wd * p32
    return ((p32 - step).astype(p.dtype), mu_new.astype(mu.dtype),
            new_row, new_col)


def fused_sync_hier1(p, xbar_pod, d1, scal, *, block: int = 0,
                     interpret=None):
    """Level-1 sync: Δ1' = Δ1 + (x̂_pod − p)/(k1γ); p' = x̂_pod.
    ``xbar_pod``: (P, 1, R, C).  Returns (p', Δ1')."""
    del block, interpret
    xb = _f32(xbar_pod)
    kg = scal[0, 0]
    new_d1 = (_f32(d1) + (xb - _f32(p)) / kg).astype(d1.dtype)
    new_p = jnp.broadcast_to(xb, p.shape).astype(p.dtype)
    return new_p, new_d1


def fused_sync_hier2(p, glob, d2, scal, *, block: int = 0, interpret=None):
    """Level-2 sync: Δ2' = Δ2 + (x̂ − x̂_pod)/(k2γ); p' = x̂.

    Assumes a level-1 sync at the same step, so every worker's params ARE
    its pod average — the (P, 1, R, C) pod average is read off worker 0 of
    each pod.  ``glob``: (R, C).  Returns (p', Δ2').
    """
    del block, interpret
    glob32 = _f32(glob)[None, None]
    pod = _f32(p[:, :1])
    kg = scal[0, 0]
    new_d2 = (_f32(d2) + (glob32 - pod) / kg).astype(d2.dtype)
    new_p = jnp.broadcast_to(glob32, p.shape).astype(p.dtype)
    return new_p, new_d2
