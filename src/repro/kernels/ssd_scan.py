"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (paper: arXiv 2405.21060): instead of the
GPU version's warp-level segmented scans we use the chunk decomposition that
maps onto the MXU —
  intra-chunk: (Q x N)(N x Q) -> masked-decay (Q x Q) @ (Q x P) matmuls
  inter-chunk: the (P x N) state summary is carried in VMEM scratch across
  the chunk grid axis (Pallas TPU executes the minor-most grid axis
  sequentially, so the recurrence is a grid-carried scratch, not a lax.scan).

Layout: one (batch*head) per major grid step; chunk index minor. B/C are
shared across heads (n_groups=1) and indexed via bh // H in the BlockSpec
index map (no materialized per-head copies in HBM).

VMEM per instance (fp32): x,dt,y: ~Q*(2P+2N+1)*4 B + state P*N*4
  ≈ 256*(2*64+2*128+1)*4 + 64*128*4 ≈ 425 KiB at Q=256, P=64, N=128.

Validated in interpret mode against ``ref.ssd_ref`` (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int, num_chunks: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    a = -jnp.exp(alog_ref[0, 0].astype(jnp.float32))  # scalar for this head
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    xd = x * dt                               # dt folded into x
    la = dt[:, 0] * a                         # (Q,) log decay
    cum = jnp.cumsum(la)                      # (Q,)

    # intra-chunk: y_ij = (C_i . B_j) * exp(cum_i - cum_j) * [j <= i]
    seg = cum[:, None] - cum[None, :]
    iu = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ju = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(ju <= iu, seg, -1e30))
    cb_mat = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(cb_mat * decay, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                    # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S <- exp(cum_last) S + sum_j exp(cum_last - cum_j) xd_j B_j^T
    w = jnp.exp(cum[-1] - cum)                # (Q,)
    s_new = jax.lax.dot_general(xd * w[:, None], b, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state_scr[...] = jnp.exp(cum[-1]) * state + s_new

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "num_heads", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 256, num_heads: int,
             interpret: bool = True) -> jax.Array:
    """x: (BH, L, P); dt: (BH, L); a_log: (BH, 1); b, c: (B, L, N) with
    BH = B * num_heads. Returns y: (BH, L, P).
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    h = num_heads
    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, h=h: (i // h, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, h=h: (i // h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], a_log, b, c)
