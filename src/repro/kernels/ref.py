"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q, k, v: (BH, S, D) — plain softmax attention oracle."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(q.shape[1])[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def ssd_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
            c: jax.Array) -> jax.Array:
    """Sequential SSD recurrence oracle.

    x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b, c: (B, L, N).
    S_t = exp(dt_t A) S_{t-1} + dt_t x_t ⊗ B_t ;  y_t = S_t · C_t
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp           # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a)        # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)   # (B, L, H, P)


def vrl_update_ref(p: jax.Array, g: jax.Array, delta: jax.Array,
                   lr: float) -> jax.Array:
    """Fused local step oracle: p - lr * (g - delta)  (eq. 5/6)."""
    return (p.astype(jnp.float32)
            - lr * (g.astype(jnp.float32) - delta.astype(jnp.float32))
            ).astype(p.dtype)


def vrl_sync_ref(p: jax.Array, xbar: jax.Array, delta: jax.Array,
                 inv_kg: float):
    """Fused sync oracle: Δ' = Δ + (x̂ − x)·1/(kγ); x' = x̂  (eq. 4)."""
    new_delta = (delta.astype(jnp.float32)
                 + (xbar.astype(jnp.float32) - p.astype(jnp.float32)) * inv_kg
                 ).astype(delta.dtype)
    return xbar.astype(p.dtype), new_delta
