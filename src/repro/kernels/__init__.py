# Pallas TPU kernels for the framework's compute hot-spots:
#   flash_attention — causal/windowed attention forward (VMEM-tiled, MXU)
#   ssd_scan        — Mamba2 SSD chunked scan (grid-carried state scratch)
#   vrl_update      — fused VRL-SGD local/sync updates (HBM-bound elementwise)
# ops.py = jit'd wrappers; ref.py = pure-jnp oracles; validated interpret=True.
from repro.kernels.ops import (  # noqa: F401
    mha_flash,
    ssd_chunk_scan,
    vrl_local_update_tree,
    vrl_sync_update_tree,
)
