"""Roofline analysis over compiled dry-run artifacts.

Three terms, each in seconds (per device / per step):

  compute    = HLO_FLOPs        / PEAK_FLOPS_BF16
  memory     = HLO_bytes        / HBM_BW
  collective = collective_bytes / ICI_LINK_BW

``cost_analysis()`` on an SPMD-compiled executable reports *per-device*
flops/bytes. Collective bytes are not in cost_analysis — we parse the
optimized HLO and sum output-shape bytes of every collective op, multiplying
ops that live inside while-loop bodies (scan-over-layers!) by the loop trip
count recovered from the loop-condition constant.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

from repro.launch.mesh import (DCI_LINK_BW, HBM_BW, ICI_LINK_BW,
                               PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_hlo_computations(hlo: str) -> dict[str, list[str]]:
    """Split HLO module text into {computation_name: [lines]}."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("(" in s or s.split()[0].endswith(")")):
            # computation header like: %body.123 (arg: ...) -> ... {
            name = s.split()[0].lstrip("%")
            if name == "ENTRY":
                name = s.split()[1].lstrip("%")
            cur = name
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(s)
    return comps


def _line_out_bytes(line: str) -> int:
    """Bytes of the op's output tuple/array (first shape(s) on the line)."""
    lhs = line.split("=", 1)
    target = lhs[1] if len(lhs) == 2 else line
    # take shapes up to the opcode's '(' — i.e. the result type annotation
    m = re.match(r"\s*((?:\(?[\w\[\],\s{}\/#*]+\)?))\s+[\w\-]+\(", target)
    span = m.group(1) if m else target
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(span))


def _while_trip_counts(comps: dict[str, list[str]]) -> dict[str, int]:
    """Map body-computation name -> trip count (best effort)."""
    # find while ops: ... while(...), condition=%cond.1, body=%body.2
    trip: dict[str, int] = {}
    cond_const: dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            if " constant(" in ln:
                m = re.search(r"constant\((\d+)\)", ln)
                if m:
                    cond_const[name] = max(cond_const.get(name, 0), int(m.group(1)))
    for lines in comps.values():
        for ln in lines:
            if "while(" in ln and "body=" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb and mc:
                    trip[mb.group(1)] = cond_const.get(mc.group(1), 1)
    return trip


def collective_bytes(hlo: str) -> dict[str, float]:
    """Sum collective output bytes per op kind, loop-aware."""
    comps = parse_hlo_computations(hlo)
    trips = _while_trip_counts(comps)

    # computations reachable from loop bodies inherit the multiplier
    def comp_multiplier(name: str) -> int:
        return trips.get(name, 1)

    out: dict[str, float] = defaultdict(float)
    for cname, lines in comps.items():
        mult = comp_multiplier(cname)
        for ln in lines:
            for op in COLLECTIVE_OPS:
                if re.search(rf"=\s*[\w\[\],\s()\/{{}}#*]*{op}[\.(]", ln) or \
                   re.search(rf"\s{op}\(", ln):
                    out[op] += mult * _line_out_bytes(ln)
                    break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


@dataclasses.dataclass
class Roofline:
    name: str
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device
    model_flops: float          # 6*N_active*D, whole step, all devices
    chips: int
    dci_bytes: float = 0.0      # share of coll_bytes riding the slow
                                # cross-pod DCI tier (hier sync2)
    coll_detail: dict = dataclasses.field(default_factory=dict)
    overlap: bool = False       # overlapped rounds: the sync collective
                                # runs concurrently with the local steps,
                                # hidden up to min(coll, k*local)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        """Collective time with each byte weighted by its link tier: the
        cross-pod share pays DCI bandwidth (~8x slower than ICI), which is
        why the dry-run's sync2 term dominates — and why compressing sync2
        harder (``--compress2``) pays more than intra-pod compression."""
        ici = max(self.coll_bytes - self.dci_bytes, 0.0)
        return ici / ICI_LINK_BW + self.dci_bytes / DCI_LINK_BW

    @property
    def t_coll_hidden(self) -> float:
        """Collective time hidden behind the round's local steps under
        overlapped rounds: up to min(coll, k·local), where k·local is
        the round's on-device work (the larger of its compute and memory
        terms).  0 when overlap is off."""
        if not self.overlap:
            return 0.0
        return min(self.t_collective, max(self.t_compute, self.t_memory))

    @property
    def t_coll_exposed(self) -> float:
        """Collective time actually on the critical path (== the full
        collective term when overlap is off)."""
        return self.t_collective - self.t_coll_hidden

    @property
    def bottleneck(self) -> str:
        """Largest term, with the collective priced at its EXPOSED time —
        identical to the blocking classification when overlap is off."""
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_coll_exposed}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> str:
        return (f"{self.name} | {self.t_compute*1e3:9.3f} | "
                f"{self.t_memory*1e3:9.3f} | {self.t_collective*1e3:9.3f} | "
                f"{self.bottleneck:10s} | {self.useful_ratio:6.3f}")


def engine_pass_time(per_device_engine_bytes: float) -> float:
    """HBM time of one fused local step's engine-state traffic: the flat
    buffers stream through once (read) and write back in place, so the
    term is 2x the PER-DEVICE engine bytes over HBM bandwidth.  Row-
    sharding the engine divides per-device bytes by the shard count, and
    bf16/SM3 moments shrink the moment share — both cut this term
    directly, which is what the dry-run's engine-memory artifact prices
    (``launch/dryrun.py --engine-mem``)."""
    return 2.0 * per_device_engine_bytes / HBM_BW


def round_walltime(t_local: float, t_coll: float, *,
                   overlap: bool) -> float:
    """Predicted wall-clock of one communication round from its two
    measured (or modeled) pieces: the k local steps and the sync
    collective.  Blocking rounds serialize them; overlapped rounds hide
    the collective behind the local steps, exposing only the excess
    max(coll − k·local, 0).  ``benchmarks/step_time.bench_overlap``
    reconciles this prediction against the measured overlapped round."""
    if not overlap:
        return t_local + t_coll
    return t_local + max(t_coll - t_local, 0.0)


def analyze(name: str, compiled, hlo_text: str, model_flops: float,
            chips: int, dci_fraction: float = 0.0,
            overlap: bool = False) -> Roofline:
    """``dci_fraction``: share of the collective bytes that cross the slow
    DCI tier (1.0 for the hierarchical level-2 sync, whose only collective
    is the cross-pod all-reduce; 0 for purely intra-pod lowerings).
    ``overlap``: the lowering was an overlapped round — its collective is
    hidden up to min(coll, k·local) and the bottleneck classification
    prices only the exposed remainder."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    total = coll.get("total", 0.0)
    return Roofline(name=name, hlo_flops=flops, hlo_bytes=nbytes,
                    coll_bytes=total, model_flops=model_flops, chips=chips,
                    dci_bytes=total * dci_fraction, coll_detail=coll,
                    overlap=overlap)
