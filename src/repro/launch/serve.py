"""Serving driver: batched prefill + decode with the KV-cache engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.engine import Engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (registry.smoke_arch(args.arch) if args.smoke
           else registry.get_arch(args.arch))
    print(f"arch: {registry.describe(args.arch)}"
          f"{' [reduced smoke variant]' if args.smoke else ''}")
    if cfg.frontend == "codec":
        print("codec-frontend arch: serving expects precomputed frame "
              "embeddings; using random embeddings for the demo")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, max_len=args.prompt_len + args.gen)
    key = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompt, steps=args.gen,
                       temperature=args.temperature,
                       key=jax.random.PRNGKey(args.seed + 2))
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batched; first row: {out[0, -16:].tolist()})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
