"""Training driver (real execution, CPU-scale).

Runs VRL-SGD (or a baseline) on a selectable architecture's reduced or full
config with the synthetic non-iid LM pipeline, periodic checkpointing, and
average-model evaluation — the same code path the dry-run lowers for the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 4 --steps 50 --k 10 --algorithm vrl_sgd
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import registry
from repro.configs.base import EngineConfig, VRLConfig
from repro.data import lm_token_stream
from repro.models import transformer as T
from repro.train.loss import cross_entropy_lm
from repro.train.train_loop import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--algorithm", default="vrl_sgd",
                    choices=["vrl_sgd", "local_sgd", "ssgd", "easgd"])
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "reference"],
                    help="update math: flat-buffer fused Pallas engine "
                         "(default) or the per-leaf reference path")
    ap.add_argument("--block", type=int, default=0,
                    help="engine Pallas tile height (0 = auto)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--k", type=int, default=10, help="communication period")
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--warmup", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.05,
                    help="Dirichlet non-iid skew (lower = more skewed)")
    ap.add_argument("--identical", action="store_true")
    ap.add_argument("--ckpt", default=None, help="checkpoint dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (registry.smoke_arch(args.arch) if args.smoke
           else registry.get_arch(args.arch))
    print(f"arch: {registry.describe(args.arch)}"
          f"{' [reduced smoke variant]' if args.smoke else ''}")
    vrl = VRLConfig(algorithm=args.algorithm, comm_period=args.k,
                    learning_rate=args.lr, warmup=args.warmup,
                    update_backend=args.backend,
                    engine=EngineConfig(block=args.block))
    bundle = make_train_step(cfg, vrl, remat=not args.smoke)
    state = bundle.init_state(jax.random.PRNGKey(args.seed), args.workers)
    n_params = (bundle.engine.spec.size if bundle.engine is not None else
                sum(p.size for p in jax.tree.leaves(state.params))
                // args.workers)
    print(f"params: {n_params/1e6:.2f}M x {args.workers} workers, "
          f"algorithm={args.algorithm}, k={args.k}, backend={args.backend}")
    if bundle.engine is not None:
        es = bundle.engine.spec
        print(f"engine: flat buffer {es.rows}x{es.lanes} "
              f"({es.padded - es.size} pad elems), block={es.block}")

    data = lm_token_stream(args.workers, args.seq, cfg.vocab_size,
                           steps=args.steps, batch=args.batch,
                           alpha=args.alpha, identical=args.identical,
                           seed=args.seed)
    step = jax.jit(bundle.train_step)

    @jax.jit
    def eval_avg(state, toks, labels):
        avg = bundle.average_model(state)
        logits, _ = T.forward(cfg, avg, toks.reshape(-1, args.seq))
        return cross_entropy_lm(logits, labels.reshape(-1, args.seq))

    t0 = time.time()
    for t in range(args.steps):
        toks = jnp.asarray(data[t])
        labels = jnp.roll(toks, -1, axis=-1)
        state, loss = step(state, toks, labels)
        if (t + 1) % args.log_every == 0 or t == 0:
            el = eval_avg(state, toks, labels)
            print(f"step {t+1:5d}  local_loss {float(loss):.4f}  "
                  f"avg_model_loss {float(el):.4f}  "
                  f"({(time.time()-t0)/(t+1):.2f}s/step)")
        if args.ckpt and (t + 1) % args.ckpt_every == 0:
            meta = {"step": t + 1, "arch": args.arch}
            if bundle.engine is not None:
                ckpt.save_flat_state(args.ckpt, state, bundle.engine.spec,
                                     meta=meta)
            else:
                ckpt.save(args.ckpt, state, meta=meta)
            print(f"checkpointed -> {args.ckpt}")
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
