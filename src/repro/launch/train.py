"""Training driver (real execution, CPU-scale).

Runs VRL-SGD (or a baseline, or two-level hierarchical VRL-SGD) on a
selectable architecture's reduced or full config with the synthetic non-iid
LM pipeline, periodic checkpointing, and average-model evaluation — the
same code path the dry-run lowers for the production mesh.

Execution is ROUND-based by default (``EngineConfig.round_scan``): each
communication period runs as ONE jit dispatch (k scanned local steps +
sync, state donated), tokens are prefetched per round, and losses stay
device-side until a logging boundary — ``--log-every`` counts rounds.
``--no-round`` falls back to one dispatch per local step (and per-step
loss fetch), which is the old behaviour.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 4 --steps 50 --k 10 --algorithm vrl_sgd

Hierarchical on a placeholder pod grid (devices permitting, ``--mesh-grid``
shard_maps the pod-major worker grid so level-1 syncs all-reduce only the
intra-pod axis and level-2 only the cross-pod axis):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 8 --pods 2 --algorithm hier_vrl_sgd --k1 2 --k2 8 \
      --mesh-grid

Fault tolerance (elastic rounds): ``--faults`` replays a deterministic
chaos schedule (gradient NaN/Inf, worker crash/rejoin, simulated mid-save
kill), ``--membership`` threads the survivor mask through every sync (the
repair keeps Σ_i Δ_i = 0 exactly), ``--guard`` checks finiteness each
round and rolls back to the last good checkpoint (or the round-start
snapshot) with bounded retries, and ``--resume auto`` restarts from the
newest complete checkpoint — resharding the worker axis if ``--workers``
changed:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 4 --steps 40 --k 5 --membership --guard \
      --faults "nan@1:12,crash@1:15,rejoin@1:30" \
      --ckpt /tmp/run --ckpt-every 10 --resume auto

Partial participation (federated client sampling): ``--clients M`` keeps M
logical clients' engine state (Dirichlet non-IID data each) in a host-side
store; every round a seed-deterministic cohort of ``--workers`` clients is
gathered into the flat buffers (one contiguous copy per buffer), Σ Δ is
recentred over the cohort, the UNCHANGED compiled round runs (still one
sync all-reduce), and the rows scatter back.  M == --workers with
``--participation 1.0`` is bitwise the plain engine path:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 8 --steps 40 --k 5 --clients 32 --participation 0.25 \
      --alpha 0.1

Observability (structured telemetry, ``repro.obs``): ``--metrics
out.jsonl`` streams schema-versioned JSONL events — a ``run_start``
header with the full run description (including the measured sync wire
bytes), then per-round ``round``/``sync`` records, ``diag``
algorithm-health records at ``--log-every`` cadence (drift dispersion,
the Δ-dispersion ζ² proxy for the paper's inter-worker gradient
variance, Σ Δ / Σ B invariant residuals, EF-residual and moment norms,
non-finite worker count), ``membership`` / ``rollback`` / ``cohort`` /
``checkpoint`` / ``restore`` / ``fault`` events as they happen, and a
``run_end`` record with the final averaged-model loss plus wall-clock
phase-timer p50/p95s (the phases are the host-visible boundaries —
data staging, the round dispatch+block, eval, diag, gather/scatter,
checkpoint; local-steps/sync/fold cannot be split apart, they live
inside ONE compiled dispatch).  The diagnostics pass is one read-only
jit over the flat engine state, SEPARATE from the compiled round — the
one-sync-all-reduce HLO contract is untouched.  ``--invariant-alarm
1e-3`` feeds a tripped Σ Δ / Σ B residual into the ``--guard``
rollback; ``--profile-round N --profile-dir d`` captures a
jax.profiler trace around round N.  Render a stream (or diff two) with
``scripts/report.py``:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --workers 4 --steps 40 --k 5 --metrics run.jsonl --diag \
      --guard --invariant-alarm 1e-3 --ckpt /tmp/run
  python scripts/report.py run.jsonl
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.comm import compressors as comm_mod
from repro.configs import registry
from repro.configs.base import EngineConfig, HierConfig, VRLConfig
from repro.core import clients as clients_mod
from repro.core import engine as engine_mod
from repro.core import schedule as schedule_mod
from repro.data import assigned_token_stream
from repro.data import partition as partition_mod
from repro.fault import FaultSchedule
from repro.launch import mesh as mesh_mod
from repro.models import transformer as T
from repro.obs import diagnostics as obs_diag
from repro.obs import metrics as obs_metrics
from repro.obs.timers import PhaseTimers
from repro.train.loss import cross_entropy_lm
from repro.train.train_loop import make_train_step


# --guard's loss-trend trip-wire: a round whose mean loss exceeds
# factor * last_good + slack is treated as diverged even though every
# value is finite (the signature of a scale-poisoned gradient).  The
# slack keeps ordinary early-training noise from tripping it.
_BLOWUP_FACTOR = 10.0
_BLOWUP_SLACK = 1.0


def _validate_args(args) -> None:
    """Early, named range checks — a bad flag should fail before the
    model compiles, not as an inscrutable shape error mid-run."""
    if not (0.0 <= args.deadline <= 1.0):
        raise SystemExit(f"--deadline is a probability in [0, 1], got "
                         f"{args.deadline}")
    if args.ckpt_every <= 0:
        raise SystemExit(f"--ckpt-every must be a positive step count, "
                         f"got {args.ckpt_every}")
    if args.shards < 1:
        raise SystemExit(f"--shards must be >= 1, got {args.shards}")
    if args.steps < 1:
        raise SystemExit(f"--steps must be >= 1, got {args.steps}")
    if args.k < 1:
        raise SystemExit(f"--k must be >= 1, got {args.k}")
    if args.workers < 1:
        raise SystemExit(f"--workers must be >= 1, got {args.workers}")
    if args.ckpt_retain < 0:
        raise SystemExit(f"--ckpt-retain must be >= 0 (0 keeps all), got "
                         f"{args.ckpt_retain}")
    if args.max_retries < 0:
        raise SystemExit(f"--max-retries must be >= 0, got "
                         f"{args.max_retries}")
    if args.clients < 0:
        raise SystemExit(f"--clients must be >= 0 (0 = no client "
                         f"sampling), got {args.clients}")
    if args.clients and args.clients < args.workers:
        raise SystemExit(f"--clients {args.clients} must be >= --workers "
                         f"{args.workers} (the cohort size is the worker "
                         f"count)")
    if args.participation and not args.clients:
        raise SystemExit("--participation needs --clients (it is the "
                         "sampled fraction of the client population)")
    if args.participation and not (0.0 < args.participation <= 1.0):
        raise SystemExit(f"--participation is a fraction in (0, 1], got "
                         f"{args.participation}")
    if args.participation:
        cohort = round(args.participation * args.clients)
        if cohort != args.workers:
            raise SystemExit(
                f"--participation {args.participation} of "
                f"{args.clients} clients is a cohort of {cohort}, but "
                f"--workers is {args.workers} — set --workers {cohort} "
                f"(the cohort size is the worker count)")
    if args.invariant_alarm < 0:
        raise SystemExit(f"--invariant-alarm must be >= 0 (0 disables "
                         f"the residual alarm), got {args.invariant_alarm}")
    if args.profile_round < 0:
        raise SystemExit(f"--profile-round counts rounds from 1 (0 = "
                         f"off), got {args.profile_round}")
    if args.profile_round and not args.profile_dir:
        raise SystemExit("--profile-round needs --profile-dir (where the "
                         "jax.profiler trace lands)")
    if args.profile_round and not args.round:
        raise SystemExit("--profile-round traces a compiled round; drop "
                         "--no-round")


def _build_faults(args) -> FaultSchedule | None:
    if not args.faults:
        return None
    if not args.round:
        raise SystemExit("--faults injects per-round; drop --no-round")
    try:
        if args.faults == "random":
            fs = FaultSchedule.random(
                args.steps, args.workers,
                seed=args.fault_seed if args.fault_seed is not None
                else args.seed,
                killsave=bool(args.ckpt))
        else:
            fs = FaultSchedule.parse(args.faults)
    except ValueError as e:
        raise SystemExit(f"--faults: {e}")
    for e in fs.events:
        if e.worker >= args.workers:
            raise SystemExit(f"--faults: event {e.kind}@{e.worker}:"
                             f"{e.step} targets a worker >= --workers "
                             f"{args.workers}")
    for e in fs.membership_events():
        if fs.active_at(e.step, args.workers).sum() < 1:
            raise SystemExit(f"--faults: schedule leaves no active worker "
                             f"at step {e.step}")
    return fs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--algorithm", default="vrl_sgd",
                    choices=sorted(engine_mod.ALGO_SPECS))
    ap.add_argument("--comm-schedule", default=None,
                    help="stagewise round schedule: const | "
                         "stagewise[:k0:rounds:k_max] | custom:1x4,2x4,8x2 "
                         "(default: constant --k; stl_sgd defaults to the "
                         "doubling ramp 1 -> --k).  Each distinct stage k "
                         "compiles one round executable (RoundCache).")
    ap.add_argument("--bvr-beta", type=float, default=0.5,
                    help="bvr_l_sgd bias-variate EMA rate (0 = plain "
                         "vrl_sgd)")
    ap.add_argument("--compress", default=None,
                    help="sync-payload compressor: none | int8 | "
                         "topk[:rate] (append :noef to drop error "
                         "feedback).  none/rate-1 is bitwise the "
                         "uncompressed path")
    ap.add_argument("--compress2", default=None,
                    help="hier_vrl_sgd: override the cross-pod sync2 "
                         "compressor (default: --compress) so the slow "
                         "DCI tier compresses harder")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "fused", "xla", "reference"],
                    help="update math: auto (Pallas where it compiles, "
                         "XLA elsewhere), fused Pallas, plain-jnp xla, or "
                         "the per-leaf reference path")
    ap.add_argument("--block", type=int, default=0,
                    help="engine Pallas tile height (0 = auto)")
    ap.add_argument("--shards", type=int, default=1,
                    help="row-block-shard every engine buffer over a model "
                         "mesh axis: per-device engine HBM drops by this "
                         "factor and the sync stays ONE (per-shard) all-"
                         "reduce.  With --mesh-grid the mesh grows a "
                         "trailing 'shard' axis (needs workers*shards "
                         "devices); without it the layout pads rows to "
                         "shard boundaries but runs replicated.  1 = "
                         "bitwise the unsharded path")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="storage dtype for inner-optimizer moment buffers "
                         "(math stays fp32 in-register); bfloat16 halves "
                         "moment HBM")
    ap.add_argument("--sm3", action="store_true",
                    help="SM3-factored adam second moment: nu's (W, R, C) "
                         "buffer becomes row (W, R, 1) + lane (W, S, C) "
                         "stats — ~lanes-fold less second-moment HBM "
                         "(adam only)")
    ap.add_argument("--no-round", dest="round", action="store_false",
                    default=True,
                    help="dispatch every local step from python instead of "
                         "compiling one scan-fused round per comm period")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped rounds: issue the sync all-reduce at "
                         "round START over the previous boundary's "
                         "transmitted positions, so it runs concurrently "
                         "with the round's local steps and the one-round-"
                         "stale mean is folded in at the end (hier: "
                         "overlaps the cross-pod sync2 only).  Needs round "
                         "execution and an engine backend.")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="straggler deadline: per-round probability in "
                         "[0, 1] that a participant misses its capture "
                         "(simulated), keeps its last transmitted position "
                         "and — under compressed sync — parks the missed "
                         "payload in its EF residual.  Requires --overlap.")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--clients", type=int, default=0,
                    help="partial participation: keep this many LOGICAL "
                         "clients' engine state (params drift, Δ, bias, "
                         "EF residual, moments — each on its own "
                         "Dirichlet non-iid data shard) in a host-side "
                         "store, and sample a cohort of --workers of "
                         "them per round into the flat buffers.  The "
                         "compiled round is unchanged (one sync all-"
                         "reduce); Σ Δ is recentred over each sampled "
                         "cohort.  0 = off; --clients == --workers is "
                         "bitwise the plain path")
    ap.add_argument("--participation", type=float, default=0.0,
                    help="sampled fraction of --clients per round, as a "
                         "cross-check: round(participation * clients) "
                         "must equal --workers (the cohort size).  "
                         "Default: --workers / --clients")
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--k", type=int, default=10, help="communication period")
    ap.add_argument("--pods", type=int, default=2,
                    help="hier_vrl_sgd: pods P (workers split as P x W/P)")
    ap.add_argument("--k1", type=int, default=0,
                    help="hier_vrl_sgd intra-pod period (default: --k)")
    ap.add_argument("--k2", type=int, default=0,
                    help="hier_vrl_sgd cross-pod period (default: 4*k1)")
    ap.add_argument("--mesh-grid", action="store_true",
                    help="build a (pods, W/pods) device mesh with axes "
                         "(pod, data) and shard the worker grid over it")
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--warmup", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.05,
                    help="Dirichlet non-iid skew (lower = more skewed)")
    ap.add_argument("--identical", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint ROOT dir: saves land in per-step "
                         "ckpt-XXXXXXXX/ subdirs with an atomic 'latest' "
                         "pointer (each save is temp-file + rename, so a "
                         "kill mid-save never tears a checkpoint)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-retain", type=int, default=3,
                    help="keep only the newest N step checkpoints "
                         "(0 = keep all)")
    ap.add_argument("--resume", default=None,
                    help="'auto' resumes from the newest complete "
                         "checkpoint under --ckpt (fresh start if none); "
                         "a path resumes from that step dir.  If "
                         "--workers differs from the save, the flat state "
                         "is RESHARDED (rows tiled, Δ recentred to Σ=0, "
                         "EF residuals dropped); layout/compressor/moment "
                         "mismatches still fail loudly")
    ap.add_argument("--faults", default=None,
                    help="deterministic chaos schedule: 'kind@worker:step' "
                         "events joined by commas — nan/inf (gradient "
                         "poison), scale@w:step:mult (finite gradient "
                         "blow-up — silent corruption only the --guard "
                         "loss trend catches), crash/rejoin (membership), "
                         "killsave:step (die inside the next checkpoint "
                         "save).  'random' draws a schedule from "
                         "--fault-seed.  Example: "
                         "'nan@1:12,scale@0:20:1e3,crash@1:15,"
                         "rejoin@1:30,killsave:20'")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed for --faults random (default: --seed)")
    ap.add_argument("--membership", action="store_true",
                    help="elastic membership: thread an active-worker "
                         "mask through every sync (masked means stay ONE "
                         "all-reduce; Σ Δ = 0 is repaired exactly on every "
                         "drop/rejoin; full mask is bitwise the plain "
                         "path).  Auto-enabled by crash/rejoin faults.")
    ap.add_argument("--guard", action="store_true",
                    help="divergence guard: check loss/param finiteness "
                         "AND the loss trend (a round whose mean loss "
                         "blows past 10x the last good round + 1 is "
                         "diverged even when finite — the scale-poison "
                         "signature) each round; on failure roll back to "
                         "the last good checkpoint (or the round-start "
                         "snapshot) and retry with backoff, bounded by "
                         "--max-retries")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="divergence-guard rollback budget")
    ap.add_argument("--loss-out", default=None,
                    help="write final {steps, final_loss, avg_model_loss} "
                         "json here (chaos CI compares runs with it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics", default=None,
                    help="stream schema-versioned JSONL telemetry here "
                         "(repro.obs): a run_start meta header, then "
                         "round/sync/diag/eval/membership/rollback/"
                         "cohort/checkpoint/restore/fault/run_end "
                         "records, one object per line, flushed per "
                         "event so crashed runs leave a valid prefix.  "
                         "Summarize (or diff two) with scripts/report.py")
    ap.add_argument("--diag", action="store_true",
                    help="print the engine's algorithm-health "
                         "diagnostics at --log-every cadence: drift "
                         "dispersion, the Δ-dispersion ζ² proxy, "
                         "Σ Δ / Σ B invariant residuals, EF/moment "
                         "norms, non-finite worker count.  One "
                         "read-only jit over the flat state — the "
                         "compiled round keeps its single all-reduce.  "
                         "--metrics records the same fields without "
                         "this flag's console lines")
    ap.add_argument("--invariant-alarm", type=float, default=0.0,
                    help="alarm threshold on the Σ Δ / Σ B invariant "
                         "residuals (0 = off).  With --guard a tripped "
                         "alarm is a divergence (rollback + retry); "
                         "without it the alarm prints and the run "
                         "continues.  Under a lossy --compress the "
                         "residual is genuinely nonzero (EF-bounded "
                         "bias) — leave off or set above that floor")
    ap.add_argument("--profile-round", type=int, default=0,
                    help="capture a jax.profiler trace around the Nth "
                         "compiled round (1-based; 0 = off)")
    ap.add_argument("--profile-dir", default=None,
                    help="directory for the --profile-round trace")
    args = ap.parse_args(argv)
    _validate_args(args)

    cfg = (registry.smoke_arch(args.arch) if args.smoke
           else registry.get_arch(args.arch))
    print(f"arch: {registry.describe(args.arch)}"
          f"{' [reduced smoke variant]' if args.smoke else ''}")
    hier = None
    if args.algorithm == "hier_vrl_sgd":
        if args.workers % args.pods:
            raise SystemExit(f"--workers {args.workers} not divisible by "
                             f"--pods {args.pods}")
        k1 = args.k1 or args.k
        k2 = args.k2 or 4 * k1
        hier = HierConfig(k1=k1, k2=k2,
                          grid=(args.pods, args.workers // args.pods))
        print(f"hier: {hier.grid[0]} pods x {hier.grid[1]} workers, "
              f"k1={k1} (intra-pod), k2={k2} (cross-pod)")
    sched_arg = (schedule_mod.parse_schedule(args.comm_schedule, args.k)
                 if args.comm_schedule else None)
    if hier is not None and sched_arg is not None:
        raise SystemExit("--comm-schedule drives the flat algorithms; "
                         "hier_vrl_sgd's cadence is --k1/--k2")
    comp_arg = (comm_mod.parse_compressor(args.compress)
                if args.compress else None)
    comp2_arg = (comm_mod.parse_compressor(args.compress2)
                 if args.compress2 else None)
    if comp2_arg is not None and args.algorithm != "hier_vrl_sgd":
        raise SystemExit("--compress2 drives the hierarchical cross-pod "
                         "sync2; flat algorithms have one level "
                         "(--compress)")
    if args.overlap and not args.round:
        raise SystemExit("--overlap hides the sync behind the next round's "
                         "local steps, which needs round execution; drop "
                         "--no-round")
    if args.clients:
        if args.algorithm == "hier_vrl_sgd":
            raise SystemExit("--clients samples cohorts into the flat "
                             "(W, R, C) buffers; hier_vrl_sgd runs a "
                             "pod-major grid — drop --clients or the "
                             "hierarchy")
        if args.overlap:
            raise SystemExit("--clients does not compose with --overlap: "
                             "the overlapped pend buffer is one round "
                             "stale and would mix positions from "
                             "different clients across cohorts")
        if not args.round:
            raise SystemExit("--clients gathers/scatters per round; drop "
                             "--no-round")
        if args.backend == "reference":
            raise SystemExit("--clients needs the flat-buffer engine's "
                             "contiguous client store; --backend "
                             "reference has none")
    faults = _build_faults(args)
    membership = args.membership
    if faults is not None and faults.membership_events() and not membership:
        print("faults: schedule has crash/rejoin events — enabling "
              "--membership")
        membership = True
    if membership and args.backend == "reference":
        raise SystemExit("--membership needs the flat-buffer engine's "
                         "MemberState; --backend reference has none")
    if faults is not None:
        print(f"faults: {faults.describe()}")
    vrl = VRLConfig(algorithm=args.algorithm, comm_period=args.k,
                    learning_rate=args.lr, warmup=args.warmup,
                    update_backend=args.backend, bvr_beta=args.bvr_beta,
                    comm_schedule=sched_arg, compress=comp_arg,
                    compress2=comp2_arg, overlap=args.overlap,
                    deadline=args.deadline, membership=membership,
                    moment_dtype=args.moment_dtype, sm3=args.sm3,
                    engine=EngineConfig(block=args.block,
                                        round_scan=args.round,
                                        shards=args.shards), hier=hier)
    sched = engine_mod.comm_schedule(vrl)    # explicit or the algo default
    if sched is not None:
        print(f"comm schedule: stages {sched.stages} (k repeats from the "
              f"last stage; {len(sched.distinct_periods())} distinct round "
              f"lengths)")
    mesh = None
    worker_axes = ("data",)
    if args.mesh_grid:
        try:
            mesh = mesh_mod.make_engine_mesh(
                args.workers, shards=args.shards,
                pods=hier.grid[0] if hier else 0,
                shard_axis=vrl.engine.shard_axis)
        except ValueError as e:
            raise SystemExit(f"--mesh-grid: {e}")
        worker_axes = ("pod", "data")
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    try:
        bundle = make_train_step(cfg, vrl, remat=not args.smoke, mesh=mesh,
                                 worker_axes=worker_axes)
    except ValueError as e:
        raise SystemExit(str(e))
    state = bundle.init_state(jax.random.PRNGKey(args.seed), args.workers)
    store = None
    if args.clients:
        try:
            store = clients_mod.ClientStore(state, args.clients)
        except ValueError as e:
            raise SystemExit(f"--clients: {e}")
        print(f"clients: {args.clients} logical clients over "
              f"{args.workers} worker slots (participation "
              f"{args.workers / args.clients:.3g}), host store "
              f"{store.nbytes / 2**20:.1f} MiB")
    n_params = (bundle.engine.spec.size if bundle.engine is not None else
                sum(p.size for p in jax.tree.leaves(state.params))
                // args.workers)
    resolved = engine_mod.resolve_backend(vrl)
    print(f"params: {n_params/1e6:.2f}M x {args.workers} workers, "
          f"algorithm={args.algorithm}, k={args.k}, "
          f"backend={args.backend}"
          + (f" -> {resolved}" if resolved != args.backend else "")
          + f", round_scan={args.round}")
    if bundle.engine is not None:
        es = bundle.engine.spec
        moments = ("" if args.moment_dtype == "float32" and not args.sm3
                   else f", moments={args.moment_dtype}"
                        + ("+sm3" if args.sm3 else ""))
        shard_note = ""
        if es.shards > 1:
            placed = mesh is not None and vrl.engine.shard_axis in (
                mesh.axis_names if mesh is not None else ())
            shard_note = (f", shards={es.shards}"
                          + ("" if placed else " (layout only — no mesh "
                             "axis; rows pad to shard boundaries)"))
        print(f"engine: flat buffer {es.rows}x{es.lanes} "
              f"({es.padded - es.size} pad elems), block={es.block}"
              f"{shard_note}{moments}")
    if args.overlap:
        print(f"overlap: sync collective issued at round start (one-round-"
              f"stale fold at the boundary"
              + (f"; cross-pod sync2 only, sync1 blocking" if hier else "")
              + (f"), deadline: miss prob {args.deadline}"
                 if args.deadline else ")"))
    comps = (bundle.engine.compressors if bundle.engine is not None
             else comm_mod.resolve_pair(vrl))
    if any(c is not None for c in comps) and bundle.engine is not None:
        es = bundle.engine.spec
        item = jnp.dtype(es.dtype).itemsize
        raw = comm_mod.raw_bytes(es.rows, es.lanes, item)
        distinct = []              # one figure per distinct compressor,
        for c in comps:            # matching describe_pair's collapsing
            if c is not None and c not in distinct:
                distinct.append(c)
        wires = [comm_mod.wire_bytes(c, rows=es.rows, lanes=es.lanes,
                                     size=es.size, itemsize=item)
                 for c in distinct]
        print(f"compress: {comm_mod.describe_pair(comps)} — sync wire "
              + " / ".join(f"{w/2**20:.2f} MiB ({raw/w:.1f}x)"
                           for w in wires)
              + f" vs raw {raw/2**20:.2f} MiB per worker payload")

    # ----------------------------------------------------- observability
    # The structured telemetry channel (repro.obs).  --metrics streams
    # schema-versioned JSONL events; --diag/--invariant-alarm run the
    # engine's READ-ONLY diagnostics pass at --log-every cadence as its
    # own jit — the compiled round and its one-sync-all-reduce HLO are
    # untouched.  Console prints stay the human channel; the stream is
    # the machine channel.
    diag_wanted = args.diag or args.invariant_alarm > 0
    if diag_wanted and bundle.engine is None:
        raise SystemExit("--diag/--invariant-alarm read the flat engine "
                         "state; --backend reference has none")
    wire = obs_diag.wire_bytes_per_sync(bundle.engine)
    mw = obs_metrics.NullWriter()
    if args.metrics:
        mw = obs_metrics.MetricsWriter(args.metrics, run_meta={
            "arch": args.arch, "smoke": bool(args.smoke),
            "algorithm": args.algorithm, "workers": args.workers,
            "clients": args.clients or None, "batch": args.batch,
            "seq": args.seq, "steps": args.steps, "k": args.k,
            "k1": hier.k1 if hier else None,
            "k2": hier.k2 if hier else None,
            "lr": args.lr, "seed": args.seed,
            "backend": args.backend, "resolved_backend": resolved,
            "round_scan": bool(args.round), "overlap": bool(args.overlap),
            "membership": bool(membership), "guard": bool(args.guard),
            "shards": args.shards,
            "compress": comm_mod.pair_meta(comps),
            "faults": faults.describe() if faults is not None else None,
            "n_params": int(n_params),
            "wire": wire,
            "client_store": store.meta() if store is not None else None,
        })
        print(f"metrics: streaming JSONL events -> {args.metrics}")
    timers = PhaseTimers() if mw.active else None
    phase = (timers.phase if timers is not None
             else (lambda name: contextlib.nullcontext()))
    diag_fn = None
    if bundle.engine is not None and (diag_wanted or mw.active):
        diag_fn = jax.jit(bundle.engine.diagnostics)
    profiling = profiled = False

    # data assignment: one Dirichlet-skewed shard per unit (logical client
    # or physical worker) to start; a resumed run re-splits the SAVED
    # assignment instead (below), so per-unit distributions survive a
    # resharded resume.  The trivial fresh assignment is bitwise the old
    # lm_token_stream, so non-resumed runs are unchanged.
    units = args.clients if args.clients else args.workers
    assignment = partition_mod.contiguous_assignment(units, units)

    @jax.jit
    def eval_avg(state, toks, labels):
        avg = bundle.average_model(state)
        logits, _ = T.forward(cfg, avg, toks.reshape(-1, args.seq))
        return cross_entropy_lm(logits, labels.reshape(-1, args.seq))

    def save_into(path, t):
        meta = {"step": t, "arch": args.arch, "workers": args.workers,
                "assignment": partition_mod.assignment_to_meta(assignment)}
        if store is not None:
            # client mode checkpoints the STORE (every client's state,
            # (M, ...) leaves + shared globals), not the transient cohort
            # window; the layout/compressor/moment metadata still rides
            # along so mismatched restores fail loudly
            meta["clients"] = args.clients
            meta["flat_spec"] = bundle.engine.spec.meta()
            meta["compressors"] = comm_mod.pair_meta(
                bundle.engine.compressors)
            meta["moments"] = ckpt.moments_meta(vrl)
            ckpt.save(path, store.to_tree(), meta=meta)
        elif bundle.engine is not None:
            ckpt.save_flat_state(
                path, state, bundle.engine.spec, meta=meta,
                grid=bundle.engine.grid,
                compressors=comm_mod.pair_meta(bundle.engine.compressors),
                moments=ckpt.moments_meta(vrl))
        else:
            ckpt.save(path, state, meta=meta)

    def checkpoint(t):
        # simulate a process dying inside the save: the atomic-rename
        # format must leave the previous complete checkpoint in place
        if faults is not None and faults.killsave_at(t):
            try:
                with ckpt.kill_save():
                    ckpt.save_step(args.ckpt, t, lambda p: save_into(p, t),
                                   retain=args.ckpt_retain)
            except ckpt.SimulatedKill:
                print(f"chaos: simulated kill during save at step {t} — "
                      f"'latest' still points at the previous good step")
                mw.emit("checkpoint", t=t, killed=True)
            return
        with phase("checkpoint"):
            ckpt.save_step(args.ckpt, t, lambda p: save_into(p, t),
                           retain=args.ckpt_retain)
        print(f"checkpointed -> {ckpt.step_dir(args.ckpt, t)}")
        mw.emit("checkpoint", t=t, killed=False,
                path=str(ckpt.step_dir(args.ckpt, t)))

    def load_from(path):
        """Restore into the freshly-initialized state — resharding the
        worker axis when the save's W differs from this run's.  Client
        mode restores the STORE instead (same client count required; the
        cohort size —--workers— may change freely, that's just a
        different participation rate)."""
        recorded = ckpt.load_meta(path).get("meta", {})
        if store is not None:
            if "clients" not in recorded:
                raise ValueError(
                    "checkpoint was saved without --clients (a plain "
                    "worker state, not a client store) — resume it "
                    "without --clients")
            if int(recorded["clients"]) != args.clients:
                raise ValueError(
                    f"checkpoint holds {recorded['clients']} clients but "
                    f"--clients is {args.clients}; the client population "
                    f"is fixed for a run (change --workers to change the "
                    f"participation rate instead)")
            ckpt.validate_flat_meta(
                recorded, bundle.engine.spec,
                compressors=comm_mod.pair_meta(bundle.engine.compressors),
                moments=ckpt.moments_meta(vrl))
            store.load_tree(ckpt.restore(path, store.to_tree()))
            return state        # the next round's gather installs the rows
        if "clients" in recorded:
            raise ValueError(
                f"checkpoint is a client store ({recorded['clients']} "
                f"clients) — pass --clients {recorded['clients']} to "
                f"resume it")
        if bundle.engine is None:
            return ckpt.restore(path, state)
        comps_meta = comm_mod.pair_meta(bundle.engine.compressors)
        mom = ckpt.moments_meta(vrl)
        if bundle.engine.grid is None:
            w_saved = ckpt.saved_workers(path)
            if w_saved != args.workers:
                print(f"resume: resharding {w_saved} -> {args.workers} "
                      f"workers (Δ recentred, EF residuals dropped)")
                return ckpt.restore_resharded(
                    path, state, bundle.engine.spec,
                    compressors=comps_meta, moments=mom)
        return ckpt.restore_flat_state(
            path, state, bundle.engine.spec, grid=bundle.engine.grid,
            compressors=comps_meta, moments=mom)

    start_t = 0
    if args.resume:
        if args.resume == "auto":
            if not args.ckpt:
                raise SystemExit("--resume auto finds checkpoints under "
                                 "--ckpt; pass --ckpt too")
            found = ckpt.latest_step(args.ckpt)
            if found is None:
                print("resume auto: no complete checkpoint — fresh start")
                resume_path = None
            else:
                start_t, resume_path = found
        else:
            resume_path = args.resume
        if args.resume != "auto" or resume_path is not None:
            try:
                restored = load_from(resume_path)
            except (ValueError, KeyError, FileNotFoundError) as e:
                raise SystemExit(f"--resume {args.resume}: {e}")
            state = jax.tree.map(jnp.asarray, restored)
            rec_meta = ckpt.load_meta(resume_path).get("meta", {})
            start_t = int(rec_meta.get("step", start_t))
            # data continuity: reuse the SAVED shard assignment instead of
            # re-drawing the stream; a changed unit count re-splits it
            # exactly once (data.partition.repartition) and the re-split
            # is what later checkpoints record
            saved_assign = rec_meta.get("assignment")
            if saved_assign is not None:
                saved_assign = partition_mod.assignment_from_meta(
                    saved_assign)
                if len(saved_assign) != units:
                    print(f"resume: re-splitting saved data assignment "
                          f"{len(saved_assign)} -> {units} units (shard "
                          f"skews preserved)")
                    assignment = partition_mod.repartition(saved_assign,
                                                           units)
                else:
                    assignment = saved_assign
            print(f"resumed step {start_t} from {resume_path}")
            mw.emit("restore", t=start_t, path=str(resume_path),
                    workers=args.workers)
    data = assigned_token_stream(assignment, args.seq, cfg.vocab_size,
                                 steps=args.steps, batch=args.batch,
                                 alpha=args.alpha,
                                 identical=args.identical, seed=args.seed)

    def emit_final(state, steps_done, *, note="", **extra):
        """The ONE end-of-run emit path — the normal end and the
        checkpoint-step >= --steps early exit both land here, so both
        get the real averaged-model eval (the early exit used to write
        --loss-out with avg_model_loss: null)."""
        if not (args.loss_out or mw.active):
            return
        with phase("eval"):
            toks_f = jnp.asarray(data[args.steps - 1])
            labels_f = jnp.roll(toks_f, -1, axis=-1)
            el = float(eval_avg(state, toks_f, labels_f))
        out = {"steps": int(steps_done), "final_loss": el,
               "avg_model_loss": el}
        if args.loss_out:
            with open(args.loss_out, "w") as f:
                json.dump(out, f)
            print(f"loss-out: avg_model_loss {el:.4f}{note} -> "
                  f"{args.loss_out}")
        mw.emit("run_end", **out, **extra,
                phases=timers.summary() if timers is not None else None)
        mw.close()

    if start_t >= args.steps:
        print(f"resume: checkpoint step {start_t} >= --steps "
              f"{args.steps} — nothing to do")
        if store is not None:
            # the restored rows live in the client store, not in the
            # fresh-init device state — gather the step's cohort so the
            # averaged-model eval sees restored clients, exactly as the
            # normal end path would
            cohort = clients_mod.sample_cohort(args.clients, args.workers,
                                               start_t, args.seed)
            state = store.gather(
                cohort, member=getattr(state, "member", ()), like=state,
                seed_params=(args.clients > args.workers
                             and not bundle.engine.algo.has_center))
        emit_final(state, start_t, note=" (restored checkpoint)")
        return 0

    t0 = time.time()
    if args.round:
        # Round-based execution: ONE dispatch per communication period (k
        # scanned local steps + sync, state donated, losses buffered
        # device-side), tokens prefetched per round.  VRL-SGD-W's warmup
        # runs the first period as a 1-step round (compiled separately,
        # once).  A CommSchedule sizes each round from its stage; the
        # RoundCache keys one compiled executable per distinct k, so a
        # stagewise run compiles at most len(stages) rounds.  --log-every
        # counts rounds here.
        k_round = hier.k1 if hier else args.k
        warm_first = (sched is None and args.warmup
                      and engine_mod.get_spec(args.algorithm).warmup_aware)
        round_fn = engine_mod.RoundCache(bundle.round_step)
        # chaos machinery: the fault round is its own RoundCache (the
        # (k, W) multiplier is one more scanned operand, so it compiles
        # separately and the clean path stays the clean executable)
        fault_round_fn = (engine_mod.RoundCache(bundle.round_step_fault)
                          if faults is not None else None)
        set_member = None
        cur_mask = np.ones(args.workers, np.float32)
        if membership and bundle.engine is not None:
            set_member = jax.jit(bundle.engine.set_membership)
            if hasattr(state, "member") and not isinstance(
                    state.member, tuple):
                cur_mask = np.asarray(state.member.active).reshape(-1)
        health_fn = jax.jit(bundle.health) if args.guard else None
        # client sampling: the cohort recentre is its own tiny jit (the
        # compiled round stays the UNCHANGED clean executable), and it only
        # runs when the cohort is a strict subset — full participation
        # must stay bitwise the storeless path
        recenter_fn = None
        if store is not None and args.clients > args.workers:
            recenter_fn = jax.jit(bundle.engine.recenter_drift,
                                  donate_argnums=(0,))
        # strict-subset cohorts start the round FROM the server consensus
        # (the federated broadcast): what persists per client is the
        # control variate / bias / moments / residual.  A client
        # re-entering with params from many rounds ago would otherwise
        # book the whole consensus gap into its Δ via (x̂' − x_i)/(k·γ)
        # and blow up its next participation.  EASGD keeps per-client
        # params — persistent local params are elastic averaging's point.
        seed_cohort = (store is not None and args.clients > args.workers
                       and not bundle.engine.algo.has_center)
        last_good = None        # last healthy round-mean loss (--guard)
        retries = 0
        t, r = start_t, 0
        while t < args.steps:
            if sched is not None:
                rk = sched.period_starting_at(t)
            else:
                rk = 1 if (warm_first and t == 0) else k_round
            if args.steps - t < rk:
                # tail shorter than a round: finish per-step so the sync
                # cadence matches the per-step driver exactly (no
                # off-cadence closing sync, no extra whole-round compile).
                # Under overlap the per-step sync would not maintain the
                # pend buffer, so the tail runs local steps only — its
                # contribution folds at the next boundary, which never
                # comes (the tail is the end of the run).
                cohort = None
                if store is not None:
                    cohort = clients_mod.sample_cohort(
                        args.clients, args.workers, t, args.seed)
                    with phase("gather"):
                        state = store.gather(cohort,
                                             member=getattr(state, "member",
                                                            ()),
                                             like=state,
                                             seed_params=seed_cohort)
                    mw.emit("cohort", t=t, clients=cohort.tolist())
                step = jax.jit(bundle.local_step if args.overlap
                               else bundle.train_step)
                while t < args.steps:
                    toks = jnp.asarray(data[t] if cohort is None
                                       else data[t][cohort])
                    labels = jnp.roll(toks, -1, axis=-1)
                    state, loss = step(state, toks, labels)
                    t += 1
                    if args.ckpt and t % args.ckpt_every == 0:
                        if store is not None:
                            store.scatter(state, cohort)
                        checkpoint(t)
                if store is not None:
                    store.scatter(state, cohort)
                el = eval_avg(state, toks, labels)
                print(f"step {t:5d} (tail)  "
                      f"local_loss {float(loss):.4f}  "
                      f"avg_model_loss {float(el):.4f}  "
                      f"({(time.time()-t0)/t:.2f}s/step)")
                mw.emit("tail", t=t, local_loss=float(loss),
                        avg_model_loss=float(el))
                break
            # client sampling: draw the round's cohort and load its rows
            # into the device buffers — one contiguous copy per flat
            # buffer.  The draw depends only on (seed, round-start step),
            # so a resumed or rolled-back run re-gathers the same cohort.
            cohort = None
            if store is not None:
                cohort = clients_mod.sample_cohort(
                    args.clients, args.workers, t, args.seed)
                with phase("gather"):
                    state = store.gather(cohort,
                                         member=getattr(state, "member", ()),
                                         like=state,
                                         seed_params=seed_cohort)
                mw.emit("cohort", t=t, clients=cohort.tolist())
            # membership repair at the round boundary: fold the fault
            # schedule's crash/rejoin history into a mask; one jitted
            # set_membership call redistributes the leavers' Δ over the
            # survivors (Σ Δ stays 0) and re-anchors rejoiners
            if faults is not None and set_member is not None:
                mask = faults.active_at(t, args.workers)
                if not np.array_equal(mask, cur_mask):
                    with phase("membership"):
                        state = set_member(state, mask)
                    cur_mask = mask
                    print(f"membership: step {t} active "
                          f"{int(mask.sum())}/{args.workers} "
                          f"{mask.astype(int).tolist()}")
                    mw.emit("membership", t=t,
                            active=mask.astype(int).tolist(),
                            n_active=int(mask.sum()))
            # a strict-subset cohort's corrections sum to the cohort mean,
            # not zero — recentre so the round's sync math holds
            if recenter_fn is not None:
                state = recenter_fn(state)
            snap = jax.device_get(state) if args.guard else None
            with phase("data"):
                toks = jnp.asarray(data[t:t + rk] if cohort is None
                                   else data[t:t + rk][:, cohort])
                labels = jnp.roll(toks, -1, axis=-1)
            gmul = (faults.grad_mul(t, rk, args.workers)
                    if faults is not None else None)
            if gmul is not None:
                print(f"chaos: gradient fault in round [{t}, {t + rk})")
                mw.emit("fault", t=t, k=rk,
                        events=faults.events_in(t, t + rk))
            if args.profile_round and r + 1 == args.profile_round \
                    and not profiled:
                jax.profiler.start_trace(args.profile_dir)
                profiling = True
            with phase("round"):
                if gmul is not None:
                    state, losses = fault_round_fn(state, toks, labels,
                                                   jnp.asarray(gmul))
                else:
                    state, losses = round_fn(state, toks, labels)
                if timers is not None or profiling:
                    # timed rounds block here so the sample is the real
                    # round wall-clock, not the dispatch latency
                    losses = jax.block_until_ready(losses)
            if profiling:
                jax.profiler.stop_trace()
                profiling, profiled = False, True
                print(f"profiler: traced round {r + 1} -> "
                      f"{args.profile_dir}")
            loss_r = (float(jnp.mean(losses))
                      if (health_fn is not None or mw.active) else None)
            diverged = None
            if health_fn is not None:
                if not bool(health_fn(state, jnp.asarray(loss_r))):
                    diverged = "non-finite state"
                elif (last_good is not None
                      and loss_r > _BLOWUP_FACTOR * last_good
                      + _BLOWUP_SLACK):
                    # a finite blow-up (e.g. a scale@w:s:mult poison)
                    # passes every finiteness check — catch it on the
                    # loss trend instead
                    diverged = (f"loss blow-up ({loss_r:.3g} vs last "
                                f"good {last_good:.3g})")
            # algorithm-health diagnostics at --log-every cadence (plus
            # the first/last round and any diverged round): one read-only
            # jit over the post-round state, separate from the round
            drec = None
            if diag_fn is not None and ((r + 1) % args.log_every == 0
                                        or r == 0
                                        or t + rk >= args.steps
                                        or diverged is not None):
                with phase("diag"):
                    drec = obs_diag.to_record(diag_fn(state))
                alarms = obs_diag.check_alarms(
                    drec, invariant_threshold=args.invariant_alarm)
                drec["alarms"] = alarms
                if alarms and health_fn is not None and diverged is None:
                    # the invariant monitor feeds the SAME rollback path
                    # as the loss/finiteness guard
                    diverged = "invariant alarm: " + "; ".join(alarms)
                elif alarms and health_fn is None:
                    print("invariant alarm (no --guard, continuing): "
                          + "; ".join(alarms))
            if diverged is not None:
                t_fail = t + rk
                if retries >= args.max_retries:
                    mw.emit("rollback", t_fail=t_fail, reason=diverged,
                            retry=retries, aborted=True)
                    mw.close()
                    raise SystemExit(
                        f"divergence guard: state still diverged after "
                        f"{retries} rollbacks at step {t + rk} — aborting")
                retries += 1
                time.sleep(min(0.05 * 2 ** retries, 1.0))   # backoff
                found = ckpt.latest_step(args.ckpt) if args.ckpt else None
                if found is not None and found[0] <= t:
                    back_t, back_path = found
                    state = jax.tree.map(jnp.asarray, load_from(back_path))
                    t = back_t
                else:                       # no checkpoint: round-start
                    state = jax.tree.map(jnp.asarray, snap)
                if set_member is not None and hasattr(state, "member") \
                        and not isinstance(state.member, tuple):
                    cur_mask = np.asarray(state.member.active).reshape(-1)
                print(f"divergence guard: {diverged} — rolled back "
                      f"to step {t} (retry {retries}/{args.max_retries})")
                mw.emit("rollback", t_fail=t_fail, reason=diverged,
                        back_to=t, retry=retries)
                if drec is not None:
                    mw.emit("diag", t=t_fail, r=r + 1, rolled_back=True,
                            **drec)
                continue
            if health_fn is not None:
                last_good = loss_r
            retries = 0
            # only a HEALTHY round's rows reach the store: a rolled-back
            # round never scatters, so its clients keep pre-round state
            if store is not None:
                with phase("scatter"):
                    store.scatter(state, cohort)
            t += rk
            r += 1
            mw.emit("round", t=t, r=r, k=rk, loss=loss_r,
                    wire_bytes=None if wire is None
                    else wire["wire_bytes"])
            mw.emit("sync", t=t, r=r, k_eff=rk,
                    participants=int(cur_mask.sum()),
                    wire_bytes=None if wire is None
                    else wire["wire_bytes"],
                    wire_bytes2=None if wire is None
                    else wire["wire_bytes2"])
            if drec is not None:
                mw.emit("diag", t=t, r=r, **drec)
                if args.diag:
                    print(f"diag: step {t:5d} (round {r})  "
                          + obs_diag.describe(drec))
            if r % args.log_every == 0 or r == 1 or t >= args.steps:
                with phase("eval"):
                    el = float(eval_avg(state, toks[-1], labels[-1]))
                ll = (loss_r if loss_r is not None
                      else float(jnp.mean(losses)))
                print(f"step {t:5d} (round {r})  "
                      f"local_loss {ll:.4f}  "
                      f"avg_model_loss {float(el):.4f}  "
                      f"({(time.time()-t0)/t:.2f}s/step)")
                mw.emit("eval", t=t, r=r, local_loss=ll,
                        avg_model_loss=float(el))
            if args.ckpt and t // args.ckpt_every > (t - rk) // args.ckpt_every:
                checkpoint(t)
    else:
        step = jax.jit(bundle.train_step)
        for t in range(start_t, args.steps):
            toks = jnp.asarray(data[t])
            labels = jnp.roll(toks, -1, axis=-1)
            state, loss = step(state, toks, labels)
            if (t + 1) % args.log_every == 0 or t == 0:
                el = eval_avg(state, toks, labels)
                print(f"step {t+1:5d}  local_loss {float(loss):.4f}  "
                      f"avg_model_loss {float(el):.4f}  "
                      f"({(time.time()-t0)/(t+1):.2f}s/step)")
                mw.emit("eval", t=t + 1, local_loss=float(loss),
                        avg_model_loss=float(el))
                if diag_fn is not None:
                    drec = obs_diag.to_record(diag_fn(state))
                    drec["alarms"] = obs_diag.check_alarms(
                        drec, invariant_threshold=args.invariant_alarm)
                    mw.emit("diag", t=t + 1, **drec)
                    if args.diag:
                        print(f"diag: step {t+1:5d}  "
                              + obs_diag.describe(drec))
            if args.ckpt and (t + 1) % args.ckpt_every == 0:
                checkpoint(t + 1)
    extra = ""
    end_meta = {"wall_s_train": round(time.time() - t0, 3)}
    if args.round:
        extra = (f", {round_fn.compiles} round executable"
                 f"{'s' if round_fn.compiles != 1 else ''} "
                 f"(k={list(round_fn.cached_ks)})")
        end_meta.update(rounds=r, round_executables=round_fn.compiles)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s{extra}")
    # final metrics off the average model over one fresh batch — the
    # chaos CI gate compares --loss-out across faulted/clean runs
    emit_final(state, args.steps, **end_meta)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
