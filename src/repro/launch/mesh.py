"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod or (2, 16, 16) multi-pod production mesh.

    The flat-buffer engine's row shards ride the existing "model" axis
    (``EngineConfig(shard_axis="model", shards=16)``): engine rows and
    tensor-parallel model dims shard over the SAME 16 devices, so the
    engine state stops replicating across the tensor group — a 16x
    per-device engine-HBM cut with zero extra mesh axes.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    return make_mesh(shape, axes, devices=devices)


def make_engine_mesh(workers: int, *, shards: int = 1, pods: int = 0,
                     shard_axis: str = "shard", devices=None):
    """Worker-grid mesh for shard_map'd flat-buffer runs, host or TPU.

    Builds the (pod, data) worker grid the engine's sync all-reduces over
    — ``(1, W)`` flat or ``(P, W/P)`` hierarchical — and appends a
    trailing ``shard_axis`` of size ``shards`` when row-sharding is on.
    The trailing position makes shard peers mesh-adjacent, so the
    per-shard worker all-reduce never crosses a shard boundary.
    """
    if pods and workers % pods:
        raise ValueError(f"workers {workers} not divisible by pods {pods}")
    shape = (pods, workers // pods) if pods else (1, workers)
    axes = ("pod", "data")
    if shards > 1:
        shape = shape + (shards,)
        axes = axes + (shard_axis,)
    n = math.prod(shape)
    devices = (jax.devices() if devices is None else devices)[:n]
    if len(devices) < n:
        raise ValueError(
            f"engine mesh {shape} needs {n} devices, have {len(devices)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n})")
    return make_mesh(shape, axes, devices=devices)


# TPU v5e hardware constants (per chip) used by the roofline model.
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
HBM_PER_CHIP = 16 * 2**30    # bytes (v5e: 16 GiB) — the engine-memory
#                              artifact's fit budget
CHIPS_PER_POD = 256          # 16x16 single pod
ICI_LINK_BW = 50e9           # B/s per link (intra-pod)
DCI_LINK_BW = 6.25e9         # B/s per link (cross-pod data-center tier) —
#                              the ~10x-slower tier whose traffic the
#                              hierarchical k2 period amortizes
