"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devices = jax.devices()[:n]
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (per chip) used by the roofline model.
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_LINK_BW = 50e9           # B/s per link
