"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devices = jax.devices()[:n]
    return make_mesh(shape, axes, devices=devices)


# TPU v5e hardware constants (per chip) used by the roofline model.
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_LINK_BW = 50e9           # B/s per link (intra-pod)
DCI_LINK_BW = 6.25e9         # B/s per link (cross-pod data-center tier) —
#                              the ~10x-slower tier whose traffic the
#                              hierarchical k2 period amortizes
