import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   512 placeholder host devices back both the 16x16 single-pod mesh (first
#   256 devices) and the 2x16x16 multi-pod mesh.

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) combination this lowers the
real step function (train_step / prefill / serve_step) with ShapeDtypeStruct
inputs (zero allocation), compiles it for the production mesh, and records:

  * memory_analysis()   — per-device bytes (does it fit HBM?)
  * cost_analysis()     — per-device FLOPs / bytes for the roofline
  * collective bytes    — parsed from optimized HLO (loop-aware)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""
import argparse
import dataclasses
import functools
import json
import math
import sys
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import compressors as comm_mod
from repro.configs.base import (EngineConfig, HierConfig, InputShape,
                                MeshConfig, VRLConfig)
from repro.configs import registry
from repro.core import engine as engine_mod
from repro.core import schedule as schedule_mod
from repro.launch import roofline as rl
from repro.launch.mesh import (CHIPS_PER_POD, HBM_PER_CHIP,
                               make_production_mesh)
from repro.models import transformer
from repro.models.param import abstract as abstract_params
from repro.serve.engine import make_prefill, make_serve_step
from repro.sharding import specs as sh
from repro.train.train_loop import make_train_step


# --------------------------------------------------------------------- mesh
def build_mesh(mesh_cfg: MeshConfig):
    n = math.prod(mesh_cfg.shape)
    return compat.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names,
                            devices=jax.devices()[:n])


def _data_axes(mesh_cfg: MeshConfig):
    return tuple(mesh_cfg.worker_axes) + tuple(mesh_cfg.fsdp_axes)


def _axis_size(mesh_cfg: MeshConfig, axes) -> int:
    sizes = dict(zip(mesh_cfg.axis_names, mesh_cfg.shape))
    return math.prod(sizes[a] for a in axes) if axes else 1


# -------------------------------------------------------------- input specs
def input_specs(arch_id: str, shape_id: str, mesh_cfg: MeshConfig,
                cfg=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this combo."""
    cfg = cfg or registry.padded_arch(arch_id, mesh_cfg)
    shape = registry.get_shape(shape_id)
    w = mesh_cfg.num_workers
    if shape.kind == "train":
        b = shape.global_batch // w
        if cfg.frontend == "codec":
            inp = jax.ShapeDtypeStruct((w, b, shape.seq_len, cfg.frontend_dim),
                                       jnp.bfloat16)
        else:
            inp = jax.ShapeDtypeStruct((w, b, shape.seq_len), jnp.int32)
        lab = jax.ShapeDtypeStruct((w, b, shape.seq_len), jnp.int32)
        return {"tokens": inp, "labels": lab}
    if shape.kind == "prefill":
        if cfg.frontend == "codec":
            inp = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.frontend_dim),
                jnp.bfloat16)
        else:
            inp = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32)
        return {"tokens": inp}
    # decode: one new token against a seq_len cache
    window = _decode_window(cfg, shape)
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len,
                                       dtype=jnp.bfloat16, window=window))
    if cfg.frontend == "codec":
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.frontend_dim),
                                   jnp.bfloat16)
    else:
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"tokens": tok, "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _decode_window(cfg, shape: InputShape) -> Optional[int]:
    """long_500k needs sub-quadratic attention: SSM/hybrid run natively,
    full-attention archs run the sliding-window variant."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        if cfg.attn_window is not None:
            return cfg.attn_window
        return cfg.long_context_window
    return cfg.attn_window


# ---------------------------------------------------------------- shardings
def _maybe(axes, size_needed: int, mesh_cfg: MeshConfig):
    """Axes tuple if it divides size_needed, else None (replicated)."""
    if not axes:
        return None
    if size_needed % _axis_size(mesh_cfg, axes) == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def batch_sharding_spec(mesh_cfg: MeshConfig, batch: int, extra: int,
                        *, worker_stacked: bool) -> P:
    if worker_stacked:
        lead = sh._norm(tuple(mesh_cfg.worker_axes))
        inner = _maybe(tuple(mesh_cfg.fsdp_axes), batch, mesh_cfg)
        return P(lead, inner, *([None] * extra))
    axes = _maybe(_data_axes(mesh_cfg), batch, mesh_cfg)
    return P(axes, *([None] * extra))


def cache_specs(cfg, mesh_cfg: MeshConfig, batch: int, seq_len: int = 0):
    """PartitionSpec tree mirroring init_cache's (layer-stacked) structure.

    KV layout policy: shard kv heads over the tensor axis when divisible;
    otherwise shard the cache SEQ dim (distributed flash-decode: per-shard
    partial softmax combined by small all-reduces) — replicating a 32k cache
    across 16 tensor shards would blow HBM on the GQA-8 architectures.
    """
    t = mesh_cfg.tensor_size
    bax = _maybe(_data_axes(mesh_cfg), batch, mesh_cfg)
    tax = sh._norm(tuple(mesh_cfg.tensor_axes))
    kvh = None
    seq_ax = None
    if cfg.num_kv_heads and cfg.num_kv_heads % t == 0:
        kvh = tax
    elif seq_len and seq_len % t == 0:
        seq_ax = tax
    ssmh = tax if cfg.ssm_state and cfg.ssm_num_heads % t == 0 else None

    attn = {"k": P(None, bax, seq_ax, kvh, None),
            "v": P(None, bax, seq_ax, kvh, None)}
    ssm_c = {"state": P(None, bax, ssmh, None, None),
             "conv": P(None, bax, None, None)}
    if cfg.family == "ssm":
        return ssm_c
    if cfg.family == "hybrid":
        return {"attn": attn, "ssm": ssm_c}
    return attn


def state_specs(cfg, mesh_cfg: MeshConfig, vrl_cfg: VRLConfig):
    """PartitionSpec tree for WorkerState."""
    from repro.core.types import CommState, WorkerState
    defs = transformer.model_defs(cfg)
    pspec = sh.partition_specs(defs, cfg, mesh_cfg)
    wspec = jax.tree.map(lambda s: sh.worker_stacked_spec(s, mesh_cfg),
                         pspec, is_leaf=lambda x: isinstance(x, P))
    if vrl_cfg.inner_optimizer == "sgd" and not vrl_cfg.momentum:
        inner = ()
    elif vrl_cfg.inner_optimizer == "adam":
        from repro.optim.optimizers import AdamState
        inner = AdamState(wspec, wspec, P())
    else:
        inner = wspec
    center = pspec if vrl_cfg.algorithm == "easgd" else None
    spec = engine_mod.get_spec(vrl_cfg.algorithm)
    bias = wspec if engine_mod.use_bias(spec, vrl_cfg) else None
    comp, _ = comm_mod.resolve_pair(vrl_cfg)
    comm = ()
    if comp is not None:
        comm = CommState(
            resid=(wspec if comp.error_feedback else ()),
            ref=(() if (spec.grad_all_reduce or spec.sync == "none")
                 else pspec))
    return WorkerState(params=wspec, delta=wspec, inner=inner, center=center,
                       step=P(), last_sync=P(), bias=bias, comm=comm)


# ------------------------------------------------------------------- lower
@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    fn: str
    ok: bool
    compile_s: float
    per_device_bytes: int
    roofline: Optional[rl.Roofline]
    error: str = ""
    compressor: str = ""         # active compressor for this fn's level
    comp_bytes: int = 0          # compressed wire bytes of the sync payload

    def to_json(self) -> dict:
        d = {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "fn": self.fn, "ok": self.ok, "compile_s": round(self.compile_s, 2),
            "per_device_bytes": self.per_device_bytes, "error": self.error,
        }
        if self.compressor:
            d.update(compressor=self.compressor, comp_bytes=self.comp_bytes)
        if self.roofline:
            r = self.roofline
            d.update(hlo_flops=r.hlo_flops, hlo_bytes=r.hlo_bytes,
                     coll_bytes=r.coll_bytes, dci_bytes=r.dci_bytes,
                     model_flops=r.model_flops,
                     t_compute=r.t_compute, t_memory=r.t_memory,
                     t_collective=r.t_collective, bottleneck=r.bottleneck,
                     useful_ratio=r.useful_ratio, coll_detail=r.coll_detail)
        return d


def _mem_bytes(compiled) -> int:
    try:
        ma = compiled.memory_analysis()
        return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes + ma.generated_code_size_in_bytes)
    except Exception:
        return -1


def _model_flops_train(cfg, shape: InputShape) -> float:
    return 6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len


def _model_flops_prefill(cfg, shape: InputShape) -> float:
    return 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len


def _model_flops_decode(cfg, shape: InputShape) -> float:
    return 2.0 * cfg.active_param_count() * shape.global_batch


# --------------------------------------------------- engine-state memory
def _leaf_per_device(shape, nbytes: int, workers: int, shards: int) -> int:
    """Per-device bytes of one engine-state leaf under the engine's
    placement rules: worker-stacked leading dims ((W, ...) or pod-major
    (P, D, ...)) split over the worker axes, and the row dim (-2) splits
    over the shard axis exactly when ``core.engine._row_axis`` would
    shard it — ``shape[-2] > 1 and shape[-2] % shards == 0``.  Everything
    else (step counters, pend_k) replicates."""
    div = 1
    if len(shape) >= 3 and shape[0] == workers:
        div *= workers                              # (W, R, C) stacks
    elif len(shape) >= 4 and shape[0] * shape[1] == workers:
        div *= workers                              # (P, D, R, C) grids
    if (shards > 1 and len(shape) >= 2
            and shape[-2] > 1 and shape[-2] % shards == 0):
        div *= shards
    return nbytes // div


def _engine_state_bytes(cfg, vrl_cfg: VRLConfig, workers: int) -> dict:
    """{leaf path: (shape, dtype, bytes, per_device_bytes)} for the flat
    engine's state, from ``eval_shape`` alone — no allocation, no compile,
    so it works at kimi-k2-1t scale on any host."""
    template = jax.eval_shape(functools.partial(
        transformer.init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    eng = engine_mod.make_engine(vrl_cfg, template)
    state = jax.eval_shape(lambda: eng.init(
        transformer.init_params(cfg, jax.random.PRNGKey(0),
                                dtype=jnp.bfloat16), workers))
    shards = vrl_cfg.engine.shards
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(p, "name", getattr(p, "key",
                       getattr(p, "idx", p)))) for p in path)
        nb = int(np.prod(leaf.shape, dtype=np.int64)
                 * jnp.dtype(leaf.dtype).itemsize) if leaf.shape else \
            jnp.dtype(leaf.dtype).itemsize
        out[key] = {"shape": list(leaf.shape), "dtype": str(leaf.dtype),
                    "bytes": nb,
                    "per_device_bytes": _leaf_per_device(
                        leaf.shape, nb, workers, shards)}
    return out


def engine_mem(arch_id: str, *, algorithm: str = "vrl_sgd",
               inner: str = "adam", workers: int = 0, shards: int = 1,
               moment_dtype: str = "float32", sm3: bool = False,
               clients: int = 0, verbose: bool = True) -> dict:
    """Analytic engine-state HBM artifact for one (arch, sharding,
    moment-storage) point, plus the unsharded-fp32 baseline.

    Fields:
      buffers            — per engine-state leaf: shape, dtype, total
                           bytes, per-device bytes under the placement
                           rules (worker dims / worker axes, row dim /
                           shard axis)
      total_bytes        — engine state summed over all workers (what a
                           checkpoint holds; placement-invariant)
      per_device_bytes   — what ONE chip persists between steps
      baseline_per_device_bytes, reduction
                         — the same arch at shards=1 / fp32 / no SM3,
                           and baseline/current (the headline factor)
      devices_used       — workers x shards chips the placement occupies
      fits_pod           — devices_used <= CHIPS_PER_POD and
                           per_device_bytes <= HBM_PER_CHIP (v5e 16 GiB)
      t_engine_pass      — roofline HBM seconds of one fused local step's
                           engine traffic (2x per-device bytes / HBM BW)
      client_store_bytes — with ``clients`` = M > 0: the HOST bytes of a
                           ``core.clients.ClientStore`` holding M logical
                           clients behind the W-slot device window — each
                           per-participant leaf ((W, ...) leading axis)
                           scaled by M/W, globals counted once.  Host
                           RAM, not HBM: it never rides a chip.
    """
    mesh_cfg = registry.mesh_roles(arch_id, multi_pod=False, serving=False)
    cfg = registry.padded_arch(arch_id, mesh_cfg)
    workers = workers or mesh_cfg.num_workers
    delta_dt = ("bfloat16" if (arch_id in registry._FSDP_ARCHS
                               or os.environ.get("VRL_DELTA_BF16"))
                else "float32")

    def _cfg(s, mdt, sm):
        return VRLConfig(algorithm=algorithm, inner_optimizer=inner,
                         update_backend="xla", delta_dtype=delta_dt,
                         moment_dtype=mdt, sm3=sm,
                         engine=EngineConfig(shards=s))

    bufs = _engine_state_bytes(cfg, _cfg(shards, moment_dtype, sm3), workers)
    base = _engine_state_bytes(cfg, _cfg(1, "float32", False), workers)
    per_dev = sum(b["per_device_bytes"] for b in bufs.values())
    base_dev = sum(b["per_device_bytes"] for b in base.values())
    devices = workers * shards
    art = {
        "arch": arch_id, "algorithm": algorithm, "inner": inner,
        "workers": workers, "shards": shards,
        "moment_dtype": moment_dtype, "sm3": sm3,
        "delta_dtype": delta_dt,
        "buffers": bufs,
        "total_bytes": sum(b["bytes"] for b in bufs.values()),
        "per_device_bytes": per_dev,
        "baseline_per_device_bytes": base_dev,
        "reduction": round(base_dev / per_dev, 2) if per_dev else 0.0,
        "devices_used": devices,
        "hbm_per_chip": HBM_PER_CHIP, "chips_per_pod": CHIPS_PER_POD,
        "fits_pod": (devices <= CHIPS_PER_POD
                     and per_dev <= HBM_PER_CHIP),
        "t_engine_pass": rl.engine_pass_time(per_dev),
    }
    if clients:
        if clients < workers:
            raise ValueError(f"clients ({clients}) must be >= workers "
                             f"({workers}) — the cohort size is the "
                             f"worker count")
        store = 0
        for b in bufs.values():
            per_participant = (len(b["shape"]) >= 3
                               and b["shape"][0] == workers)
            store += (b["bytes"] // workers * clients if per_participant
                      else b["bytes"])
        art["clients"] = clients
        art["client_store_bytes"] = store
    if verbose:
        extra = (f", client store {art['client_store_bytes']/2**30:.2f} "
                 f"GiB host (M={clients})" if clients else "")
        print(f"[engine-mem] {arch_id} {algorithm}/{inner} W={workers} "
              f"shards={shards} moments={moment_dtype}"
              f"{'+sm3' if sm3 else ''}: "
              f"{per_dev/2**30:.2f} GiB/device "
              f"(baseline {base_dev/2**30:.2f}, {art['reduction']}x), "
              f"{devices} chips, fits_pod={art['fits_pod']}{extra}")
    return art


def lower_one(arch_id: str, shape_id: str, *, multi_pod: bool,
              vrl_cfg: Optional[VRLConfig] = None,
              fn_kind: Optional[str] = None, verbose: bool = True,
              unrolled: bool = False, algorithm: str = "vrl_sgd",
              comm_period: int = 20, k1: int = 5, k2: int = 20,
              comm_schedule: Optional[str] = None, round_k: int = 0,
              backend: str = "fused",
              overlap: bool = False, deadline: float = 0.0,
              compress: Optional[str] = None,
              compress2: Optional[str] = None,
              shards: int = 1, moment_dtype: str = "float32",
              sm3: bool = False,
              mesh_override: Optional[dict] = None,
              cfg_override: Optional[dict] = None, tag: str = "",
              last_only: bool = False, no_remat: bool = False):
    """Lower+compile one combination. fn_kind in
    {train, local, sync, sync1, sync2, round, prefill, decode} (default by
    shape kind; sync1/sync2 are the hierarchical per-level syncs and require
    ``algorithm="hier_vrl_sgd"``; "round" lowers ``bundle.round_step`` — one
    scanned communication period with the state donated, tokens stacked
    (k, W, b, s)).

    The train family lowers through ``backend`` ("fused" default: the
    flat-buffer engine, so the memory/cost/collective-bytes artifacts
    reflect the production TPU update path even when lowering on a CPU
    host; "auto"/"xla" lower the plain-jnp executor, "reference" the
    per-leaf tree path — one flat all-reduce per sync and one per-axis
    all-reduce per hierarchical sync level in every engine variant).

    ``unrolled=True`` unrolls the layer scan so cost_analysis() counts every
    layer (XLA's HLO cost analysis counts a while-loop body ONCE); use the
    scanned variant for the memory/fit artifact and the unrolled one for
    roofline terms.

    Link-tier attribution (``Roofline.dci_bytes``) and the per-level
    compressed wire bytes are exact on the PER-LEVEL lowerings: "sync2"
    prices its cross-pod all-reduce at DCI bandwidth and reports the
    level-2 compressor; everything else is ICI / level-1.  Hierarchical
    "round"/"train" lowerings aggregate BOTH levels in one HLO, so their
    collective term is priced at ICI rate and comp_bytes shows level 1
    only — use the sync1/sync2 artifacts as the tier-attributed source of
    truth."""
    serving = fn_kind in ("prefill", "decode") or (
        fn_kind is None and registry.get_shape(shape_id).kind != "train")
    mesh_cfg = registry.mesh_roles(arch_id, multi_pod=multi_pod,
                                   serving=serving)
    if mesh_override:
        mesh_cfg = dataclasses.replace(mesh_cfg, **mesh_override)
    cfg = registry.padded_arch(arch_id, mesh_cfg)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = registry.get_shape(shape_id)
    hier = None
    if algorithm == "hier_vrl_sgd" and vrl_cfg is None:
        sizes = dict(zip(mesh_cfg.axis_names, mesh_cfg.shape))
        pods = sizes.get("pod", 1)
        hier = HierConfig(k1=k1, k2=k2,
                          grid=(pods, mesh_cfg.num_workers // pods))
    sched = (schedule_mod.parse_schedule(comm_schedule, comm_period)
             if comm_schedule else None)
    if compress2 and algorithm != "hier_vrl_sgd":
        # match launch/train.py: flat algorithms have one level
        raise ValueError("--compress2 drives the hierarchical cross-pod "
                         "sync2; flat algorithms have one level "
                         "(--compress)")
    vrl_cfg = vrl_cfg or VRLConfig(
        algorithm=algorithm, comm_period=comm_period, hier=hier,
        comm_schedule=sched, update_backend=backend,
        overlap=overlap, deadline=deadline,
        compress=(comm_mod.parse_compressor(compress) if compress
                  else None),
        compress2=(comm_mod.parse_compressor(compress2) if compress2
                   else None),
        moment_dtype=moment_dtype, sm3=sm3,
        # the production mesh carries no dedicated shard axis — engine row
        # shards REUSE the tensor axis "model" (launch/mesh.py), so
        # shards must equal that axis's size when > 1
        engine=EngineConfig(shards=shards,
                            shard_axis="model" if shards > 1 else "shard"),
        delta_dtype="bfloat16" if (arch_id in registry._FSDP_ARCHS
                                   or os.environ.get("VRL_DELTA_BF16"))
        else "float32")
    mesh = build_mesh(mesh_cfg)
    mesh_name = "multi" if multi_pod else "single"
    chips = math.prod(mesh_cfg.shape)
    if fn_kind is None:
        fn_kind = {"train": "train", "prefill": "prefill",
                   "decode": "decode"}[shape.kind]

    unroll = cfg.num_layers if unrolled else 1
    ins = input_specs(arch_id, shape_id, mesh_cfg, cfg=cfg)
    t0 = time.time()
    name = f"{arch_id}/{shape_id}/{mesh_name}/{fn_kind}"
    if unrolled:
        name += "/unrolled"
    if tag:
        name += f"/{tag}"

    eng_spec = None               # flat-buffer layout (for wire-bytes)
    with compat.set_mesh(mesh):
        if fn_kind in ("train", "local", "sync", "sync1", "sync2", "round"):
            fused = engine_mod.resolve_backend(vrl_cfg) != "reference"
            with warnings.catch_warnings():
                # dryrun's fused default deliberately lowers the Pallas
                # path on a CPU host (artifacts reflect the TPU plan;
                # nothing executes) — the engine's interpret-mode perf
                # warning does not apply to compile-only lowering
                warnings.filterwarnings(
                    "ignore", message=".*interpret-mode Pallas.*")
                bundle = make_train_step(cfg, vrl_cfg,
                                         remat=not no_remat, unroll=unroll,
                                         param_dtype=jnp.bfloat16,
                                         mesh=mesh if fused else None,
                                         worker_axes=mesh_cfg.worker_axes)
            state_abs = jax.eval_shape(
                lambda: bundle.init_state(jax.random.PRNGKey(0),
                                          mesh_cfg.num_workers))
            if bundle.engine is not None:
                eng_spec = bundle.engine.spec
            if fused:
                # hier axes resolve against THIS mesh: the single mesh has
                # no "pod" axis, so its (1, W) grid shards data only
                haxes = tuple(a if a in mesh_cfg.axis_names else None
                              for a in engine_mod.hier_config(vrl_cfg).axes)
                sh_ax = sh.engine_shard_axis(mesh_cfg, vrl_cfg.engine)
                st_spec = engine_mod.state_partition_specs(
                    state_abs, mesh_cfg.worker_axes, hier_axes=haxes,
                    shard_axis=sh_ax, shards=vrl_cfg.engine.shards)
            else:
                st_spec = state_specs(cfg, mesh_cfg, vrl_cfg)
            sts = compat.shardings(mesh, st_spec)
            extra = 2 if cfg.frontend == "codec" else 1
            tok_spec = batch_sharding_spec(
                mesh_cfg, shape.global_batch // mesh_cfg.num_workers,
                extra, worker_stacked=True)
            lab_spec = batch_sharding_spec(
                mesh_cfg, shape.global_batch // mesh_cfg.num_workers,
                1, worker_stacked=True)
            if fn_kind in ("sync", "sync1", "sync2"):
                step_fn = {"sync": bundle.sync_step,
                           "sync1": bundle.sync1_step,
                           "sync2": bundle.sync2_step}[fn_kind]
                if step_fn is None:
                    raise ValueError(
                        f"fn_kind {fn_kind!r} requires hier_vrl_sgd")
                fn = jax.jit(step_fn, in_shardings=(sts,),
                             out_shardings=sts)
                lowered = fn.lower(state_abs)
            elif fn_kind == "round":
                # one scanned communication period: (k, W, ...) stacks,
                # state donated — the artifacts show the no-copy round.
                # ``round_k`` overrides the length (a stagewise schedule's
                # per-stage round is the same executable at that stage's k)
                hcfg = engine_mod.hier_config(vrl_cfg)
                rk = round_k or (hcfg.k1 if algorithm == "hier_vrl_sgd"
                                 else vrl_cfg.comm_period)
                stk = jax.ShapeDtypeStruct(
                    (rk, *ins["tokens"].shape), ins["tokens"].dtype)
                slb = jax.ShapeDtypeStruct(
                    (rk, *ins["labels"].shape), ins["labels"].dtype)
                tks = compat.shardings(mesh, P(None, *tok_spec))
                lbs = compat.shardings(mesh, P(None, *lab_spec))
                fn = jax.jit(bundle.round_step, donate_argnums=(0,),
                             in_shardings=(sts, tks, lbs),
                             out_shardings=(sts,
                                            compat.shardings(mesh,
                                                             P(None))))
                lowered = fn.lower(state_abs, stk, slb)
            else:
                step = (bundle.train_step if fn_kind == "train"
                        else bundle.local_step)
                fn = jax.jit(step,
                             in_shardings=(sts,
                                           compat.shardings(mesh, tok_spec),
                                           compat.shardings(mesh, lab_spec)),
                             out_shardings=(sts,
                                            compat.shardings(mesh, P())))
                lowered = fn.lower(state_abs, ins["tokens"], ins["labels"])
            mf = _model_flops_train(cfg, shape)
            if fn_kind in ("sync", "sync1", "sync2"):
                mf = 0.0
            elif fn_kind == "round":
                mf = mf * rk
        elif fn_kind == "prefill":
            pdefs = transformer.model_defs(cfg)
            params_abs = abstract_params(pdefs, jnp.bfloat16)
            pspec = sh.partition_specs(pdefs, cfg, mesh_cfg)
            prefill_fn = make_prefill(cfg, shape.seq_len, unroll=unroll,
                                      last_only=last_only)
            extra = 2 if cfg.frontend == "codec" else 1
            tok_spec = batch_sharding_spec(mesh_cfg, shape.global_batch,
                                           extra, worker_stacked=False)
            bax = _maybe(_data_axes(mesh_cfg), shape.global_batch, mesh_cfg)
            vax = _maybe(tuple(mesh_cfg.tensor_axes), cfg.vocab_size, mesh_cfg)
            logits_spec = P(bax, None, vax)
            eff = cfg.attn_window or shape.seq_len
            c_spec = cache_specs(cfg, mesh_cfg, shape.global_batch,
                                 seq_len=min(eff, shape.seq_len))
            fn = jax.jit(prefill_fn,
                         in_shardings=compat.shardings(
                             mesh, (pspec, tok_spec)),
                         out_shardings=compat.shardings(
                             mesh, (logits_spec, c_spec)))
            lowered = fn.lower(params_abs, ins["tokens"])
            mf = _model_flops_prefill(cfg, shape)
        elif fn_kind == "decode":
            pdefs = transformer.model_defs(cfg)
            params_abs = abstract_params(pdefs, jnp.bfloat16)
            pspec = sh.partition_specs(pdefs, cfg, mesh_cfg)
            window = _decode_window(cfg, shape)
            serve_fn = make_serve_step(cfg, window=window, unroll=unroll)
            eff = window if window is not None else shape.seq_len
            c_spec = cache_specs(cfg, mesh_cfg, shape.global_batch,
                                 seq_len=min(eff, shape.seq_len))
            extra = 2 if cfg.frontend == "codec" else 1
            tok_spec = batch_sharding_spec(mesh_cfg, shape.global_batch,
                                           extra, worker_stacked=False)
            bax = _maybe(_data_axes(mesh_cfg), shape.global_batch, mesh_cfg)
            vax = _maybe(tuple(mesh_cfg.tensor_axes), cfg.vocab_size, mesh_cfg)
            logits_spec = P(bax, None, vax)
            fn = jax.jit(serve_fn,
                         in_shardings=compat.shardings(
                             mesh, (pspec, c_spec, tok_spec, P())),
                         out_shardings=compat.shardings(
                             mesh, (logits_spec, c_spec)))
            lowered = fn.lower(params_abs, ins["cache"], ins["tokens"],
                               ins["pos"])
            mf = _model_flops_decode(cfg, shape)
        else:
            raise ValueError(fn_kind)

        compiled = lowered.compile()

    dt = time.time() - t0
    hlo = compiled.as_text()
    # the hierarchical level-2 sync's only collective crosses pods: its
    # bytes ride the slow DCI tier in the roofline (sync1/locals are ICI)
    # an overlapped round hides its collective behind the k local steps:
    # the roofline prices only the exposed remainder in the bottleneck
    roof = rl.analyze(name, compiled, hlo, mf, chips,
                      dci_fraction=1.0 if fn_kind == "sync2" else 0.0,
                      overlap=(fn_kind == "round" and vrl_cfg.overlap))
    # per-level compressed wire bytes of the sync payload, next to the
    # raw-payload collective bytes the HLO measures
    c1, c2 = comm_mod.resolve_pair(vrl_cfg)
    level_comp = c2 if fn_kind == "sync2" else c1
    comp_label, comp_bytes = "", 0
    if level_comp is not None and eng_spec is not None \
            and fn_kind in ("train", "sync", "sync1", "sync2", "round"):
        item = jnp.dtype(eng_spec.dtype).itemsize
        comp_label = level_comp.label()
        comp_bytes = comm_mod.wire_bytes(
            level_comp, rows=eng_spec.rows, lanes=eng_spec.lanes,
            size=eng_spec.size, itemsize=item)
    fn_label = fn_kind + ("+unroll" if unrolled else "") + \
        (f"+{tag}" if tag else "")
    res = DryrunResult(arch=arch_id, shape=shape_id, mesh=mesh_name,
                       fn=fn_label, ok=True, compile_s=dt,
                       per_device_bytes=_mem_bytes(compiled), roofline=roof,
                       compressor=comp_label, comp_bytes=comp_bytes)
    if verbose:
        try:
            print(compiled.memory_analysis())
        except Exception as e:  # noqa: BLE001
            print("memory_analysis unavailable:", e)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        comp_note = ""
        if comp_label:
            raw = comm_mod.raw_bytes(eng_spec.rows, eng_spec.lanes,
                                     jnp.dtype(eng_spec.dtype).itemsize)
            comp_note = (f"  wire[{comp_label}]="
                         f"{comp_bytes/2**20:.2f} MiB ({raw/comp_bytes:.1f}x)")
        print(f"[{name}] compile {dt:.1f}s  mem/device "
              f"{res.per_device_bytes/2**30:.2f} GiB  "
              f"bottleneck={roof.bottleneck}  "
              f"terms(ms) c={roof.t_compute*1e3:.3f} "
              f"m={roof.t_memory*1e3:.3f} coll={roof.t_collective*1e3:.3f}"
              + comp_note)
    return res


FN_KINDS_BY_SHAPE = {
    "train_4k": ["train", "local", "sync"],
    "prefill_32k": ["prefill"],
    "decode_32k": ["decode"],
    "long_500k": ["decode"],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--fn", default=None,
                    help="train|local|sync|sync1|sync2|round|prefill|decode "
                         "(default by shape; sync1/sync2 need hier_vrl_sgd; "
                         "round = one scanned comm period, state donated)")
    ap.add_argument("--all", action="store_true",
                    help="run the full arch x shape matrix")
    ap.add_argument("--unrolled", action="store_true",
                    help="unroll the layer scan (accurate roofline flops)")
    ap.add_argument("--algorithm", default="vrl_sgd",
                    choices=sorted(engine_mod.ALGO_SPECS))
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "reference", "xla", "auto"],
                    help="update-math backend for the train lowerings "
                         "(fused default: artifacts reflect the production "
                         "TPU Pallas path; auto resolves by the HOST jax "
                         "backend — xla on a CPU host)")
    ap.add_argument("--k1", type=int, default=5,
                    help="hier_vrl_sgd intra-pod period")
    ap.add_argument("--k2", type=int, default=20,
                    help="hier_vrl_sgd cross-pod period")
    ap.add_argument("--comm-schedule", default=None,
                    help="stagewise round schedule for the train lowerings "
                         "(const|stagewise[:k0:rounds:k_max]|custom:kxr,..)")
    ap.add_argument("--overlap", action="store_true",
                    help="lower the OVERLAPPED round (fn=round): the sync "
                         "collective is issued at round start over the "
                         "previous boundary's transmitted positions and "
                         "folds one-round-stale; the roofline prices only "
                         "the exposed collective remainder")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="straggler miss probability per participant per "
                         "round (requires --overlap; 0 disables)")
    ap.add_argument("--round-k", type=int, default=0,
                    help="fn=round: round length to lower (a stagewise "
                         "run compiles one such executable per stage k); "
                         "0 = comm period")
    ap.add_argument("--compress", default=None,
                    help="sync-payload compressor for the train lowerings "
                         "(none|int8|topk[:rate][:noef]); artifacts gain "
                         "the compressed wire bytes next to the raw "
                         "collective bytes")
    ap.add_argument("--compress2", default=None,
                    help="override the cross-pod sync2 compressor "
                         "(hier_vrl_sgd; default: --compress)")
    ap.add_argument("--shards", type=int, default=1,
                    help="row-shard the engine state over the mesh's "
                         "'model' axis (must equal its size when > 1); "
                         "also sets the --engine-mem placement")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="inner-optimizer moment storage dtype")
    ap.add_argument("--sm3", action="store_true",
                    help="SM3-factored adam second moment")
    ap.add_argument("--engine-mem", action="store_true",
                    help="emit the ANALYTIC engine-state memory artifact "
                         "(eval_shape only — no compile, works at "
                         "kimi-k2-1t scale): per-buffer + per-device "
                         "bytes, the unsharded-fp32 baseline and "
                         "reduction factor, and pod-fit under v5e HBM.  "
                         "Appends one JSON line per arch to --out")
    ap.add_argument("--inner", default="adam",
                    choices=["sgd", "momentum", "adam"],
                    help="--engine-mem inner optimizer (moment buffers "
                         "are the point, so adam by default)")
    ap.add_argument("--workers", type=int, default=0,
                    help="--engine-mem worker count (0 = the arch's "
                         "single-pod mesh role)")
    ap.add_argument("--clients", type=int, default=0,
                    help="--engine-mem: also size the HOST client store "
                         "of M logical clients behind the W worker slots "
                         "(per-participant buffers x M/W + globals)")
    ap.add_argument("--gate-bytes", type=int, default=0,
                    help="--engine-mem CI gate: exit 1 if any arch's "
                         "per-device engine bytes exceed this budget")
    ap.add_argument("--worker-axes", default=None,
                    help="comma list overriding VRL worker mesh axes")
    ap.add_argument("--fsdp-axes", default=None)
    ap.add_argument("--tensor-axes", default=None)
    ap.add_argument("--seq-shard-acts", action="store_true",
                    help="Megatron-style sequence-parallel activations")
    ap.add_argument("--tag", default="", help="label for this variant")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing in train lowering")
    ap.add_argument("--delta-bf16", action="store_true")
    ap.add_argument("--last-only", action="store_true",
                    help="prefill emits last-position logits only")
    ap.add_argument("--two-layer", action="store_true",
                    help="2-layer unrolled calibration lowering: per-layer "
                         "roofline cost = (this run) - (scanned run); "
                         "total = scanned + (L-1) * per-layer")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args(argv)

    archs = registry.list_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(registry.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]

    if args.engine_mem:
        over_budget = []
        for arch in archs:
            art = engine_mem(arch, algorithm=args.algorithm,
                             inner=args.inner, workers=args.workers,
                             shards=args.shards,
                             moment_dtype=args.moment_dtype, sm3=args.sm3,
                             clients=args.clients)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(art) + "\n")
            if args.gate_bytes and art["per_device_bytes"] > args.gate_bytes:
                over_budget.append(
                    f"{arch}: {art['per_device_bytes']} > {args.gate_bytes}")
        if over_budget:
            print("engine-mem gate FAILED:\n  " + "\n  ".join(over_budget),
                  file=sys.stderr)
            return 1
        print(f"engine-mem: {len(archs)} arch(s) OK"
              + (f" (gate {args.gate_bytes} B/device)" if args.gate_bytes
                 else ""))
        return 0

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            fns = [args.fn] if args.fn else FN_KINDS_BY_SHAPE[shape]
            for multi in meshes:
                for fn_kind in fns:
                    mesh_override = {}
                    for key, val in [("worker_axes", args.worker_axes),
                                     ("fsdp_axes", args.fsdp_axes),
                                     ("tensor_axes", args.tensor_axes)]:
                        if val is not None:
                            mesh_override[key] = tuple(
                                a for a in val.split(",") if a)
                    cfg_override = {}
                    if args.seq_shard_acts:
                        cfg_override["seq_shard_acts"] = True
                    if args.two_layer:
                        cfg_override["num_layers"] = 2
                    try:
                        res = lower_one(
                            arch, shape, multi_pod=multi, fn_kind=fn_kind,
                            unrolled=args.unrolled or args.two_layer,
                            algorithm=args.algorithm,
                            backend=args.backend, k1=args.k1, k2=args.k2,
                            overlap=args.overlap, deadline=args.deadline,
                            comm_schedule=args.comm_schedule,
                            round_k=args.round_k,
                            compress=args.compress,
                            compress2=args.compress2,
                            shards=args.shards,
                            moment_dtype=args.moment_dtype, sm3=args.sm3,
                            mesh_override=mesh_override or None,
                            cfg_override=cfg_override or None,
                            tag=args.tag or ("u2" if args.two_layer else ""),
                            last_only=args.last_only,
                            no_remat=args.no_remat)
                    except Exception as e:  # noqa: BLE001
                        failures += 1
                        mesh_name = "multi" if multi else "single"
                        fl = fn_kind + ("+unroll+u2" if args.two_layer
                                        else "+unroll" if args.unrolled
                                        else "") + (f"+{args.tag}" if args.tag else "")
                        res = DryrunResult(
                            arch=arch, shape=shape, mesh=mesh_name,
                            fn=fl, ok=False, compile_s=0.0,
                            per_device_bytes=-1, roofline=None,
                            error=f"{type(e).__name__}: {e}"[:500])
                        print(f"[FAIL] {arch}/{shape}/{mesh_name}/{fn_kind}: "
                              f"{res.error}", file=sys.stderr)
                    results.append(res)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(res.to_json()) + "\n")
    print(f"\ndry-run complete: {len(results) - failures}/{len(results)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
