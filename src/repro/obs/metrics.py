"""Schema-versioned JSONL metrics stream.

One record per line; every record carries ``schema`` (an integer, bumped
on breaking layout changes), ``event`` (the record type) and ``wall_s``
(seconds since the stream opened).  The FIRST record of a stream is
always ``run_start`` with a ``meta`` dict describing the run (arch,
algorithm, workers, k, resolved backend, wire bytes per sync, ...), so a
metrics file is self-describing — ``scripts/report.py`` needs nothing
else.

Event vocabulary the training driver emits (consumers must tolerate
unknown events — the set grows):

  run_start    stream header: ``meta`` run-description dict
  round        a compiled round committed: t, r, k, loss, wire_bytes
  sync         the round's sync collective: wire_bytes, participants
  diag         algorithm-health diagnostics (``Engine.diagnostics``):
               drift_sq_mean/drift_max/drift_per_worker, zeta_sq_proxy,
               delta_residual (+bias_residual), ef_resid_rms, mu/nu_rms,
               nonfinite_workers, alarms
  eval         averaged-model eval at a log boundary
  membership   the worker-slot mask changed: active list, n_active
  rollback     divergence guard (or invariant alarm) rolled back
  cohort       client sampling drew a cohort: client ids
  checkpoint   atomic save (killed=True when a simulated kill hit)
  restore      resume loaded a checkpoint
  fault        injected faults scheduled inside the upcoming round
  tail         per-step tail (steps not divisible by k)
  bench        benchmark row (see ``repro.obs.convert``)
  run_end      final record: steps, final/avg-model loss, phase timers

Writers flush after every record, so a crashed run leaves a valid
prefix — exactly what the chaos pipeline reads back.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1


def _json_safe(x: Any) -> Any:
    """Recursively coerce numpy/jax scalars and small arrays to plain
    python so ``json.dump`` accepts them."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        return {str(k): _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    item = getattr(x, "item", None)
    if item is not None and getattr(x, "ndim", 1) == 0:
        return _json_safe(item())
    tolist = getattr(x, "tolist", None)
    if tolist is not None:
        return _json_safe(tolist())
    return str(x)


class MetricsWriter:
    """Append-only JSONL event stream (see module docstring).

    Opens ``path`` eagerly (creating parent dirs) and writes the
    ``run_start`` header immediately; ``emit`` stamps ``schema`` /
    ``event`` / ``wall_s`` onto every record and flushes, so partial
    streams from crashed runs stay readable.  ``close`` is optional —
    nothing is buffered — but emits a final flush point for symmetry.
    """

    active = True

    def __init__(self, path: str, *, run_meta: Optional[Dict[str, Any]] = None,
                 source: str = "train"):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._t0 = time.time()
        self._f = open(path, "w")
        self._write({"schema": SCHEMA_VERSION, "event": "run_start",
                     "wall_s": 0.0, "source": source,
                     "meta": _json_safe(dict(run_meta or {}))})

    def _write(self, rec: Dict[str, Any]) -> None:
        json.dump(rec, self._f)
        self._f.write("\n")
        self._f.flush()

    def emit(self, event: str, **fields: Any) -> None:
        if self._f is None:
            return
        rec = {"schema": SCHEMA_VERSION, "event": str(event),
               "wall_s": round(time.time() - self._t0, 6)}
        rec.update(_json_safe(fields))
        self._write(rec)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "MetricsWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullWriter:
    """Inactive stand-in so driver code can emit unconditionally."""

    active = False
    path = None

    def emit(self, event: str, **fields: Any) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "NullWriter":
        return self

    def __exit__(self, *exc) -> None:
        pass


def read_metrics(path: str) -> List[Dict[str, Any]]:
    """Parse and validate a metrics JSONL file.

    Every line must be a JSON object with ``schema`` and ``event``;
    records from a NEWER schema than this reader are rejected loudly
    rather than misread.  Unknown event types pass through (the
    vocabulary grows; see module docstring).
    """
    records: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: invalid JSON: {e}") from e
            if not isinstance(rec, dict) or "event" not in rec \
                    or "schema" not in rec:
                raise ValueError(
                    f"{path}:{i + 1}: metrics records must be objects with "
                    "'schema' and 'event' fields")
            if int(rec["schema"]) > SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{i + 1}: schema {rec['schema']} is newer than "
                    f"this reader (supports <= {SCHEMA_VERSION})")
            records.append(rec)
    return records


def run_meta(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """The ``run_start`` header's ``meta`` dict ({} when absent)."""
    for rec in records:
        if rec.get("event") == "run_start":
            meta = rec.get("meta")
            return dict(meta) if isinstance(meta, dict) else {}
    return {}
