"""Converter shim between legacy benchmark results and the obs stream.

``results/`` grew three ad-hoc formats before the telemetry subsystem
existed: ``comm_bench.jsonl`` (raw dry-run rows, one JSON object per
line, no schema), ``comm_compress.json`` and ``comm_cohort.json``
(nested dicts with a ``table`` of either one or two key levels).  The
canonical form is now the schema-versioned obs JSONL stream: a
``run_start`` header whose meta carries every non-table field, then one
``bench`` record per table cell:

    {"schema": 1, "event": "bench", "bench": "<kind>",
     "key": ["ssgd/every_step/none", "117187"], "data": {...}}

``key`` is the cell's path inside the legacy ``table`` (one entry per
nesting level; row files use the line index), so ``legacy_view`` can
rebuild the exact legacy object and existing artifact consumers keep
working — the benchmark writes the canonical ``.jsonl`` AND the legacy
``.json`` through this shim.

Round-trip contract (tested): ``legacy_view(records_from_legacy(x))``
equals ``x`` up to JSON's own key stringification (ints used as dict
keys become strings, exactly as ``json.dump`` would emit them).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.metrics import SCHEMA_VERSION, _json_safe, read_metrics


def _is_row(node: Any) -> bool:
    """A table node is a row (emit it) when no value nests further."""
    return isinstance(node, dict) and \
        not any(isinstance(v, dict) for v in node.values())


def _walk(node: Dict[str, Any], key: List[str], out: List[dict],
          kind: str) -> None:
    if _is_row(node):
        out.append({"schema": SCHEMA_VERSION, "event": "bench",
                    "bench": kind, "key": list(key),
                    "data": _json_safe(node)})
        return
    for k, v in node.items():
        if not isinstance(v, dict):
            raise ValueError(
                f"mixed table node at {key + [str(k)]}: rows and "
                f"sub-tables cannot share a level")
        _walk(v, key + [str(k)], out, kind)


def records_from_legacy(obj: Any, kind: str) -> List[Dict[str, Any]]:
    """A legacy results object -> obs records (header + bench rows).

    ``obj`` is either the nested-dict shape (``table`` + scalar meta
    fields, e.g. comm_compress/comm_cohort) or a list of row dicts
    (e.g. the raw comm_bench JSONL lines).
    """
    header = {"schema": SCHEMA_VERSION, "event": "run_start",
              "wall_s": 0.0, "source": "bench", "bench": kind}
    out: List[Dict[str, Any]] = [header]
    if isinstance(obj, list):
        header["meta"] = {}
        for i, row in enumerate(obj):
            if not isinstance(row, dict):
                raise ValueError(f"row {i} of {kind} is not an object")
            out.append({"schema": SCHEMA_VERSION, "event": "bench",
                        "bench": kind, "key": [str(i)],
                        "data": _json_safe(row)})
        return out
    if not isinstance(obj, dict):
        raise ValueError(f"cannot convert {type(obj).__name__} to a "
                         f"bench stream")
    header["meta"] = _json_safe(
        {k: v for k, v in obj.items() if k != "table"})
    table = obj.get("table")
    if table is not None:
        _walk(table, [], out, kind)
    return out


def legacy_view(records: Sequence[Dict[str, Any]]) -> Any:
    """Rebuild the legacy object from an obs bench stream.

    Row streams (every key is a single line index) come back as a list;
    table streams come back as the meta fields + nested ``table``.
    """
    header = next((r for r in records if r.get("event") == "run_start"),
                  None)
    rows = [r for r in records if r.get("event") == "bench"]
    meta = dict((header or {}).get("meta") or {})
    if not meta and rows and all(len(r.get("key", [])) == 1
                                 and r["key"][0].isdigit() for r in rows):
        return [r["data"] for r in rows]
    table: Dict[str, Any] = {}
    for r in rows:
        node = table
        key = r.get("key", [])
        if not key:
            raise ValueError("bench record with an empty key cannot be "
                             "placed in a table")
        for k in key[:-1]:
            node = node.setdefault(k, {})
        node[key[-1]] = r["data"]
    out = dict(meta)
    if table or not rows:
        out["table"] = table
    return out


def write_jsonl(records: Sequence[Dict[str, Any]], path: str) -> str:
    """Write obs records as a canonical JSONL stream."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for rec in records:
            json.dump(rec, f)
            f.write("\n")
    return path


def write_legacy_json(records: Sequence[Dict[str, Any]], path: str,
                      indent: int = 1) -> str:
    """Write the legacy .json view of an obs bench stream (the shim for
    pre-obs artifact consumers)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(legacy_view(records), f, indent=indent)
    return path


def convert_file(src: str, dst: str, kind: Optional[str] = None) -> str:
    """File-to-file conversion, direction inferred from extensions:
    legacy (.json / raw .jsonl rows) -> obs .jsonl, or obs .jsonl ->
    legacy .json."""
    kind = kind or os.path.splitext(os.path.basename(src))[0]
    if src.endswith(".jsonl"):
        with open(src) as f:
            lines = [json.loads(ln) for ln in f if ln.strip()]
        if lines and all(isinstance(r, dict) and "schema" in r
                         for r in lines):
            return write_legacy_json(read_metrics(src), dst)
        return write_jsonl(records_from_legacy(lines, kind), dst)
    with open(src) as f:
        obj = json.load(f)
    return write_jsonl(records_from_legacy(obj, kind), dst)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="convert legacy results files <-> obs bench streams")
    ap.add_argument("src")
    ap.add_argument("dst")
    ap.add_argument("--kind", default=None,
                    help="bench kind tag (default: src basename)")
    a = ap.parse_args()
    print(convert_file(a.src, a.dst, kind=a.kind))
