"""Render metrics streams: summarize one run, or diff two.

The input is the schema-versioned JSONL stream ``MetricsWriter`` emits
(``read_metrics`` validates it).  ``summarize`` turns one stream into a
human-readable report: the run header, a sampled loss trajectory, the
algorithm-health diagnostics (max/last invariant residuals, drift, the
ζ² proxy), measured communication volume, the wall-clock phase table,
and the fault/rollback/membership timeline.  ``diff`` lines two runs up
metric-by-metric — the chaos pipeline uses it to show a faulted run
against its clean twin.

``scripts/report.py`` is the CLI; everything here is pure formatting
over parsed records so tests can call it in-process.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import read_metrics, run_meta

# events rendered in the timeline section, in stream order
_TIMELINE_EVENTS = ("membership", "rollback", "fault", "checkpoint",
                    "restore", "tail")
_MAX_TIMELINE = 40
_MAX_TRAJECTORY = 12


def load(path: str) -> List[Dict[str, Any]]:
    """Alias for :func:`repro.obs.metrics.read_metrics`."""
    return read_metrics(path)


def _by_event(records: Sequence[Dict[str, Any]], event: str) -> List[dict]:
    return [r for r in records if r.get("event") == event]


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _sample(rows: List[Any], cap: int = _MAX_TRAJECTORY) -> List[Any]:
    """First, last, and an even stride in between — a glanceable curve."""
    if len(rows) <= cap:
        return rows
    stride = (len(rows) - 1) / (cap - 1)
    idx = sorted({round(i * stride) for i in range(cap)})
    return [rows[i] for i in idx]


def _table(headers: Sequence[str], rows: List[Sequence[Any]]) -> List[str]:
    cells = [[_fmt(c) for c in row] for row in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for row in cells:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
    return lines


def _diag_extrema(records: Sequence[dict]) -> Dict[str, Tuple[float, float]]:
    """{key: (max, last)} over the numeric diag fields present."""
    out: Dict[str, Tuple[float, float]] = {}
    for rec in _by_event(records, "diag"):
        for k, v in rec.items():
            if k in ("schema", "event", "wall_s", "t", "r", "alarms",
                     "rolled_back") or not isinstance(v, (int, float)) \
                    or isinstance(v, bool):
                continue
            prev = out.get(k)
            out[k] = (v if prev is None else max(prev[0], v), v)
    return out


def _comm_totals(records: Sequence[dict]) -> Dict[str, Any]:
    """Total measured sync traffic: Σ wire_bytes * participants."""
    syncs = _by_event(records, "sync")
    total = 0
    known = True
    for s in syncs:
        w = s.get("wire_bytes")
        if w is None:
            known = False
            continue
        n = s.get("participants") or 1
        total += int(w) * int(n)
        w2 = s.get("wire_bytes2")
        if w2 is not None:
            total += int(w2)
    return {"syncs": len(syncs), "bytes": total if syncs and known else None}


def summarize(records: Sequence[Dict[str, Any]],
              label: Optional[str] = None) -> str:
    """One run -> a multi-section plain-text report."""
    meta = run_meta(records)
    lines: List[str] = []
    title = f"run report{f' — {label}' if label else ''}"
    lines += [title, "=" * len(title)]
    if meta:
        head = [f"{k}={_fmt(meta.get(k))}"
                for k in ("arch", "algorithm", "workers", "clients",
                          "steps", "k", "backend", "resolved_backend",
                          "compress", "faults", "guard", "membership")
                if meta.get(k) not in (None, False)]
        lines.append("  ".join(head))
        wire = meta.get("wire") or {}
        if wire.get("wire_bytes"):
            note = (f"  sync wire {wire['wire_bytes'] / 2**20:.2f} MiB"
                    f"/participant (raw {wire['raw_bytes'] / 2**20:.2f}"
                    f" MiB)")
            if wire.get("wire_bytes2"):
                note += f", sync2 {wire['wire_bytes2'] / 2**20:.2f} MiB"
            lines.append(note)

    rounds = _by_event(records, "round")
    evals = _by_event(records, "eval") + _by_event(records, "tail")
    if rounds or evals:
        lines += ["", "loss trajectory"]
        by_t = {e.get("t"): e for e in evals}
        rows = [(rec.get("t"), rec.get("r"), rec.get("loss"),
                 (by_t.get(rec.get("t")) or {}).get("avg_model_loss"))
                for rec in rounds]
        if not rows:                       # per-step runs have only evals
            rows = [(e.get("t"), None, e.get("local_loss"),
                     e.get("avg_model_loss")) for e in evals]
        lines += ["  " + ln for ln in _table(
            ("step", "round", "local_loss", "avg_model_loss"),
            _sample(rows))]

    diag = _diag_extrema(records)
    if diag:
        lines += ["", "algorithm health (diag records: "
                  f"{len(_by_event(records, 'diag'))})"]
        rows = [(k, mx, last) for k, (mx, last) in sorted(diag.items())
                if k != "drift_per_worker"]
        lines += ["  " + ln for ln in _table(("metric", "max", "last"),
                                             rows)]
        alarms = [(r.get("t"), a) for r in _by_event(records, "diag")
                  for a in (r.get("alarms") or [])]
        for t, a in alarms[:10]:
            lines.append(f"  ALARM @step {t}: {a}")
        if len(alarms) > 10:
            lines.append(f"  ... {len(alarms) - 10} more alarms")

    comm = _comm_totals(records)
    if comm["syncs"]:
        vol = ("unknown" if comm["bytes"] is None
               else f"{comm['bytes'] / 2**20:.1f} MiB")
        lines += ["", f"communication: {comm['syncs']} syncs, total "
                  f"measured wire volume {vol}"]

    ends = _by_event(records, "run_end")
    phases = (ends[-1].get("phases") or {}) if ends else {}
    if phases:
        lines += ["", "wall-clock phases"]
        rows = [(name, p.get("n"), p.get("total_s"), p.get("p50_ms"),
                 p.get("p95_ms")) for name, p in phases.items()]
        lines += ["  " + ln for ln in _table(
            ("phase", "n", "total_s", "p50_ms", "p95_ms"), rows)]

    timeline = [r for r in records if r.get("event") in _TIMELINE_EVENTS]
    if timeline:
        lines += ["", "event timeline"]
        for rec in timeline[:_MAX_TIMELINE]:
            body = "  ".join(f"{k}={_fmt(v)}" for k, v in rec.items()
                             if k not in ("schema", "event", "wall_s"))
            lines.append(f"  [{rec.get('wall_s', 0):8.2f}s] "
                         f"{rec['event']:<10s} {body}")
        if len(timeline) > _MAX_TIMELINE:
            lines.append(f"  ... {len(timeline) - _MAX_TIMELINE} more")

    if ends:
        e = ends[-1]
        lines += ["", f"final: steps={_fmt(e.get('steps'))}  "
                  f"avg_model_loss={_fmt(e.get('avg_model_loss'))}  "
                  f"rounds={_fmt(e.get('rounds'))}  "
                  f"wall={_fmt(e.get('wall_s'))}s"]
    else:
        lines += ["", "final: (no run_end record — stream is a partial "
                  "prefix from a crashed or killed run)"]
    return "\n".join(lines)


def _run_metrics(records: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """The comparable scalars of one run, for ``diff``."""
    ends = _by_event(records, "run_end")
    end = ends[-1] if ends else {}
    diag = _diag_extrema(records)
    comm = _comm_totals(records)
    out: Dict[str, Any] = {
        "steps": end.get("steps"),
        "rounds": end.get("rounds", len(_by_event(records, "round"))),
        "avg_model_loss": end.get("avg_model_loss"),
        "wall_s": end.get("wall_s"),
        "syncs": comm["syncs"],
        "wire_MiB_total": (None if comm["bytes"] is None
                           else round(comm["bytes"] / 2**20, 2)),
        "rollbacks": len(_by_event(records, "rollback")),
        "membership_changes": len(_by_event(records, "membership")),
        "checkpoints": len(_by_event(records, "checkpoint")),
    }
    for k in ("delta_residual", "bias_residual", "delta1_residual",
              "delta2_residual", "zeta_sq_proxy", "drift_sq_mean",
              "nonfinite_workers"):
        if k in diag:
            out[f"max_{k}"] = diag[k][0]
    phases = end.get("phases") or {}
    for name, p in phases.items():
        out[f"phase_{name}_s"] = p.get("total_s")
    return out


def diff(a: Sequence[Dict[str, Any]], b: Sequence[Dict[str, Any]],
         labels: Tuple[str, str] = ("A", "B")) -> str:
    """Two runs -> a metric | A | B | delta table."""
    ma, mb = _run_metrics(a), _run_metrics(b)
    keys = list(dict.fromkeys(list(ma) + list(mb)))
    rows = []
    for k in keys:
        va, vb = ma.get(k), mb.get(k)
        delta = (vb - va if isinstance(va, (int, float))
                 and isinstance(vb, (int, float))
                 and not isinstance(va, bool) else None)
        rows.append((k, va, vb, delta))
    title = f"run diff: {labels[0]} vs {labels[1]}"
    lines = [title, "=" * len(title)]
    lines += _table(("metric", labels[0], labels[1], "delta"), rows)
    return "\n".join(lines)
