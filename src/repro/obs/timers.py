"""Wall-clock phase timers with p50/p95 accumulation.

The driver cannot split a compiled round into local-steps/sync/fold —
those live inside ONE jitted dispatch — so the phases it times are the
host-visible boundaries: data staging, the round dispatch+block, eval,
diagnostics, gather/scatter, membership updates, checkpointing.  The
summary reports per-phase sample count, total seconds and nearest-rank
p50/p95 milliseconds.

Self-contained on purpose: ``src/repro`` must not import ``benchmarks``
(the percentile helper there is the same nearest-rank convention).
"""
from __future__ import annotations

import math
import time
from contextlib import contextmanager
from typing import Dict, List


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    s = sorted(samples)
    rank = max(1, math.ceil(q / 100.0 * len(s)))
    return s[rank - 1]


class PhaseTimers:
    """Accumulate named wall-clock phase samples."""

    def __init__(self):
        self._samples: Dict[str, List[float]] = {}

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def add(self, name: str, seconds: float) -> None:
        self._samples.setdefault(name, []).append(float(seconds))

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-phase {n, total_s, mean_ms, p50_ms, p95_ms}, insertion
        order (which is first-seen order — roughly pipeline order)."""
        out: Dict[str, Dict[str, float]] = {}
        for name, s in self._samples.items():
            out[name] = {
                "n": len(s),
                "total_s": round(sum(s), 6),
                "mean_ms": round(1e3 * sum(s) / len(s), 3),
                "p50_ms": round(1e3 * percentile(s, 50), 3),
                "p95_ms": round(1e3 * percentile(s, 95), 3),
            }
        return out
