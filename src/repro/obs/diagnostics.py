"""Host-side helpers around ``Engine.diagnostics``.

``Engine.diagnostics`` (built in ``core/engine.py``) is ONE small jitted
read-only pass over the flat state returning a dict of device scalars
(plus a per-worker drift vector on flat engines).  The helpers here turn
that into JSON-safe records, one-line console summaries, and alarm
decisions:

  ``to_record``           device dict -> plain-float dict for the metrics
                          stream
  ``check_alarms``        the invariant monitor: Σ Δ / Σ B residuals over
                          a configurable threshold, plus any non-finite
                          worker row -> list of human-readable reasons
                          (the driver feeds these into the ``--guard``
                          rollback path)
  ``describe``            one-line console summary of a record
  ``wire_bytes_per_sync`` measured sync payload bytes from the engine's
                          flat spec + resolved compressors — by
                          construction identical to
                          ``comm.rep_nbytes(compress(...))``

What the paper grounds each field in:

  zeta_sq_proxy    (1/n) Σᵢ ‖Δᵢ − Δ̄‖² — the across-worker dispersion of
                   the VRL control variates.  In the paper's analysis Δᵢ
                   tracks ∇Fᵢ(x) − ∇F(x), so this dispersion is the
                   runtime proxy for ζ², the inter-worker gradient
                   variance whose dependency VRL-SGD eliminates.  (The
                   naive between-round drift dispersion is ~0 for
                   broadcast syncs — post-sync params are identical — so
                   it would measure nothing.)
  drift_*          ‖xᵢ − x̂‖ against the active-worker mean: bounded
                   drift is the analysis' other pillar, and is the
                   meaningful dispersion under overlap / membership /
                   EASGD where params do NOT re-coincide each round.
  delta_residual   ‖(1/n) Σᵢ Δᵢ‖∞ — the paper's Σᵢ Δᵢ = 0 invariant
                   (bias_residual is the BVR Σᵢ Bᵢ = 0 twin).  Nonzero
                   means the control variates have leaked a systematic
                   bias into every sync.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax

from repro import comm as comm_mod

# alarm-relevant invariant residuals (flat + hierarchical spellings)
_RESIDUAL_KEYS = (
    ("delta_residual", "sum-delta"),
    ("bias_residual", "sum-bias"),
    ("delta1_residual", "pod sum-delta1"),
    ("delta2_residual", "cross-pod sum-delta2"),
)


def to_record(diag: Dict[str, Any]) -> Dict[str, Any]:
    """Fetch a device diagnostics dict into plain JSON-safe floats."""
    host = jax.device_get(diag)
    out: Dict[str, Any] = {}
    for k, v in host.items():
        if getattr(v, "ndim", 0) == 0:
            out[k] = float(v)
        else:
            out[k] = [float(x) for x in v.reshape(-1)]
    return out


def check_alarms(rec: Dict[str, Any], *,
                 invariant_threshold: float = 0.0) -> List[str]:
    """The invariant monitor: reasons this record should trip the guard.

    A non-finite worker row always alarms (it is unconditionally wrong);
    the Σ Δ / Σ B residual checks only run with a positive threshold —
    the residual is never exactly 0.0 in finite arithmetic, so the
    driver owns the tolerance (``--invariant-alarm``).  NaN residuals do
    NOT re-alarm here: the non-finite count already covers that state.

    Threshold guidance: uncompressed syncs hold the residual at float
    noise (~1e-6 of the Δ scale).  A LOSSY sync compressor keeps it
    genuinely nonzero — x̂' is rebuilt from decoded payloads, not the
    true mean, so Σ Δ picks up an error-feedback-bounded bias — pick a
    threshold above that floor (watch ``ef_resid_rms``) or leave the
    alarm off under compression.
    """
    reasons: List[str] = []
    nf = rec.get("nonfinite_workers")
    if nf is not None and nf > 0:
        reasons.append(f"{int(nf)} worker row(s) hold non-finite params")
    if invariant_threshold > 0.0:
        for key, label in _RESIDUAL_KEYS:
            v = rec.get(key)
            if v is not None and math.isfinite(v) \
                    and v > invariant_threshold:
                reasons.append(f"{label} residual {v:.3g} exceeds "
                               f"{invariant_threshold:g}")
    return reasons


def describe(rec: Dict[str, Any]) -> str:
    """One console line: the headline health figures of a record."""
    parts = []
    if "drift_sq_mean" in rec:
        parts.append(f"drift2 {rec['drift_sq_mean']:.3e}")
    if "zeta_sq_proxy" in rec:
        parts.append(f"zeta2~ {rec['zeta_sq_proxy']:.3e}")
    for key, _ in _RESIDUAL_KEYS:
        if key in rec:
            parts.append(f"{key.replace('_residual', '')}-res "
                         f"{rec[key]:.2e}")
    if "ef_resid_rms" in rec:
        parts.append(f"ef-rms {rec['ef_resid_rms']:.2e}")
    nf = rec.get("nonfinite_workers")
    if nf:
        parts.append(f"NONFINITE x{int(nf)}")
    return "  ".join(parts) if parts else "(empty)"


def wire_bytes_per_sync(engine) -> Optional[Dict[str, Any]]:
    """Measured per-participant sync payload for an engine, from the
    flat spec and the resolved compressor pair.

    ``comm.wire_bytes`` is documented (and CI-asserted in the comm
    benchmarks) to equal ``rep_nbytes(compress(...))`` exactly, padding
    elision included, so this is the measured figure without running a
    compressor.  ``wire_bytes2`` is the level-2 (cross-pod) payload on
    hierarchical engines, None otherwise.
    """
    if engine is None:
        return None
    es = engine.spec
    item = int(jax.numpy.dtype(es.dtype).itemsize)
    raw = comm_mod.raw_bytes(es.rows, es.lanes, item)
    wires = [comm_mod.wire_bytes(c, rows=es.rows, lanes=es.lanes,
                                 size=es.size, itemsize=item)
             for c in engine.compressors]
    hier = getattr(engine, "grid", None) is not None
    return {
        "raw_bytes": int(raw),
        "wire_bytes": int(wires[0]),
        "wire_bytes2": int(wires[1]) if hier and len(wires) > 1 else None,
    }
