"""Structured telemetry for the training driver and benchmarks.

The paper's claims ride on quantities the driver historically never
measured at runtime: VRL-SGD removes the ζ² (inter-worker gradient
variance) dependency, and its correctness rests on invariants —
Σᵢ Δᵢ = 0, bounded drift ‖x_i − x̂‖ — that were previously only visible
by adding prints.  This package makes them first-class:

  ``metrics``      schema-versioned JSONL event stream (``MetricsWriter``)
  ``diagnostics``  host-side helpers around ``Engine.diagnostics`` — the
                   one small jitted read-only pass over the flat state
                   (drift dispersion, Δ-dispersion ζ² proxy, Σ Δ / Σ B
                   residuals, EF/moment norms, non-finite worker count)
  ``timers``       wall-clock phase timers with p50/p95 accumulation
  ``report``       summarize / diff metrics streams (``scripts/report.py``)
  ``convert``      legacy ``results/*.json`` ↔ obs JSONL converters

Everything here is host-side except what ``core/engine.py`` builds; the
diagnostics pass is its OWN jit, never part of the compiled round, so the
round's one-sync-all-reduce HLO contract is untouched.
"""
from repro.obs.metrics import (SCHEMA_VERSION, MetricsWriter, NullWriter,
                               read_metrics, run_meta)

__all__ = [
    "SCHEMA_VERSION",
    "MetricsWriter",
    "NullWriter",
    "read_metrics",
    "run_meta",
]
