# Sync-payload compression subsystem: pluggable quantization / top-k
# sparsification with error feedback, applied to the engine's per-round
# communication payload and measured end-to-end in bytes.
from repro.comm.compressors import (  # noqa: F401
    COMPRESSORS,
    CompressorSpec,
    compress,
    decompress,
    describe_pair,
    ef_int8,
    ef_leaf,
    ef_roundtrip,
    ef_topk,
    is_identity,
    meta,
    pair_meta,
    parse_compressor,
    raw_bytes,
    rep_nbytes,
    resolve,
    resolve_pair,
    topk_k,
    used_rows,
    wire_bytes,
)
