"""Sync-payload compression — the bytes-per-round axis of communication
complexity.

The paper (and PRs 1-4) drive down the ROUNDS term of communication cost:
one flat all-reduce per sync, stagewise cadences, a hierarchical k2 period
for the slow cross-pod tier.  This module owns the orthogonal axis — how
many BYTES each of those rounds has to move.  Spiridonoff & Olshevsky
(2020) show the round count can be pushed to depend only on N, at which
point the per-round payload is the binding cost; a compressor composes
multiplicatively with every schedule and algorithm in the engine.

Compressors
-----------

``CompressorSpec`` names one of three wire formats over the engine's flat
(R, C) payload rows (layout: ``core/flat``):

  * ``none`` — identity.  Resolved to "no compressor at all": the engine
    takes its original code path, bitwise, with no extra state buffers.
  * ``int8`` — per-row-scaled linear quantization: each row of C lanes is
    scaled by max|row|/127 and rounded to int8.  Wire: 1 byte/element plus
    one fp32 scale per row.
  * ``topk`` — per-row magnitude sparsification with a FIXED k = C //
    ``rate`` survivors per row (fixed k ⇒ the wire layout is static and
    jittable: (rows, k) values + (rows, k) int32 indices, no variable-
    length segments).  ``rate=1`` keeps every lane and resolves to the
    identity path like ``none``.

What gets compressed: the DRIFT of each worker's payload against a shared
reference, not the payload itself.  Every sync already ends by installing a
value every participant knows (the broadcast mean x̂, or for EASGD the
shared mean it computed), so the engine carries that value as a ``ref``
buffer and each worker transmits ``compress(x_i − ref [+ residual])``.
Because ref is identical across the averaging group, the mean reconstructs
exactly: ``mean_i(x_i) = ref + mean_i(x_i − ref)``.  Drift compression is
what makes top-k sane (zeroing 1−1/rate of raw *parameters* would destroy
the model; zeroing small *drifts* just defers them) and shrinks int8's
quantization range.  S-SGD has no sync — its per-step gradient all-reduce
is the payload instead, compressed with ref ≡ 0 (classic QSGD/EF-SGD).

Error feedback: the compression error ``payload − decompress(compress(
payload))`` is carried per worker in a ``resid`` buffer and added to the
next round's payload before compressing (EF-SGD, Stich et al. 2018 — the
same carried-correction pattern as BVR-L-SGD's bias buffer).  The residual
is computed by literal subtraction, so the invariant

    residual' + decompressed == payload        (bitwise, in fp32)

holds by construction; it is property-tested in ``tests/test_compressors``.

Byte accounting
---------------

``wire_bytes`` is the measured one-way payload for one (R, C) buffer.  The
RAW baseline is what the engine's all-reduce actually carries today — the
full padded flat buffer (R·C·itemsize; the 2.00 GB/round figure on the
16×16 mesh comes from exactly this buffer in the compiled HLO).  The
compressed wire skips the tile-padding rows (padding is a Pallas-tiling
artifact; a byte-stream transport has no reason to send rows that are
identically zero by construction), transmitting ``used_rows =
ceil(size/lanes)`` rows.  ``compress``/``decompress`` build the actual
wire representation arrays so benchmarks measure real ``nbytes``, not a
formula.

Layering: this module is pure jnp + numpy — the canonical math.  The
engine's executors reuse it: ``kernels/xla_update`` wraps ``ef_int8`` /
``ef_topk`` directly, ``kernels/vrl_update`` re-states the same formulas
as Pallas kernel bodies (single HBM pass, residual donated), and the
per-leaf reference executor goes through ``ef_leaf``.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


COMPRESSORS = ("none", "int8", "topk")
_DEFAULT_TOPK_RATE = 32


class CompressorSpec(NamedTuple):
    """A named wire format for the sync payload.

    ``rate`` is the top-k keep divisor (k = lanes // rate survivors per
    row); it is 0 for the compressors that have no rate knob so specs
    compare/hash canonically.  ``error_feedback`` carries the compression
    error across rounds in a per-worker residual buffer.
    """

    name: str
    rate: int = 0
    error_feedback: bool = True

    def label(self) -> str:
        tag = f":{self.rate}" if self.name == "topk" else ""
        ef = "" if self.error_feedback or self.name == "none" else ":noef"
        return f"{self.name}{tag}{ef}"


def parse_compressor(text: str) -> CompressorSpec:
    """CLI syntax for ``--compress`` / ``--compress2``:

      "none"            identity (the uncompressed path, bitwise)
      "int8"            per-row-scaled int8 quantization
      "topk"            top-k sparsification, default rate 32 (C//32 kept)
      "topk:8"          explicit keep divisor (k = lanes // 8 per row)
      "int8:noef"       any compressor with error feedback disabled
    """
    parts = [p for p in text.split(":") if p]
    if not parts or parts[0] not in COMPRESSORS:
        raise ValueError(f"unknown compressor {text!r}; expected "
                         f"{'|'.join(COMPRESSORS)}[:rate][:noef]")
    name = parts[0]
    rate = _DEFAULT_TOPK_RATE if name == "topk" else 0
    ef = True
    for p in parts[1:]:
        if p == "noef":
            ef = False
        elif p == "ef":
            ef = True
        elif p.isdigit():
            if name != "topk":
                raise ValueError(f"{name!r} takes no rate (got {text!r})")
            rate = int(p)
        else:
            raise ValueError(f"bad compressor option {p!r} in {text!r}")
    return CompressorSpec(name=name, rate=rate, error_feedback=ef)


def as_spec(c) -> Optional[CompressorSpec]:
    if c is None or isinstance(c, CompressorSpec):
        return c
    if isinstance(c, str):
        return parse_compressor(c)
    raise TypeError(f"expected CompressorSpec | str | None, got {type(c)}")


def is_identity(c) -> bool:
    """True when the compressor changes nothing — the engine must then take
    its ORIGINAL code path (bitwise identical, no extra state buffers)."""
    c = as_spec(c)
    if c is None or c.name == "none":
        return True
    return c.name == "topk" and c.rate <= 1


def resolve(c) -> Optional[CompressorSpec]:
    """Spec for an active compressor, None for the identity path."""
    c = as_spec(c)
    return None if is_identity(c) else c


def resolve_pair(cfg) -> Tuple[Optional[CompressorSpec],
                               Optional[CompressorSpec]]:
    """(level-1, level-2) compressors for a VRLConfig.

    ``compress`` drives the (only) sync of the flat algorithms and the
    intra-pod level-1 sync of the hierarchical one; ``compress2`` overrides
    the cross-pod level-2 sync (so the slow DCI tier can compress harder)
    and falls back to ``compress`` when unset.
    """
    c1 = resolve(getattr(cfg, "compress", None))
    c2_raw = getattr(cfg, "compress2", None)
    c2 = resolve(c2_raw) if c2_raw is not None else c1
    return c1, c2


def topk_k(spec: CompressorSpec, lanes: int) -> int:
    """Survivors per row — fixed at trace time (the jittable layout)."""
    return max(1, lanes // max(spec.rate, 1))


def used_rows(size: int, lanes: int) -> int:
    """Rows carrying real elements — the wire skips pure tile padding."""
    return -(-size // lanes)


# ============================================================== EF round-trip
# The canonical compress→decompress math over (..., R, C) payload buffers,
# in fp32.  Returns (decompressed, residual); residual is the literal
# subtraction, so resid + dec == payload bitwise.

def ef_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row-scaled int8 round-trip: scale = max|row|/127, symmetric
    round-to-nearest.  All-zero rows quantize to zero exactly."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(amax > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
    dec = q * scale
    return dec, x - dec


def ef_topk(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the k largest-magnitude lanes per row, zero the rest.

    Selection is by threshold (the kth magnitude per row) so the Pallas
    kernel, the jnp twin, and this function agree bitwise.  Tie semantics:
    on EXACT magnitude ties at the threshold, threshold-keep retains every
    tied lane (>= k survivors), while the fixed-k wire format
    (``compress``) carries exactly k of them — so the wire reconstruction
    can differ from this round-trip at tied lanes (e.g. +x and −x tied at
    the kth magnitude).  Exact fp32 ties have measure zero for real
    payloads; the engine uses THIS round-trip, and the wire bytes it
    reports are exact-k (a lower bound on tied rows).
    """
    c = x.shape[-1]
    if k >= c:
        return x, jnp.zeros_like(x)
    a = jnp.abs(x)
    thresh = jax.lax.top_k(a, k)[0][..., k - 1:k]
    dec = jnp.where(a >= thresh, x, jnp.zeros_like(x))
    return dec, x - dec


def ef_roundtrip(spec: CompressorSpec, x: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Dispatch ``ef_int8`` / ``ef_topk`` by spec over an (..., R, C)
    payload (fp32 in, fp32 out)."""
    if spec.name == "int8":
        return ef_int8(x)
    if spec.name == "topk":
        return ef_topk(x, topk_k(spec, x.shape[-1]))
    return x, jnp.zeros_like(x)          # "none": identity


def ef_leaf(spec: CompressorSpec, payload: jax.Array, n_lead: int,
            lanes: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Per-leaf EF round-trip for the reference tree executor.

    ``payload``: fp32 with ``n_lead`` leading worker axes; the trailing
    leaf dims are raveled into rows of ``lanes`` (zero-padded tail).  Row
    grouping is leaf-aligned here but layout-aligned on the flat-buffer
    executors, so compressed reference-vs-fused trajectories agree only
    approximately — both are compared against the UNCOMPRESSED oracle.
    """
    lead = payload.shape[:n_lead]
    n = int(np.prod(payload.shape[n_lead:])) if payload.ndim > n_lead else 1
    u = used_rows(n, lanes)
    flat = payload.reshape(lead + (n,))
    pad = u * lanes - n
    if pad:
        flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, pad)])
    dec2, res2 = ef_roundtrip(spec, flat.reshape(lead + (u, lanes)))

    def back(b):
        return b.reshape(lead + (u * lanes,))[..., :n].reshape(payload.shape)

    return back(dec2), back(res2)


# ================================================================ wire format
class Int8Rep(NamedTuple):
    values: jax.Array            # (..., U, C) int8
    scales: jax.Array            # (..., U, 1) fp32


class TopKRep(NamedTuple):
    values: jax.Array            # (..., U, K) fp32
    indices: jax.Array           # (..., U, K) int32 lane offsets


class RawRep(NamedTuple):
    values: jax.Array            # (..., U, C) payload dtype


def compress(spec: CompressorSpec, x: jax.Array, *,
             rows_used: Optional[int] = None):
    """Payload (..., R, C) → the actual wire representation arrays.

    ``rows_used`` drops the trailing tile-padding rows (identically zero by
    the flat layout's construction) from the wire.
    """
    if rows_used is not None:
        x = x[..., :rows_used, :]
    x = x.astype(jnp.float32)
    if spec.name == "int8":
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = amax / 127.0
        safe = jnp.where(amax > 0, scale, 1.0)
        q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
        return Int8Rep(values=q.astype(jnp.int8), scales=scale)
    if spec.name == "topk":
        k = topk_k(spec, x.shape[-1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        vals = jnp.take_along_axis(x, idx, axis=-1)
        return TopKRep(values=vals, indices=idx.astype(jnp.int32))
    return RawRep(values=x)


def decompress(spec: CompressorSpec, rep, *, rows: int,
               lanes: int) -> jax.Array:
    """Wire representation → the dense (..., R, C) fp32 payload (dropped
    tile-padding rows reconstructed as zeros)."""
    if spec.name == "int8":
        dec = rep.values.astype(jnp.float32) * rep.scales
    elif spec.name == "topk":
        v, idx = rep.values, rep.indices
        lead = v.shape[:-2]
        u, k = v.shape[-2:]
        v2 = v.reshape((-1, u, k))
        i2 = idx.reshape((-1, u, k))
        b = v2.shape[0]
        bi = jnp.arange(b)[:, None, None]
        ui = jnp.arange(u)[None, :, None]
        dec = jnp.zeros((b, u, lanes), jnp.float32).at[bi, ui, i2].set(v2)
        dec = dec.reshape(lead + (u, lanes))
    else:
        dec = rep.values.astype(jnp.float32)
    u = dec.shape[-2]
    if rows > u:
        pad = [(0, 0)] * (dec.ndim - 2) + [(0, rows - u), (0, 0)]
        dec = jnp.pad(dec, pad)
    return dec


def rep_nbytes(rep) -> int:
    """Measured wire bytes of an actual compressed representation."""
    return int(sum(a.size * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(rep)))


def raw_bytes(rows: int, lanes: int, itemsize: int = 4) -> int:
    """The uncompressed baseline: the full padded flat buffer the sync
    all-reduce carries today."""
    return rows * lanes * itemsize


def wire_bytes(spec: Optional[CompressorSpec], *, rows: int, lanes: int,
               size: Optional[int] = None, itemsize: int = 4) -> int:
    """One-way wire bytes for one (R, C) payload under ``spec``.

    ``size`` (real element count) enables the padding-row elision; the
    identity path transmits the raw buffer unchanged.  Matches
    ``rep_nbytes(compress(...))`` exactly.
    """
    if spec is None or is_identity(spec):
        return raw_bytes(rows, lanes, itemsize)
    u = used_rows(size, lanes) if size is not None else rows
    if spec.name == "int8":
        return u * lanes * 1 + u * 4
    if spec.name == "topk":
        k = topk_k(spec, lanes)
        return u * k * (4 + 4)
    raise ValueError(spec.name)


# ============================================================ metadata / ckpt
def meta(c) -> Optional[dict]:
    """JSON-safe description of one compressor (checkpoint validation)."""
    c = resolve(c)
    if c is None:
        return None
    return {"name": c.name, "rate": int(c.rate),
            "error_feedback": bool(c.error_feedback)}


def pair_meta(cfg_or_pair) -> Optional[dict]:
    """Per-level compressor metadata for a VRLConfig (or an explicit
    (level1, level2) pair); None when fully uncompressed."""
    if isinstance(cfg_or_pair, tuple):
        c1, c2 = cfg_or_pair
    else:
        c1, c2 = resolve_pair(cfg_or_pair)
    if c1 is None and c2 is None:
        return None
    return {"level1": meta(c1), "level2": meta(c2)}


def describe_pair(cfg_or_pair) -> str:
    """Human-readable per-level summary for launch banners."""
    if isinstance(cfg_or_pair, tuple):
        c1, c2 = cfg_or_pair
    else:
        c1, c2 = resolve_pair(cfg_or_pair)
    if c1 is None and c2 is None:
        return "none"
    l1 = c1.label() if c1 else "none"
    if c2 == c1 or c2 is None:
        return l1
    return f"{l1} / sync2={c2.label()}"
