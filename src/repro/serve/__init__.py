from repro.serve.engine import Engine, make_prefill, make_serve_step  # noqa: F401
