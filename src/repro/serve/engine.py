"""Serving engine: cache-building prefill + batched single-token decode.

``serve_step`` is the function the decode dry-run shapes lower: ONE new token
against a ``seq_len``-sized cache. The engine wraps it with greedy/temperature
sampling for the runnable examples.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None,
                    unroll: int = 1):
    """(params, cache, tokens (B,1), pos) -> (logits (B,1,V), cache)."""
    def serve_step(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos,
                                       window=window, unroll=unroll)
    return serve_step


def make_prefill(cfg: ModelConfig, cache_len: int,
                 window: Optional[int] = None, unroll: int = 1,
                 last_only: bool = False):
    def prefill_fn(params, tokens):
        return transformer.prefill(cfg, params, tokens, cache_len,
                                   window=window, unroll=unroll,
                                   last_only=last_only)
    return prefill_fn


class Engine:
    """Minimal batched generation engine (greedy / temperature sampling)."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 window: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window = window
        self._prefill = jax.jit(make_prefill(cfg, max_len, window,
                                             last_only=True))
        self._step = jax.jit(make_serve_step(cfg, window))

    def generate(self, prompt: jax.Array, steps: int, *,
                 temperature: float = 0.0, key=None) -> jax.Array:
        """prompt (B, S) int32 -> (B, S+steps) greedy/sampled continuation."""
        bsz, s = prompt.shape
        logits, cache = self._prefill(self.params, prompt)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out = [prompt, tok]
        pos = s
        for i in range(steps - 1):
            logits, cache = self._step(self.params, cache, tok, pos)
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, 0] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
            pos += 1
        return jnp.concatenate(out, axis=1)
