"""STL-SGD [Shen et al. 2020]: Local SGD with a stagewise communication
period.

The update structure IS Local SGD's (k local steps, periodic model
averaging, no correction term) — what changes is the cadence: the
communication period grows stagewise (doubling per stage in the paper), so
the number of communication rounds over a horizon T is O(log T) stages x
rounds_per_stage instead of T/k.  Described by ``SPEC`` (no correction,
"average" sync, ``stagewise=True``) and executed by ``core/engine.py``;
the schedule itself is a ``core.schedule.CommSchedule``
(``VRLConfig.comm_schedule``; when unset, ``engine.comm_schedule`` defaults
this algorithm to the doubling ramp 1 → ``comm_period``).

With a constant schedule the trajectory is bitwise Local SGD — asserted in
``tests/test_engine_parity.py``.
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["stl_sgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return engine.ref_init(SPEC, cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return engine.ref_sync(SPEC, cfg, state)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_train_step(SPEC, cfg, state, grads)
