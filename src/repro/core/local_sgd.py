"""Local SGD baseline [Stich 2019]: k local steps, periodic model averaging.

Exactly VRL-SGD with Δ_i ≡ 0 (paper §4.1, line 5 of Alg. 1 removed).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import VRLConfig
from repro.core import vrl_sgd
from repro.core.types import WorkerState
from repro.optim.optimizers import make_inner


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    state = vrl_sgd.init(cfg, params, num_workers)
    return state


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    opt = make_inner(cfg)
    new_params, new_inner = opt.update(state.params, grads, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    xbar = vrl_sgd.worker_mean(state.params)
    new_params = jax.tree.map(
        lambda x, xb: jnp.broadcast_to(xb, x.shape).astype(x.dtype),
        state.params, xbar)
    return state._replace(params=new_params, last_sync=state.step)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    state = local_step(cfg, state, grads)
    return jax.lax.cond(
        (state.step - state.last_sync) >= cfg.comm_period,
        lambda s: sync(cfg, s), lambda s: s, state)
