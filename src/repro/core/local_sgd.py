"""Local SGD baseline [Stich 2019]: k local steps, periodic model averaging.

Exactly VRL-SGD with Δ_i ≡ 0 (paper §4.1, line 5 of Alg. 1 removed).
Described by ``SPEC`` (no correction term, "average" sync rule) and executed
by ``core/engine.py`` — reference tree path here, fused flat-buffer path via
``engine.make_engine``.
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["local_sgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return engine.ref_init(SPEC, cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return engine.ref_sync(SPEC, cfg, state)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_train_step(SPEC, cfg, state, grads)
