# The paper's primary contribution: VRL-SGD and its baselines as composable
# distributed optimizers over worker-stacked pytrees.
from repro.core.api import Algorithm, get_algorithm, list_algorithms  # noqa: F401
from repro.core.types import WorkerState  # noqa: F401
