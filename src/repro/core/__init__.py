# The paper's primary contribution: VRL-SGD and its baselines as composable
# distributed optimizers.  Algorithms are thin AlgoSpec descriptions executed
# by core/engine.py (reference tree path or fused flat-buffer Pallas path).
from repro.core.api import (  # noqa: F401
    Algorithm,
    get_algorithm,
    get_spec,
    list_algorithms,
)
from repro.core.engine import (  # noqa: F401
    AlgoSpec,
    Engine,
    FlatWorkerState,
    HierFlatState,
    RoundCache,
    comm_schedule,
    flat_algorithms,
    hier_config,
    make_engine,
    resolve_backend,
    state_partition_specs,
)
from repro.core.schedule import (  # noqa: F401
    CommSchedule,
    const_comm,
    custom_stages,
    parse_schedule,
    stagewise_doubling,
)
from repro.core.types import (  # noqa: F401
    CommState,
    HierCommState,
    HierState,
    MemberState,
    WorkerState,
)
