"""Flat-buffer layout for the algorithm engine.

The paper's per-step math (eqs. 4-6) is elementwise over *model-sized*
buffers, so its natural execution shape is not the parameter pytree but one
contiguous 2D buffer per worker: every leaf raveled, concatenated, padded to
a (rows, lanes) tile grid the Pallas kernels consume directly.  This module
owns that layout:

  * ``FlatSpec``     — the static unravel spec: leaf paths/shapes/dtypes with
                       their offsets into the flat vector, plus the chosen
                       (rows, lanes, block) tiling.  Hashable, and JSON
                       round-trippable for checkpoints.
  * ``make_spec``    — build a spec from a single-model template pytree
                       (concrete arrays or ShapeDtypeStructs).
  * flatten/unflatten — exact (pad/slice only, no arithmetic) conversions
                       between the pytree world and (R, C) / (W, R, C)
                       worker-stacked buffers.

Tiling policy (``choose_block``): lanes are fixed at a VPU-friendly multiple
of 128; the row count is padded up to a multiple of the largest block in
{1024, 512, ..., 8} whose padding waste stays under ``max_waste`` — big
models get 1024-row tiles (one grid step per ~1 MiB of fp32), tiny ones
degrade gracefully instead of padding 8 elements up to a megabyte.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Leaf paths use the checkpoint key style — share the formatter so the two
# can never diverge (save_flat_state metadata must match the array keys).
from repro.checkpoint.checkpoint import _path_str


_BLOCK_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)


class LeafSpec(NamedTuple):
    path: str          # "/"-joined key path (matches checkpoint key style)
    shape: Tuple[int, ...]
    dtype: str
    offset: int        # element offset into the flat vector
    size: int


class FlatSpec(NamedTuple):
    treedef: Any                    # jax treedef of the single-model pytree
    leaves: Tuple[LeafSpec, ...]
    size: int                       # total real elements (sum of leaf sizes)
    lanes: int                      # C — last dim of the 2D buffer
    rows: int                       # R — padded row count (multiple of block)
    block: int                      # Pallas grid tile height
    dtype: str                      # buffer dtype for the params buffer
    shards: int = 1                 # model-axis shard count (rows % (block *
                                    # shards) == 0, so each shard holds whole
                                    # Pallas tiles and every tile stays local)

    @property
    def padded(self) -> int:
        return self.rows * self.lanes

    def meta(self) -> dict:
        """JSON-safe description (checkpoint validation / inspection)."""
        return {
            "leaves": [{"path": l.path, "shape": list(l.shape),
                        "dtype": l.dtype, "offset": l.offset, "size": l.size}
                       for l in self.leaves],
            "size": self.size, "lanes": self.lanes, "rows": self.rows,
            "block": self.block, "dtype": self.dtype, "shards": self.shards,
        }


def choose_block(rows: int, *, target: int = 1024,
                 max_waste: float = 0.25) -> int:
    """Largest candidate block whose row padding wastes <= ``max_waste``.

    Falls through to the smallest candidate when everything wastes more
    (tiny buffers) — matching the old hardcoded floor of 8 rows.
    """
    rows = max(int(rows), 1)
    for b in _BLOCK_CANDIDATES:
        if b > target:
            continue
        padded = -(-rows // b) * b
        if (padded - rows) / padded <= max_waste:
            return b
    return _BLOCK_CANDIDATES[-1]


def make_spec(template: Any, *, lanes: int = 256, block: int = 0,
              max_waste: float = 0.25, shards: int = 1) -> FlatSpec:
    """Build the unravel spec from a SINGLE-MODEL pytree template.

    ``template`` leaves may be arrays or ShapeDtypeStructs; only shapes and
    dtypes are read.  ``block=0`` selects the tile height automatically.
    ``shards`` pads rows up to a multiple of ``block * shards`` so the row
    axis splits into equal shards on tile boundaries — sharding only adds
    zero pad rows (inert through every update), never changes unflattened
    values, and ``shards=1`` reproduces the unsharded layout exactly.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    off = 0
    for path, leaf in flat:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        leaves.append(LeafSpec(
            path="/".join(_path_str(p) for p in path),
            shape=tuple(int(s) for s in leaf.shape),
            dtype=str(jnp.dtype(leaf.dtype)), offset=off, size=size))
        off += size
    if not leaves:
        raise ValueError("empty template pytree")
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    dtype = str(jnp.result_type(*[np.dtype(l.dtype) for l in leaves]))
    rows_needed = -(-off // lanes)
    blk = int(block) if block else choose_block(rows_needed,
                                                max_waste=max_waste)
    quantum = blk * int(shards)
    rows = -(-rows_needed // quantum) * quantum
    return FlatSpec(treedef=treedef, leaves=tuple(leaves), size=off,
                    lanes=lanes, rows=rows, block=blk, dtype=dtype,
                    shards=int(shards))


def _check(spec: FlatSpec, tree: Any, stacked: bool):
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != len(spec.leaves):
        raise ValueError(f"tree has {len(leaves)} leaves, spec has "
                         f"{len(spec.leaves)}")
    lead = 1 if stacked else 0
    for got, want in zip(leaves, spec.leaves):
        if tuple(got.shape[lead:]) != want.shape:
            raise ValueError(f"leaf {want.path}: shape {got.shape} does not "
                             f"match spec {want.shape} (stacked={stacked})")
    return leaves


def flatten_tree(spec: FlatSpec, tree: Any,
                 dtype: Optional[Any] = None) -> jax.Array:
    """Single-model pytree -> (R, C) buffer.  Exact: pad-only."""
    leaves = _check(spec, tree, stacked=False)
    dt = jnp.dtype(dtype or spec.dtype)
    vec = jnp.concatenate([l.reshape(-1).astype(dt) for l in leaves])
    pad = spec.padded - spec.size
    if pad:
        vec = jnp.pad(vec, (0, pad))
    return vec.reshape(spec.rows, spec.lanes)


def flatten_stacked(spec: FlatSpec, tree: Any,
                    dtype: Optional[Any] = None) -> jax.Array:
    """Worker-stacked pytree (leading axis W on every leaf) -> (W, R, C)."""
    leaves = _check(spec, tree, stacked=True)
    w = leaves[0].shape[0]
    dt = jnp.dtype(dtype or spec.dtype)
    vec = jnp.concatenate([l.reshape(w, -1).astype(dt) for l in leaves],
                          axis=1)
    pad = spec.padded - spec.size
    if pad:
        vec = jnp.pad(vec, ((0, 0), (0, pad)))
    return vec.reshape(w, spec.rows, spec.lanes)


def unflatten_tree(spec: FlatSpec, buf: jax.Array,
                   cast: bool = True) -> Any:
    """(R, C) buffer -> single-model pytree (leaf dtypes restored)."""
    vec = buf.reshape(-1)
    leaves = []
    for l in spec.leaves:
        piece = vec[l.offset:l.offset + l.size].reshape(l.shape)
        leaves.append(piece.astype(l.dtype) if cast else piece)
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unflatten_stacked(spec: FlatSpec, buf: jax.Array,
                      cast: bool = True) -> Any:
    """(W, R, C) buffer -> worker-stacked pytree ((W, ...) leaves)."""
    w = buf.shape[0]
    vec = buf.reshape(w, -1)
    leaves = []
    for l in spec.leaves:
        piece = vec[:, l.offset:l.offset + l.size].reshape((w,) + l.shape)
        leaves.append(piece.astype(l.dtype) if cast else piece)
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ------------------------------------------------- pod-major (P, D, R, C)
# The hierarchical engine carries its worker population as a pod-major grid:
# axis 0 indexes pods (slow cross-pod links), axis 1 the workers inside a
# pod (fast intra-pod links).  The flat layout per worker is IDENTICAL to
# the (W, R, C) one — a grid buffer is just the stacked buffer with its
# worker axis split (P, D) — so these are exact reshapes around the stacked
# converters and the same FlatSpec round-trips both.

def flatten_grid(spec: FlatSpec, tree: Any,
                 dtype: Optional[Any] = None) -> jax.Array:
    """Grid-stacked pytree ((P, D, ...) leaves) -> (P, D, R, C)."""
    leaves = jax.tree_util.tree_leaves(tree)
    p, d = leaves[0].shape[:2]
    stacked = jax.tree.map(lambda x: x.reshape((p * d,) + x.shape[2:]), tree)
    buf = flatten_stacked(spec, stacked, dtype=dtype)
    return buf.reshape(p, d, spec.rows, spec.lanes)


def unflatten_grid(spec: FlatSpec, buf: jax.Array,
                   cast: bool = True) -> Any:
    """(P, D, R, C) buffer -> grid-stacked pytree ((P, D, ...) leaves)."""
    p, d, r, c = buf.shape
    tree = unflatten_stacked(spec, buf.reshape(p * d, r, c), cast=cast)
    return jax.tree.map(lambda x: x.reshape((p, d) + x.shape[1:]), tree)
