"""Bias-Variance Reduced Local SGD [Murata & Suzuki 2021], engine form.

BVR-L-SGD augments the variance-reduction correction with a *bias*
control variate evaluated at the communication point.  The engine sees
exactly one gradient per local step (the train loop computes it at the
current params), so the paper's same-sample anchor-gradient correction is
carried in its parameter-motion form, the same telescoping that gives
VRL-SGD its Δ:

  local:  v_i = g_i − Δ_i − B_i
  sync:   u_i = (x̂ − x_i)/(k_eff γ)      (realized drift this round)
          Δ_i ← Δ_i + u_i                (eq. 4, unchanged)
          B_i ← (1−β)·B_i + β·u_i        (bias-variate EMA, β = bvr_beta)
          x_i ← x̂

Δ accumulates the full drift history while B tracks its *recent* component
— subtracting both anticipates the persistent (heterogeneity-driven) bias
the lagged Δ has not yet absorbed.  Invariants: Σ_i B_i = 0 after every
sync (same argument as Δ); β = 0 disables the correction at trace time and
the trajectory is bitwise VRL-SGD (``tests/test_engine_parity.py``).

Described by ``SPEC`` (Δ + B corrections, "bvr" sync rule) and executed by
``core/engine.py`` — the sync is still a single flat all-reduce (x̂ only;
u, Δ, B are worker-local).
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["bvr_l_sgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return engine.ref_init(SPEC, cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return engine.ref_sync(SPEC, cfg, state)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_train_step(SPEC, cfg, state, grads)
