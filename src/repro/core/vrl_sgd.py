"""Variance Reduced Local SGD (VRL-SGD) — Algorithm 1 of the paper.

Per worker i (leading axis of every leaf):

  local, k times:   v_i = ∇f_i(x_i, ξ) − Δ_i
                    x_i ← inner_opt(x_i, v_i)            (paper: plain SGD)
  sync (every k):   x̂   = (1/N) Σ_i x_i                  (one all-reduce)
                    Δ_i ← Δ_i + (x̂ − x_i) / (k_eff · γ)  (eq. 4)
                    x_i ← x̂

``k_eff`` is the *actual* number of local steps since the last sync, so the
warm-up variant (Remark 5.3: first period k=1 ⇒ C = 0) and arbitrary
communication schedules stay exact.

Invariants (tested):
  * Σ_i Δ_i = 0 after every sync                     (paper §4.1)
  * k=1 ⇒ identical trajectory to S-SGD              (paper §4.1)
  * Δ_i ≡ 0 ⇒ identical trajectory to Local SGD      (paper §4.1)
  * x̂ follows eq. (8): exact generalized SGD on the averaged gradients.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import VRLConfig
from repro.core.types import WorkerState
from repro.optim.optimizers import make_inner


def _bcast(tree, w: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (w, *x.shape)).copy(), tree)


def worker_mean(tree):
    return jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=True), tree)


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    """params: single-model pytree -> worker-stacked state."""
    stacked = _bcast(params, num_workers)
    delta_dt = jnp.dtype(cfg.delta_dtype)
    delta = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=delta_dt), stacked)
    inner = make_inner(cfg).init(stacked)
    return WorkerState(params=stacked, delta=delta, inner=inner, center=None,
                       step=jnp.zeros((), jnp.int32),
                       last_sync=jnp.zeros((), jnp.int32))


def corrected_grads(state: WorkerState, grads: Any) -> Any:
    """v_i = g_i − Δ_i  (eq. 6)."""
    return jax.tree.map(lambda g, d: g - d.astype(g.dtype), grads, state.delta)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    """One local iteration on every worker (no cross-worker communication)."""
    v = corrected_grads(state, grads)
    opt = make_inner(cfg)
    new_params, new_inner = opt.update(state.params, v, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    """Model averaging + Δ update (the only cross-worker communication)."""
    k_eff = jnp.maximum(state.step - state.last_sync, 1).astype(jnp.float32)
    xbar = worker_mean(state.params)                     # the all-reduce

    def upd_delta(d, x, xb):
        return (d.astype(jnp.float32)
                + (xb.astype(jnp.float32) - x.astype(jnp.float32))
                / (k_eff * cfg.learning_rate)).astype(d.dtype)

    new_delta = jax.tree.map(upd_delta, state.delta, state.params, xbar)
    new_params = jax.tree.map(
        lambda x, xb: jnp.broadcast_to(xb, x.shape).astype(x.dtype),
        state.params, xbar)
    return state._replace(params=new_params, delta=new_delta,
                          last_sync=state.step)


def should_sync(cfg: VRLConfig, step: jax.Array, last_sync: jax.Array):
    """True when ``step`` (post-increment) completes a communication period."""
    k = jnp.where(cfg.warmup & (last_sync == 0) & (step <= 1),
                  1, cfg.comm_period)
    return (step - last_sync) >= k


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    """local step, then sync if the period ends (lax.cond keeps one jit)."""
    state = local_step(cfg, state, grads)
    return jax.lax.cond(
        should_sync(cfg, state.step, state.last_sync),
        lambda s: sync(cfg, s), lambda s: s, state)


def average_model(state: WorkerState) -> Any:
    """x̂ — the evaluation model (paper reports metrics on the average)."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)


def make_algorithm(cfg: VRLConfig):
    """Uniform (init, train_step, local_step, sync) tuple for this algorithm."""
    return init, train_step, local_step, sync
