"""Variance Reduced Local SGD (VRL-SGD) — Algorithm 1 of the paper.

Per worker i (leading axis of every leaf):

  local, k times:   v_i = ∇f_i(x_i, ξ) − Δ_i
                    x_i ← inner_opt(x_i, v_i)            (paper: plain SGD)
  sync (every k):   x̂   = (1/N) Σ_i x_i                  (one all-reduce)
                    Δ_i ← Δ_i + (x̂ − x_i) / (k_eff · γ)  (eq. 4)
                    x_i ← x̂

``k_eff`` is the *actual* number of local steps since the last sync, so the
warm-up variant (Remark 5.3: first period k=1 ⇒ C = 0) and arbitrary
communication schedules stay exact.

Invariants (tested):
  * Σ_i Δ_i = 0 after every sync                     (paper §4.1)
  * k=1 ⇒ identical trajectory to S-SGD              (paper §4.1)
  * Δ_i ≡ 0 ⇒ identical trajectory to Local SGD      (paper §4.1)
  * x̂ follows eq. (8): exact generalized SGD on the averaged gradients.

Engine architecture: this module is a thin *description* — ``SPEC`` names
the correction term (Δ in the local step) and the sync rule ("vrl") — and
delegates execution to ``core/engine.py``, which provides two backends: the
per-leaf reference path below, and the flat-buffer fused-Pallas path
(``engine.make_engine``) where the whole update is one HBM pass and the
sync's model average is a single all-reduce over the flattened parameters.
See the engine module docstring for the flat layout and backend knob.

Overlapped rounds (``VRLConfig.overlap``, engine-only): because Δ is a
*previous-round* quantity already, the sync tolerates one round of
staleness — the round-START all-reduce averages the positions transmitted
at the PREVIOUS boundary and the fold applies c_i = x̂_stale − x_i^(sent)
to x_i and Δ_i at the boundary (Δ_i scaled by the period that position
covered).  Σ_i c_i = 0, so Σ_i Δ_i = 0 and eq. (8) on the mean survive;
the collective runs concurrently with the next k local steps.  See the
engine docstring ("Overlapped rounds") for the exact state and deadline
semantics.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.engine import _bcast, average_model, worker_mean  # noqa: F401
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["vrl_sgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    """params: single-model pytree -> worker-stacked state."""
    return engine.ref_init(SPEC, cfg, params, num_workers)


def corrected_grads(state: WorkerState, grads: Any) -> Any:
    """v_i = g_i − Δ_i  (eq. 6)."""
    return engine.corrected_grads(state, grads)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    """One local iteration on every worker (no cross-worker communication)."""
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    """Model averaging + Δ update (the only cross-worker communication)."""
    return engine.ref_sync(SPEC, cfg, state)


def should_sync(cfg: VRLConfig, step: jax.Array, last_sync: jax.Array):
    """True when ``step`` (post-increment) completes a communication period."""
    return engine.should_sync(SPEC, cfg, step, last_sync)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    """local step, then sync if the period ends (lax.cond keeps one jit)."""
    return engine.ref_train_step(SPEC, cfg, state, grads)


def make_algorithm(cfg: VRLConfig):
    """Uniform (init, train_step, local_step, sync) tuple for this algorithm."""
    return init, train_step, local_step, sync
