"""Shared state containers for the distributed optimizers.

All algorithm state lives on a *worker-stacked* pytree convention: every
leaf has a leading axis of size W (number of VRL workers). On the production
mesh that axis is sharded over the worker mesh axes, so "mean over axis 0"
lowers to exactly one all-reduce over the slow links — the paper's
communication event. On CPU the same code simulates W workers on one device.

``WorkerState`` is the reference executor's tree-structured state; the
fused flat-buffer executor carries the same fields as contiguous (W, R, C)
buffers in ``core.engine.FlatWorkerState`` (layout: ``core.flat``).

The worker-stacked convention is also the client-sampling contract
(``core.clients``): a state leaf is *per-participant* exactly when it has
``ndim == 3`` with leading axis W — those leaves get (M, ...) host-side
twins in a ``ClientStore`` and are gathered/scattered per sampled cohort —
while everything else (step counters, the EASGD center, the shared
compressed-sync reference) is global.  ``MemberState`` is deliberately
outside that contract: the active mask describes physical worker SLOTS,
not logical clients, so it stays device-resident across cohorts.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax


class WorkerState(NamedTuple):
    """State carried by every algorithm in ``repro.core``."""

    params: Any              # (W, ...) worker-stacked model parameters
    delta: Any               # (W, ...) VRL correction Δ_i (zeros if unused)
    inner: Any               # inner-optimizer state (momentum buffers, ...)
    center: Any              # EASGD center variable x̃ (None elsewhere)
    step: jax.Array          # scalar int32: iterations completed
    last_sync: jax.Array     # scalar int32: step index of the last sync
    bias: Any = None         # (W, ...) BVR-L-SGD bias variate B_i (else None)
    comm: Any = ()           # compressed-sync state (CommState) — () when
                             # the sync payload is uncompressed


class CommState(NamedTuple):
    """Compressed-sync state (repro.comm) for the single-level executors.

    ``resid``: per-worker error-feedback residual (worker-stacked like the
    params, fp32), () when error feedback is off.  ``ref``: the shared
    drift reference — the value every worker holds after the last sync —
    against which the next payload is compressed; () for S-SGD's gradient
    compression (ref ≡ 0).  Reference executor: trees (ref single-model);
    fused/xla executors: flat buffers (resid (W, R, C), ref (R, C)).
    """

    resid: Any = ()
    ref: Any = ()


class HierCommState(NamedTuple):
    """Per-level compressed-sync state for the two-level executors.

    Level 1 (intra-pod): ``resid1`` per worker, ``ref1`` per pod (shared
    within each averaging group).  Level 2 (cross-pod): ``resid2`` per pod,
    ``ref2`` global.  Each half is () when its level is uncompressed.
    """

    resid1: Any = ()
    ref1: Any = ()
    resid2: Any = ()
    ref2: Any = ()


class MemberState(NamedTuple):
    """Elastic-membership state for fault-tolerant rounds.

    ``active``: {0, 1} fp32 mask over workers, shaped to broadcast against
    the flat buffers — flat engine ``(W, 1, 1)``, hierarchical
    ``(P, D, 1, 1)``.  Dead workers keep their rows in the buffers (the
    layout never changes, so nothing recompiles); every sync mean excludes
    them with a ``where`` (not a multiply — a multiply would propagate a
    dead worker's NaNs as ``NaN * 0``).

    ``n_active``: () fp32 — the divisor of the top-level masked mean,
    carried in state so the masked sync stays exactly ONE all-reduce (no
    second collective to count survivors).  Flat engine: number of active
    workers.  Hierarchical: number of ALIVE pods (>= 1 active member) —
    the cross-pod mean is uniform over alive pods, which is the weighting
    that keeps Σ_pods Δ2 = 0 through pod-level churn.

    ``n_pod``: hierarchical only — per-pod active-member counts
    ``(P, 1, 1, 1)`` fp32 (the intra-pod mean's divisors; a pod is alive
    iff its count is > 0).  () on the flat engine.

    Counts are updated exclusively by ``Engine.set_membership`` (the
    repair step), never inside the compiled round.
    """

    active: Any
    n_active: Any
    n_pod: Any = ()


class OverlapState(NamedTuple):
    """Double-buffered overlap state for the overlapped round (one per
    hierarchy level).

    The overlapped round issues its sync collective at round START over the
    positions every participant TRANSMITTED at the previous round boundary,
    so the all-reduce runs concurrently with the next round's local steps
    and the result is folded in one round stale (VRL-SGD's Δ is already a
    previous-round quantity, so the staleness rides the existing math).

    ``pend``: each participant's last transmitted *absolute* position —
    flat engine: (W, R, C) fp32; hierarchical level 2: the per-pod
    (P, 1, R, C) fp32 positions whose cross-pod mean is the overlapped
    collective.  Absolute positions make straggler misses self-healing:
    a participant that misses a capture deadline keeps its old ``pend``
    (its last transmitted value is what the next collective averages) and
    its shortfall is transmitted whole at its next successful capture
    (compressed syncs park the shortfall in the EF residual instead).

    ``pend_k``: per-participant elapsed local steps covered by ``pend``
    ((W, 1, 1) / (P, 1, 1, 1) fp32) — the k_eff that scales the stale
    fold's Δ update, accumulated across missed deadlines.
    """

    pend: Any
    pend_k: Any


class HierState(NamedTuple):
    """Two-level hierarchical VRL-SGD state (reference tree executor).

    Leaves carry a pod-major (P, D, ...) worker grid; the fused executor's
    counterpart is ``core.engine.HierFlatState`` on (P, D, R, C) buffers.
    """

    params: Any              # (P, D, ...) pod-major worker grid
    delta1: Any              # (P, D, ...) intra-pod corrections
    delta2: Any              # (P, 1, ...) cross-pod corrections (per pod)
    inner: Any
    step: jax.Array
    last_sync1: jax.Array    # step of the last level-1 (intra-pod) sync
    last_sync2: jax.Array    # step of the last level-2 (cross-pod) sync
    comm: Any = ()           # per-level compressed-sync state
                             # (HierCommState) — () when uncompressed


def swap_dims(tree, a: int = 0, b: int = 1):
    return jax.tree.map(lambda x: x.swapaxes(a, b), tree)
