"""Shared state containers for the distributed optimizers.

All algorithm state lives on a *worker-stacked* pytree convention: every
leaf has a leading axis of size W (number of VRL workers). On the production
mesh that axis is sharded over the worker mesh axes, so "mean over axis 0"
lowers to exactly one all-reduce over the slow links — the paper's
communication event. On CPU the same code simulates W workers on one device.

``WorkerState`` is the reference executor's tree-structured state; the
fused flat-buffer executor carries the same fields as contiguous (W, R, C)
buffers in ``core.engine.FlatWorkerState`` (layout: ``core.flat``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax


class WorkerState(NamedTuple):
    """State carried by every algorithm in ``repro.core``."""

    params: Any              # (W, ...) worker-stacked model parameters
    delta: Any               # (W, ...) VRL correction Δ_i (zeros if unused)
    inner: Any               # inner-optimizer state (momentum buffers, ...)
    center: Any              # EASGD center variable x̃ (None elsewhere)
    step: jax.Array          # scalar int32: iterations completed
    last_sync: jax.Array     # scalar int32: step index of the last sync
    bias: Any = None         # (W, ...) BVR-L-SGD bias variate B_i (else None)


class HierState(NamedTuple):
    """Two-level hierarchical VRL-SGD state (reference tree executor).

    Leaves carry a pod-major (P, D, ...) worker grid; the fused executor's
    counterpart is ``core.engine.HierFlatState`` on (P, D, R, C) buffers.
    """

    params: Any              # (P, D, ...) pod-major worker grid
    delta1: Any              # (P, D, ...) intra-pod corrections
    delta2: Any              # (P, 1, ...) cross-pod corrections (per pod)
    inner: Any
    step: jax.Array
    last_sync1: jax.Array    # step of the last level-1 (intra-pod) sync
    last_sync2: jax.Array    # step of the last level-2 (cross-pod) sync


def swap_dims(tree, a: int = 0, b: int = 1):
    return jax.tree.map(lambda x: x.swapaxes(a, b), tree)
