"""Communication-period schedules (beyond-paper extension).

Corollary 5.2 allows k up to O(T^{1/2} N^{-3/2}) for a *fixed* horizon T.
Reading T as "steps so far" suggests an anytime schedule: sync densely early
(when Δ estimates are stale — this generalizes the Remark 5.3 warm-up) and
stretch the period as sqrt(t) later. Because ``vrl_sgd.sync`` uses the true
elapsed period k_eff in the Δ update (eq. 4), any schedule remains exact.

    sched = sqrt_schedule(c=1.0, k_max=64)
    if sched.should_sync(step, last_sync):
        state = alg.sync(cfg, state)

Stagewise schedules (STL-SGD)
-----------------------------

``CommSchedule`` is the *round-structured* schedule the engine consumes
(``VRLConfig.comm_schedule`` → ``core.engine.should_sync`` and the round
drivers): training is a sequence of stages, stage s running ``rounds_s``
communication rounds of ``k_s`` local steps each, with the final stage's
period repeating forever.  STL-SGD (Shen et al., 2020) grows the period
geometrically — ``stagewise_doubling`` builds its schedule, and the closed
form for the total local steps after ``s`` full (uncapped) stages is

    T(s) = rounds_per_stage · k0 · (2^s − 1)

so the number of communication rounds grows only logarithmically in T
(``rounds_per_stage`` per doubling stage) while Local SGD at constant k
pays T/k.  Round boundaries are fixed absolute step counts, so the same
schedule drives both the per-step executors (``period_starting_at`` is
jnp-traceable over ``last_sync``) and the round drivers (``round_sizes``),
and they agree exactly.  Each distinct k is one ``lax.scan`` compilation
unit — a run compiles at most ``len(stages)`` round executables
(``core.engine.RoundCache``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class Schedule:
    kind: str
    k: int = 20
    c: float = 1.0
    k_max: int = 512
    warmup: bool = True

    def period_at(self, step: int) -> int:
        if self.warmup and step <= 1:
            return 1
        if self.kind == "const":
            return self.k
        if self.kind == "sqrt":
            return max(1, min(self.k_max, int(self.c * math.sqrt(step))))
        raise ValueError(self.kind)

    def should_sync(self, step: int, last_sync: int) -> bool:
        """step = iterations completed (post-increment)."""
        return (step - last_sync) >= self.period_at(step)


def const_schedule(k: int, warmup: bool = True) -> Schedule:
    return Schedule(kind="const", k=k, warmup=warmup)


def sqrt_schedule(c: float = 1.0, k_max: int = 512,
                  warmup: bool = True) -> Schedule:
    return Schedule(kind="sqrt", c=c, k_max=k_max, warmup=warmup)


def total_syncs(sched: Schedule, t_total: int) -> int:
    """Communication rounds over a horizon (for complexity comparisons)."""
    n, last = 0, 0
    for t in range(1, t_total + 1):
        if sched.should_sync(t, last):
            n += 1
            last = t
    return n


# ================================================ stagewise round schedules
@dataclass(frozen=True)
class CommSchedule:
    """A stagewise communication-period schedule.

    ``stages`` is a tuple of ``(k, rounds)`` pairs: stage s runs ``rounds``
    communication rounds of ``k`` local steps each, in order; after the
    last stage its ``k`` repeats forever.  Frozen and tuple-valued so it
    hashes (it rides inside ``VRLConfig`` and jit closures).

    Round boundaries are absolute step counts fixed by the schedule alone,
    so the per-step executors (``period_starting_at`` over the state's
    ``last_sync``) and the round drivers (``round_sizes``) sync at exactly
    the same steps.
    """

    stages: Tuple[Tuple[int, int], ...]

    def __post_init__(self):
        if not self.stages:
            raise ValueError("CommSchedule needs at least one stage")
        for k, r in self.stages:
            if k < 1 or r < 1:
                raise ValueError(f"stage ({k}, {r}): k and rounds must "
                                 f"be >= 1")

    @property
    def stage_ks(self) -> Tuple[int, ...]:
        return tuple(k for k, _ in self.stages)

    @property
    def stage_ends(self) -> Tuple[int, ...]:
        """Absolute local-step count at which each stage ends."""
        ends, t = [], 0
        for k, r in self.stages:
            t += k * r
            ends.append(t)
        return tuple(ends)

    def total_steps(self) -> int:
        """Local steps covered by the explicit stages (sum of k·rounds)."""
        return self.stage_ends[-1]

    def period_starting_at(self, last_sync):
        """k for the round that STARTS at step ``last_sync``.

        Accepts a python int (drivers) or a traced jax int (``should_sync``
        inside jit): stage boundaries are baked in as constants, so the
        lookup is one ``searchsorted`` over ≤ len(stages) entries.
        """
        bounds = self.stage_ends[:-1]       # boundary INTO each later stage
        if isinstance(last_sync, int):
            idx = sum(1 for b in bounds if b <= last_sync)
            return self.stage_ks[idx]
        import jax.numpy as jnp
        ks = jnp.asarray(self.stage_ks, dtype=jnp.int32)
        if not bounds:
            return ks[0]
        idx = jnp.searchsorted(jnp.asarray(bounds, dtype=jnp.int32),
                               last_sync.astype(jnp.int32), side="right")
        return ks[jnp.minimum(idx, len(self.stage_ks) - 1)]

    def round_sizes(self, t_total: int) -> List[int]:
        """Per-round k over a horizon of ``t_total`` local steps.

        Only whole rounds: a tail shorter than the next period is left to
        the caller (the launch driver finishes it per-step, exactly like
        the constant-k path).
        """
        out, t = [], 0
        while True:
            k = self.period_starting_at(t)
            if t + k > t_total:
                return out
            out.append(k)
            t += k

    def sync_steps(self, t_total: int) -> List[int]:
        """Absolute step indices of the round-closing syncs over a horizon."""
        steps, t = [], 0
        for k in self.round_sizes(t_total):
            t += k
            steps.append(t)
        return steps

    def distinct_periods(self, t_total: Optional[int] = None) -> List[int]:
        """Sorted distinct round lengths — the number of round executables
        a run compiles (see ``core.engine.RoundCache``)."""
        ks = (self.round_sizes(t_total) if t_total is not None
              else self.stage_ks)
        return sorted(set(ks))


def const_comm(k: int) -> CommSchedule:
    """Constant period k — the seed cadence as a (degenerate) stage list."""
    return CommSchedule(stages=((k, 1),))


def stagewise_doubling(k0: int = 1, k_max: int = 512,
                       rounds_per_stage: int = 4) -> CommSchedule:
    """STL-SGD's geometric period growth: k0, 2·k0, 4·k0, ... capped at
    ``k_max`` (the final stage, which then repeats forever)."""
    if k0 < 1 or k_max < k0:
        raise ValueError(f"need 1 <= k0 <= k_max, got k0={k0} "
                         f"k_max={k_max}")
    stages, k = [], k0
    while k < k_max:
        stages.append((k, rounds_per_stage))
        k *= 2
    stages.append((min(k, k_max), rounds_per_stage))
    return CommSchedule(stages=tuple(stages))


def stagewise_total_steps(k0: int, rounds_per_stage: int,
                          n_stages: int) -> int:
    """STL-SGD closed form: local steps after ``n_stages`` full uncapped
    doubling stages = rounds_per_stage · k0 · (2^n − 1)."""
    return rounds_per_stage * k0 * ((1 << n_stages) - 1)


def custom_stages(stages) -> CommSchedule:
    """Explicit (k, rounds) stage list."""
    return CommSchedule(stages=tuple((int(k), int(r)) for k, r in stages))


def parse_schedule(text: str, k_default: int = 20) -> CommSchedule:
    """CLI syntax for ``--comm-schedule``:

      "const"                      constant at k_default
      "stagewise"                  doubling 1 → k_default, 4 rounds/stage
      "stagewise:k0:rounds:k_max"  doubling with explicit knobs
      "custom:1x4,2x4,8x2"         explicit kxrounds stage list
    """
    kind, _, rest = text.partition(":")
    if kind == "const":
        return const_comm(int(rest) if rest else k_default)
    if kind == "stagewise":
        parts = [int(p) for p in rest.split(":") if p] if rest else []
        k0 = parts[0] if len(parts) > 0 else 1
        rounds = parts[1] if len(parts) > 1 else 4
        k_max = parts[2] if len(parts) > 2 else max(k_default, k0)
        return stagewise_doubling(k0=k0, k_max=k_max,
                                  rounds_per_stage=rounds)
    if kind == "custom":
        stages = []
        for item in rest.split(","):
            k, _, r = item.partition("x")
            stages.append((int(k), int(r or 1)))
        return custom_stages(stages)
    raise ValueError(f"unknown --comm-schedule {text!r}; expected "
                     f"const|stagewise[:k0:rounds:k_max]|custom:kxr,...")
