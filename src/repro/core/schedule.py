"""Communication-period schedules (beyond-paper extension).

Corollary 5.2 allows k up to O(T^{1/2} N^{-3/2}) for a *fixed* horizon T.
Reading T as "steps so far" suggests an anytime schedule: sync densely early
(when Δ estimates are stale — this generalizes the Remark 5.3 warm-up) and
stretch the period as sqrt(t) later. Because ``vrl_sgd.sync`` uses the true
elapsed period k_eff in the Δ update (eq. 4), any schedule remains exact.

    sched = sqrt_schedule(c=1.0, k_max=64)
    if sched.should_sync(step, last_sync):
        state = alg.sync(cfg, state)
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Schedule:
    kind: str
    k: int = 20
    c: float = 1.0
    k_max: int = 512
    warmup: bool = True

    def period_at(self, step: int) -> int:
        if self.warmup and step <= 1:
            return 1
        if self.kind == "const":
            return self.k
        if self.kind == "sqrt":
            return max(1, min(self.k_max, int(self.c * math.sqrt(step))))
        raise ValueError(self.kind)

    def should_sync(self, step: int, last_sync: int) -> bool:
        """step = iterations completed (post-increment)."""
        return (step - last_sync) >= self.period_at(step)


def const_schedule(k: int, warmup: bool = True) -> Schedule:
    return Schedule(kind="const", k=k, warmup=warmup)


def sqrt_schedule(c: float = 1.0, k_max: int = 512,
                  warmup: bool = True) -> Schedule:
    return Schedule(kind="sqrt", c=c, k_max=k_max, warmup=warmup)


def total_syncs(sched: Schedule, t_total: int) -> int:
    """Communication rounds over a horizon (for complexity comparisons)."""
    n, last = 0, 0
    for t in range(1, t_total + 1):
        if sched.should_sync(t, last):
            n += 1
            last = t
    return n
