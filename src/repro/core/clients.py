"""Client sampling: M logical clients over W physical worker slots.

The paper's federated follow-ups (BVR-L-SGD, local-steps analyses) assume
only a *sampled cohort* of clients participates in each round.  This module
adds that regime on top of the flat-buffer engine without touching the
compiled round:

  ``ClientStore``   — a host-side store of per-client engine state.  Every
      (W, R, C) worker-stacked buffer in ``FlatWorkerState`` (params, Δ,
      BVR bias, EF residual, optimizer moments) has an (M, R, C) numpy
      twin; scalar/shared leaves (step, last_sync, EASGD center, the
      compressed-sync reference) are global and stored once.
  ``sample_cohort`` — a seed-deterministic draw of W distinct clients per
      round.

Each round the driver gathers the cohort's rows into the device buffers —
one contiguous fancy-indexed copy per buffer, which is precisely what the
flat layout buys us — runs the UNCHANGED compiled round (still exactly one
sync all-reduce per k steps), and scatters the updated rows back.

Two invariants the store is careful about:

  * Full participation (M == W, cohort = identity) must be BITWISE the
    plain engine path: the gather/scatter round-trip moves bytes through
    host numpy untouched and applies no repair, so the trajectory is the
    one the engine would have produced with no store at all (CI-gated).
  * A strict-subset cohort breaks Σ_i Δ_i = 0 (the sum is zero over all M
    clients, not over any W of them) — the driver runs
    ``Engine.recenter_drift`` on the gathered state before the round.

The worker-slot ``member`` mask is NOT per-client state: it describes the
health of the physical slots (crash/rejoin fault injection composes with
sampling), so it stays device-resident and never round-trips the store —
``scatter`` instead skips the rows of dead slots, leaving those clients'
state exactly as it was before the round.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.core.types import MemberState, OverlapState


def sample_cohort(num_clients: int, cohort_size: int, round_index: int,
                  seed: int = 0) -> np.ndarray:
    """Draw the round's cohort: ``cohort_size`` DISTINCT client ids out of
    ``num_clients``, sorted, int64.

    Deterministic in (seed, round_index) alone — a resumed run re-draws
    the same cohorts for the same rounds, and independent processes agree
    without communicating.  Full participation returns the identity
    permutation (``arange``), which is what makes the M == W gather a
    bitwise no-op.
    """
    if not 0 < cohort_size <= num_clients:
        raise ValueError(
            f"cohort_size must be in [1, {num_clients}], got {cohort_size}")
    if cohort_size == num_clients:
        return np.arange(num_clients, dtype=np.int64)
    rng = np.random.default_rng([seed, round_index])
    pick = rng.choice(num_clients, size=cohort_size, replace=False)
    return np.sort(pick).astype(np.int64)


def _strip_member(state):
    return state._replace(member=())


class ClientStore:
    """Host-side per-client engine state behind a (W, R, C) device window.

    Built from a FRESHLY-INITIALIZED engine state (``engine.init``
    broadcasts one model over the worker axis, so row 0 is every client's
    starting point).  Leaves with a leading worker axis (``ndim == 3`` and
    ``shape[0] == W``) become (M, ...) per-client arrays; everything else
    is a shared global, snapshotted at ``scatter`` time so checkpoints see
    a consistent (store, step) pair.
    """

    def __init__(self, state, num_clients: int):
        if isinstance(state.overlap, OverlapState):
            raise ValueError(
                "client sampling does not compose with overlapped rounds: "
                "the overlap pend buffers are one round stale, so a "
                "gathered cohort would fold positions transmitted by "
                "DIFFERENT clients")
        w = int(state.params.shape[0])
        if num_clients < w:
            raise ValueError(
                f"num_clients ({num_clients}) must be >= the cohort size "
                f"({w} worker slots)")
        self.num_clients = int(num_clients)
        self.cohort_size = w
        host = jax.device_get(_strip_member(state))
        leaves, self.treedef = jax.tree_util.tree_flatten(host)
        self._is_client = [
            getattr(lf, "ndim", 0) == 3 and lf.shape[0] == w
            for lf in leaves
        ]
        self._leaves = [
            np.ascontiguousarray(
                np.broadcast_to(lf[:1], (num_clients,) + lf.shape[1:]))
            if per_client else np.asarray(lf)
            for lf, per_client in zip(leaves, self._is_client)
        ]
        # the server consensus x̂: the post-sync parameter row every
        # participant holds at a round boundary.  Strict-subset cohorts
        # are seeded from it (``gather(..., seed_params=True)``) — the
        # round's Δ update (x̂' − x_i)/(k·γ) assumes the cohort STARTED at
        # the previous consensus, and a client re-entering with params
        # from many rounds ago would otherwise book that whole gap into
        # its control variate
        self.server_params = np.array(host.params[0])

    # ------------------------------------------------------ gather/scatter
    def gather(self, cohort: np.ndarray, member: Any = (),
               like: Any = None, seed_params: bool = False):
        """Load the cohort's client rows into a device state.

        One contiguous fancy-indexed copy per buffer; globals ride along
        from the store.  ``member`` is the device-resident worker-slot
        mask to carry (``()`` when membership is off); ``like`` — when
        given — is a state whose leaf shardings the gathered leaves are
        placed onto (mesh runs).

        ``seed_params=True`` replaces the cohort's parameter rows with
        the server consensus (the federated round contract: the server
        BROADCASTS x̂ to the sampled cohort; what persists per client is
        the control variate, bias, moments and residual).  Callers use it
        for strict-subset cohorts of the broadcast-sync algorithms, and
        must NOT use it at full participation (the bitwise gate) or for
        EASGD (persistent local params are elastic averaging's point).
        """
        cohort = np.asarray(cohort, dtype=np.int64)
        if cohort.shape != (self.cohort_size,):
            raise ValueError(
                f"cohort must have shape ({self.cohort_size},), got "
                f"{cohort.shape}")
        leaves = [lf[cohort] if per_client else lf
                  for lf, per_client in zip(self._leaves, self._is_client)]
        state = jax.tree_util.tree_unflatten(self.treedef, leaves)
        if seed_params:
            state = state._replace(params=np.ascontiguousarray(
                np.broadcast_to(
                    self.server_params.astype(state.params.dtype),
                    state.params.shape)))
        if like is not None:
            # Place onto ``like``'s shardings only when they are actually
            # distributed.  On the first round the init state has not been
            # through the mesh-jitted round yet — its leaves sit
            # uncommitted on the default device, and committing the
            # gathered copy there would make the multi-device shard_map
            # jit refuse the input.  Host leaves are auto-placed by jit,
            # same as the storeless path's init state.
            tgt = _strip_member(like)
            state = jax.tree.map(
                lambda x, t: (jax.device_put(x, t.sharding)
                              if getattr(t, "sharding", None) is not None
                              and len(t.sharding.device_set) > 1 else x),
                state, tgt)
        return state._replace(member=member)

    def scatter(self, state, cohort: np.ndarray) -> None:
        """Write the round's updated rows back to the cohort's clients.

        Rows whose worker slot is marked dead in ``state.member`` are
        SKIPPED — that slot's client keeps its pre-round state (it simply
        did not participate), rather than absorbing whatever a crashed
        slot's buffers hold.  Globals (step, center, sync reference, ...)
        are snapshotted unconditionally.
        """
        cohort = np.asarray(cohort, dtype=np.int64)
        alive = np.ones(self.cohort_size, dtype=bool)
        if isinstance(state.member, MemberState):
            alive = np.asarray(
                jax.device_get(state.member.active)).reshape(-1) > 0
        host = jax.device_get(_strip_member(state))
        leaves = jax.tree_util.tree_flatten(host)[0]
        for i, (lf, per_client) in enumerate(zip(leaves, self._is_client)):
            if per_client:
                self._leaves[i][cohort[alive]] = np.asarray(lf)[alive]
            else:
                self._leaves[i] = np.asarray(lf)
        # refresh the consensus from the post-round rows.  Every round
        # closes with a sync, after which the broadcast-sync algorithms'
        # alive rows are identical — the mean IS that common value (it is
        # never read on the bitwise full-participation path, which does
        # not seed)
        if alive.any():
            p = np.asarray(host.params)
            self.server_params = p[alive].mean(axis=0).astype(p.dtype)

    # -------------------------------------------------------- checkpoints
    def to_tree(self):
        """The store as a checkpointable pytree: the state-shaped client
        tree with (M, ...) per-client leaves, plus the server consensus
        (which must survive a resume — a restored run seeds its first
        strict-subset cohort from it)."""
        return {
            "clients": jax.tree_util.tree_unflatten(self.treedef,
                                                    list(self._leaves)),
            "server_params": self.server_params,
        }

    def load_tree(self, tree) -> None:
        """Install a restored store pytree (shapes must match)."""
        if not isinstance(tree, dict) or set(tree) != {"clients",
                                                       "server_params"}:
            raise ValueError(
                "client store tree must be {'clients', 'server_params'}, "
                f"got {sorted(tree) if isinstance(tree, dict) else type(tree).__name__}")
        leaves, treedef = jax.tree_util.tree_flatten(tree["clients"])
        if treedef != self.treedef:
            raise ValueError(
                f"client store structure mismatch:\n  restored: {treedef}"
                f"\n  expected: {self.treedef}")
        for mine, theirs in zip(self._leaves, leaves):
            theirs = np.asarray(theirs)
            if theirs.shape != mine.shape:
                raise ValueError(
                    f"client store leaf shape mismatch: restored "
                    f"{theirs.shape} != expected {mine.shape}")
        server = np.asarray(tree["server_params"])
        if server.shape != self.server_params.shape:
            raise ValueError(
                f"server consensus shape mismatch: restored {server.shape} "
                f"!= expected {self.server_params.shape}")
        self._leaves = [np.asarray(lf) for lf in leaves]
        self.server_params = server

    def global_leaf(self, name: str):
        """A stored global leaf by state field name (e.g. ``step``)."""
        tree = self.to_tree()["clients"]
        return getattr(tree, name)

    @property
    def nbytes(self) -> int:
        return int(sum(lf.nbytes for lf in self._leaves))

    def meta(self) -> dict:
        """JSON-safe store description for telemetry / run metadata."""
        return {"num_clients": self.num_clients,
                "cohort_size": self.cohort_size,
                "store_bytes": self.nbytes}


def cohort_schedule(num_clients: int, cohort_size: int, rounds: int,
                    seed: int = 0,
                    start_round: int = 0) -> list[np.ndarray]:
    """The cohorts of ``rounds`` consecutive rounds (inspection/tests)."""
    return [sample_cohort(num_clients, cohort_size, r, seed)
            for r in range(start_round, start_round + rounds)]
