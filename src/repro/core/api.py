"""Uniform algorithm interface.

    alg = get_algorithm("vrl_sgd")
    state = alg.init(vrl_cfg, params, num_workers)
    state = alg.train_step(vrl_cfg, state, worker_grads)   # grads: (W, ...)
    model = alg.average_model(state)

Every algorithm is a thin ``engine.AlgoSpec`` description executed by
``repro.core.engine``.  ``get_algorithm`` returns the per-leaf *reference*
executor (tree-structured state, easy to inspect); the production
flat-buffer executors (Pallas "fused" and plain-jnp "xla") are built with
``engine.make_engine`` (selected by ``VRLConfig.update_backend`` in the
train loop — "auto" default: fused on TPU/GPU, xla elsewhere).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.core import (
    bvr_l_sgd,
    easgd,
    engine,
    hierarchical,
    local_sgd,
    ssgd,
    stl_sgd,
    vrl_sgd,
)


class Algorithm(NamedTuple):
    name: str
    init: Callable
    train_step: Callable
    local_step: Callable
    sync: Callable
    average_model: Callable


_ALGS = {
    "vrl_sgd": vrl_sgd,
    "local_sgd": local_sgd,
    "ssgd": ssgd,
    "easgd": easgd,
    "hier_vrl_sgd": hierarchical,
    "stl_sgd": stl_sgd,
    "bvr_l_sgd": bvr_l_sgd,
}


def get_algorithm(name: str) -> Algorithm:
    if name not in _ALGS:
        raise KeyError(f"unknown algorithm {name!r}; known: {sorted(_ALGS)}")
    m = _ALGS[name]
    return Algorithm(
        name=name,
        init=m.init,
        train_step=m.train_step,
        local_step=m.local_step,
        sync=m.sync,
        average_model=getattr(m, "average_model", engine.average_model),
    )


def get_spec(name: str) -> engine.AlgoSpec:
    """The algorithm's engine description (correction term + sync rule)."""
    return engine.get_spec(name)


def list_algorithms() -> list[str]:
    return sorted(_ALGS)
