"""Uniform algorithm interface.

    alg = get_algorithm("vrl_sgd")
    state = alg.init(vrl_cfg, params, num_workers)
    state = alg.train_step(vrl_cfg, state, worker_grads)   # grads: (W, ...)
    model = alg.average_model(state)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.core import easgd, local_sgd, ssgd, vrl_sgd


class Algorithm(NamedTuple):
    name: str
    init: Callable
    train_step: Callable
    local_step: Callable
    sync: Callable
    average_model: Callable


_ALGS = {
    "vrl_sgd": vrl_sgd,
    "local_sgd": local_sgd,
    "ssgd": ssgd,
    "easgd": easgd,
}


def get_algorithm(name: str) -> Algorithm:
    if name not in _ALGS:
        raise KeyError(f"unknown algorithm {name!r}; known: {sorted(_ALGS)}")
    m = _ALGS[name]
    return Algorithm(
        name=name,
        init=m.init,
        train_step=m.train_step,
        local_step=m.local_step,
        sync=m.sync,
        average_model=vrl_sgd.average_model,
    )


def list_algorithms() -> list[str]:
    return sorted(_ALGS)
