"""EASGD baseline [Zhang, Choromanska & LeCun 2015], periodic variant.

Each worker runs k local SGD steps; at a communication round the workers and
the center variable x̃ pull toward each other elastically:

    x_i ← x_i − α (x_i − x̃)
    x̃  ← x̃ + α Σ_i (x_i − x̃)   =  (1 − Nα) x̃ + Nα · x̄

Described by ``SPEC`` (no correction term, "elastic" sync rule, center
variable) and executed by ``core/engine.py``.
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["easgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return engine.ref_init(SPEC, cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return engine.ref_sync(SPEC, cfg, state)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_train_step(SPEC, cfg, state, grads)
