"""EASGD baseline [Zhang, Choromanska & LeCun 2015], periodic variant.

Each worker runs k local SGD steps; at a communication round the workers and
the center variable x̃ pull toward each other elastically:

    x_i ← x_i − α (x_i − x̃)
    x̃  ← x̃ + α Σ_i (x_i − x̃)   =  (1 − Nα) x̃ + Nα · x̄
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import VRLConfig
from repro.core import vrl_sgd
from repro.core.types import WorkerState
from repro.optim.optimizers import make_inner


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    state = vrl_sgd.init(cfg, params, num_workers)
    center = jax.tree.map(lambda x: x[0].astype(jnp.float32), state.params)
    return state._replace(center=center)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    opt = make_inner(cfg)
    new_params, new_inner = opt.update(state.params, grads, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    # Zhang et al. parameterize elasticity as beta/N (beta = easgd_alpha):
    # keeps the center update (1 - beta) x̃ + beta x̄ stable for any N.
    n = jax.tree.leaves(state.params)[0].shape[0]
    a = cfg.easgd_alpha / n

    def upd_worker(x, c):
        return (x.astype(jnp.float32)
                - a * (x.astype(jnp.float32) - c)).astype(x.dtype)

    def upd_center(c, x):
        xbar = jnp.mean(x.astype(jnp.float32), axis=0)
        return (1.0 - n * a) * c + n * a * xbar

    new_params = jax.tree.map(upd_worker, state.params, state.center)
    new_center = jax.tree.map(upd_center, state.center, state.params)
    return state._replace(params=new_params, center=new_center,
                          last_sync=state.step)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    state = local_step(cfg, state, grads)
    return jax.lax.cond(
        (state.step - state.last_sync) >= cfg.comm_period,
        lambda s: sync(cfg, s), lambda s: s, state)
