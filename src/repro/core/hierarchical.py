"""Hierarchical (two-level) VRL-SGD — a thin spec over the shared engine.

On a multi-pod cluster the two communication domains have ~10x different
bandwidth (intra-pod ICI vs cross-pod DCI). The paper uses ONE period k; we
generalize to a two-level scheme, one VRL correction per level:

  level 1 (intra-pod, cheap links):  sync every k1 steps within each pod
      x̂_pod = mean over the pod's workers
      Δ1_i  += (x̂_pod − x_i) / (k1 γ);  x_i = x̂_pod
  level 2 (cross-pod, slow links):   sync every k2 ≥ k1 steps globally
      x̂     = mean over everything
      Δ2_p  += (x̂ − x̂_pod) / (k2 γ)    (one Δ2 per pod, shared)
      x_i    = x̂

  local step:  x_i ← inner_opt(x_i, ∇f_i(x_i, ξ) − Δ1_i − Δ2_p)

Execution lives in ``core/engine.py`` under the ``AlgoSpec`` sync rule
"vrl2" — this module only re-exports the reference executor under the
historical names.  Two interchangeable executors:

  * reference — per-leaf tree math over ``types.HierState`` ((P, D, ...)
    pod-major leaves); the oracle (``engine.ref_hier_*``).
  * fused — ``engine.make_engine`` on ``VRLConfig(algorithm=
    "hier_vrl_sgd", hier=HierConfig(k1, k2, grid))``: state is an
    ``engine.HierFlatState`` of contiguous pod-major (P, D, R, C) buffers
    (layout: ``core/flat.flatten_grid``) with Δ2 carried as (P, 1, R, C),
    the local step is one fused Pallas pass subtracting both corrections
    (``kernels/vrl_update.fused_hier_local_*``), and each sync level is one
    fused pass + ONE ``psum`` over its own mesh axis (level 1: the
    intra-pod axis; level 2: the cross-pod axis) under ``shard_map``.

Properties (tested on BOTH executors, tests/test_hierarchical.py and
tests/test_engine_parity.py):
  * Σ_i Δ1_i = 0 within each pod; Σ_p Δ2_p = 0 across pods.
  * The global average x̂ still follows exact SGD on the mean gradient
    (the paper's eq. 8 survives the composition).
  * k1 = k2 = k with one pod reduces exactly to the paper's Algorithm 1
    (the flat ``vrl_sgd`` spec), fused path included.

Cross-pod bytes drop by k2/k1 relative to flat VRL-SGD at period k1 while
keeping the intra-pod variance correction tight — the right trade on
hardware where DCI is the bottleneck (benchmarks/comm_complexity.py
reports the measured per-axis bytes from the compiled production-mesh HLO).

Overlapped rounds (``VRLConfig.overlap``, fused executor only): the SLOW
collective is the cross-pod level-2 all-reduce, and that is the one the
overlap hides — it is issued at round START over the per-pod positions
transmitted at the previous k2 boundary and its stale mean folds into
params/Δ2 at the boundary, while the cheap intra-pod sync1 stays blocking
(pods stay internally exact).  ``VRLConfig.deadline`` simulates per-POD
stragglers at level 2.  See the engine docstring for the full contract.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple, Union

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import HierState  # noqa: F401  (historical home)


def init(cfg: VRLConfig, params: Any,
         grid: Union[int, Tuple[int, int]]) -> HierState:
    """``grid``: the pod-major (P, D) worker grid; a plain worker count is
    accepted for the uniform Algorithm interface and validated against
    ``cfg.hier.grid``."""
    if isinstance(grid, int):
        hcfg = engine.hier_config(cfg)
        if hcfg.grid[0] * hcfg.grid[1] != grid:
            raise ValueError(f"hier grid {hcfg.grid} holds "
                             f"{hcfg.grid[0] * hcfg.grid[1]} workers, "
                             f"init asked for {grid}")
        grid = hcfg.grid
    return engine.ref_hier_init(cfg, params, grid)


def local_step(cfg: VRLConfig, state: HierState, grads: Any) -> HierState:
    return engine.ref_hier_local_step(cfg, state, grads)


def sync_level1(cfg: VRLConfig, state: HierState) -> HierState:
    """Intra-pod sync: mean over axis 1 (the pod-internal worker axis)."""
    return engine.ref_hier_sync1(cfg, state)


def sync_level2(cfg: VRLConfig, state: HierState) -> HierState:
    """Cross-pod sync. Assumes a level-1 sync at the same step (so every
    worker already holds its pod average)."""
    return engine.ref_hier_sync2(cfg, state)


def sync(cfg: VRLConfig, state: HierState) -> HierState:
    """The full level-2 boundary event: intra-pod then cross-pod."""
    return sync_level2(cfg, sync_level1(cfg, state))


def train_step(cfg: VRLConfig, state: HierState, grads: Any, *,
               k1: Optional[int] = None, k2: Optional[int] = None
               ) -> HierState:
    """Local step + conditional per-level syncs (periods from ``cfg.hier``
    unless overridden)."""
    return engine.ref_hier_train_step(cfg, state, grads, k1=k1, k2=k2)


def average_model(state: HierState) -> Any:
    return engine.hier_average_model(state)
