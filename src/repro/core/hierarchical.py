"""Hierarchical VRL-SGD (beyond-paper extension).

On a multi-pod cluster the two communication domains have ~10x different
bandwidth (intra-pod ICI vs cross-pod DCI). The paper uses ONE period k; we
generalize to a two-level scheme, one VRL correction per level:

  level 1 (intra-pod, cheap links):  sync every k1 steps within each pod
      x̂_pod = mean over the pod's workers
      Δ1_i  += (x̂_pod − x_i) / (k1 γ);  x_i = x̂_pod
  level 2 (cross-pod, slow links):   sync every k2 ≥ k1 steps globally
      x̂     = mean over everything
      Δ2_p  += (x̂ − x̂_pod) / (k2 γ)    (one Δ2 per pod, shared)
      x_i    = x̂

  local step:  x_i ← x_i − γ (∇f_i(x_i, ξ) − Δ1_i − Δ2_p)

Properties (tested):
  * Σ_i Δ1_i = 0 within each pod; Σ_p Δ2_p = 0 across pods.
  * The global average x̂ still follows exact SGD on the mean gradient
    (the paper's eq. 8 survives the composition).
  * k1 = k2 = k with one pod reduces to the paper's Algorithm 1.

Cross-pod bytes drop by k2/k1 relative to flat VRL-SGD at period k1 while
keeping the intra-pod variance correction tight — the right trade on
hardware where DCI is the bottleneck (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import VRLConfig
from repro.optim.optimizers import make_inner


class HierState(NamedTuple):
    params: Any        # (P, D, ...) pod-major worker grid
    delta1: Any        # (P, D, ...) intra-pod corrections
    delta2: Any        # (P, 1, ...) cross-pod corrections (shared in pod)
    inner: Any
    step: jax.Array
    last_sync1: jax.Array
    last_sync2: jax.Array


def init(cfg: VRLConfig, params: Any, grid: Tuple[int, int]) -> HierState:
    p, d = grid
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (p, d, *x.shape)).copy(), params)
    dt = jnp.dtype(cfg.delta_dtype)
    z = lambda x: jnp.zeros_like(x, dtype=dt)
    d2 = jax.tree.map(
        lambda x: jnp.zeros((p, 1, *x.shape[2:]), dt), stacked)
    inner = make_inner(cfg).init(stacked)
    return HierState(params=stacked, delta1=jax.tree.map(z, stacked),
                     delta2=d2, inner=inner,
                     step=jnp.zeros((), jnp.int32),
                     last_sync1=jnp.zeros((), jnp.int32),
                     last_sync2=jnp.zeros((), jnp.int32))


def local_step(cfg: VRLConfig, state: HierState, grads: Any) -> HierState:
    v = jax.tree.map(
        lambda g, d1, d2: g - d1.astype(g.dtype) - d2.astype(g.dtype),
        grads, state.delta1, state.delta2)
    opt = make_inner(cfg)
    new_params, new_inner = opt.update(state.params, v, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def sync_level1(cfg: VRLConfig, state: HierState) -> HierState:
    """Intra-pod sync: mean over axis 1 (the pod-internal worker axis)."""
    k_eff = jnp.maximum(state.step - state.last_sync1, 1).astype(jnp.float32)
    xbar = jax.tree.map(lambda x: jnp.mean(x, axis=1, keepdims=True),
                        state.params)

    def upd(d, x, xb):
        return (d.astype(jnp.float32)
                + (xb.astype(jnp.float32) - x.astype(jnp.float32))
                / (k_eff * cfg.learning_rate)).astype(d.dtype)

    new_d1 = jax.tree.map(upd, state.delta1, state.params, xbar)
    new_p = jax.tree.map(lambda x, xb: jnp.broadcast_to(xb, x.shape).astype(x.dtype),
                         state.params, xbar)
    return state._replace(params=new_p, delta1=new_d1,
                          last_sync1=state.step)


def sync_level2(cfg: VRLConfig, state: HierState) -> HierState:
    """Cross-pod sync. Assumes a level-1 sync at the same step (so every
    worker already holds its pod average)."""
    k_eff = jnp.maximum(state.step - state.last_sync2, 1).astype(jnp.float32)
    pod_avg = jax.tree.map(lambda x: jnp.mean(x, axis=1, keepdims=True),
                           state.params)
    glob = jax.tree.map(lambda x: jnp.mean(x, axis=(0, 1), keepdims=True),
                        state.params)

    def upd(d2, pa, g):
        return (d2.astype(jnp.float32)
                + (g.astype(jnp.float32) - pa.astype(jnp.float32))
                / (k_eff * cfg.learning_rate)).astype(d2.dtype)

    new_d2 = jax.tree.map(upd, state.delta2, pod_avg, glob)
    new_p = jax.tree.map(lambda x, g: jnp.broadcast_to(g, x.shape).astype(x.dtype),
                         state.params, glob)
    return state._replace(params=new_p, delta2=new_d2,
                          last_sync2=state.step)


def train_step(cfg: VRLConfig, state: HierState, grads: Any, *,
               k1: int, k2: int) -> HierState:
    state = local_step(cfg, state, grads)
    do1 = (state.step - state.last_sync1) >= k1
    do2 = (state.step - state.last_sync2) >= k2
    state = jax.lax.cond(do1 | do2, lambda s: sync_level1(cfg, s),
                         lambda s: s, state)
    state = jax.lax.cond(do2, lambda s: sync_level2(cfg, s),
                         lambda s: s, state)
    return state


def average_model(state: HierState) -> Any:
    return jax.tree.map(lambda x: jnp.mean(x, axis=(0, 1)), state.params)
