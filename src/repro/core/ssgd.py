"""Synchronous SGD baseline [Ghadimi & Lan 2013]: gradient all-reduce every
step. The reference point for *linear iteration speedup*; communication
complexity O(T).

Described by ``SPEC`` (gradient all-reduce every step, no periodic sync) and
executed by ``core/engine.py``.
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import VRLConfig
from repro.core import engine
from repro.core.types import WorkerState

SPEC = engine.ALGO_SPECS["ssgd"]


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return engine.ref_init(SPEC, cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    # "local" step of S-SGD still all-reduces: that's the point of the paper.
    return engine.ref_local_step(SPEC, cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return engine.ref_sync(SPEC, cfg, state)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    return engine.ref_train_step(SPEC, cfg, state, grads)
