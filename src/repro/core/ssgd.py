"""Synchronous SGD baseline [Ghadimi & Lan 2013]: gradient all-reduce every
step. The reference point for *linear iteration speedup*; communication
complexity O(T).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import VRLConfig
from repro.core import vrl_sgd
from repro.core.types import WorkerState
from repro.optim.optimizers import make_inner


def init(cfg: VRLConfig, params: Any, num_workers: int) -> WorkerState:
    return vrl_sgd.init(cfg, params, num_workers)


def local_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    # "local" step of S-SGD still all-reduces: that's the point of the paper.
    return train_step(cfg, state, grads)


def sync(cfg: VRLConfig, state: WorkerState) -> WorkerState:
    return state._replace(last_sync=state.step)


def train_step(cfg: VRLConfig, state: WorkerState, grads: Any) -> WorkerState:
    gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True), grads)
    gbar = jax.tree.map(lambda g, x: jnp.broadcast_to(g, x.shape),
                        gbar, state.params)
    opt = make_inner(cfg)
    new_params, new_inner = opt.update(state.params, gbar, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1, last_sync=state.step + 1)
