"""Flat-buffer algorithm engine — the shared execution core for VRL-SGD and
its baselines.

Engine architecture
===================

Every algorithm in ``repro.core`` is the same loop over worker-stacked,
model-sized state: a *local* elementwise update per step (eqs. 5-6: the
inner-optimizer step on the Δ-corrected gradient) and a periodic *sync*
(eq. 4: model averaging — the one communication event the paper's
O(T^{1/2}N^{3/2}) complexity counts — plus the Δ update).  The engine
factors that loop into two orthogonal pieces:

  1. ``AlgoSpec`` — a thin *description* of an algorithm: does the local
     step subtract Δ (and BVR-L-SGD's bias variate B), is the gradient
     all-reduced every step (S-SGD), and which sync rule runs at period
     boundaries ("vrl" | "average" | "elastic" | "none" | "bvr").
     ``core/{vrl_sgd,local_sgd,ssgd,easgd,stl_sgd,bvr_l_sgd}.py`` are now
     just named specs plus thin wrappers over this module.

  2. Two interchangeable executors over a spec:

     * the **reference** executor (``ref_*``): per-leaf ``jax.tree.map``
       math over the parameter pytree — easy to read, slow (5+ HBM passes
       per local step), and the oracle the fused path is tested against.

     * the **fused** executor (``make_engine``): parameters, Δ, and the
       inner-optimizer moments are flattened ONCE at init into contiguous
       per-worker (W, R, C) buffers (layout + unravel spec: ``core/flat``),
       and every step runs a single fused Pallas kernel
       (``kernels/vrl_update.fused_*``) — one HBM pass for the local step,
       one fused pass + a SINGLE flat all-reduce for sync.

Worker axis
-----------

With ``mesh=None`` (CPU / single device) the worker axis is just the
leading buffer dimension and "all-reduce" is ``jnp.mean`` over it.  Given a
mesh, the engine step functions are wrapped in ``shard_map`` over the
configured worker axes: the sync's model average lowers to exactly one
``psum`` over the flat (R, C) buffer — the compiled HLO contains one
all-reduce per sync and none in local steps (asserted in
``tests/test_engine_collectives.py``).

Two-level hierarchy
-------------------

``hier_vrl_sgd`` (sync rule "vrl2", ``configs.base.HierConfig``) runs the
same loop over a pod-major (P, D, R, C) worker grid with one correction per
link tier: Δ1 per worker (intra-pod, period k1) and Δ2 per pod carried as a
(P, 1, R, C) buffer (cross-pod, period k2 ≥ k1).  On a mesh the level-1
sync lowers to one ``psum`` over the intra-pod axis and the level-2 sync to
one ``psum`` over the cross-pod axis (``HierConfig.axes``), so the slow DCI
tier is touched k2/k1 times less often than flat VRL-SGD at k1.  Both
executors cover it: the per-leaf reference path over ``types.HierState``
and the fused path over ``HierFlatState`` with the
``kernels/vrl_update.fused_hier_*`` / ``fused_sync_hier{1,2}`` kernels.

Backend selection
-----------------

``VRLConfig.update_backend`` ("auto" | "fused" | "xla" | "reference")
threads from ``configs/base.py`` through ``train/train_loop.py`` to the
launch drivers.  The flat-buffer engine has TWO interchangeable executors
over the same state layout:

  * "fused" — the Pallas kernels (``kernels/vrl_update``): explicit HBM
    passes, the right choice where Pallas compiles (TPU/GPU).  On other
    backends Pallas falls back to interpret mode (python per block) and is
    orders of magnitude slower than either alternative.
  * "xla" — the identical (W, R, C) elementwise math as plain jnp
    (``kernels/xla_update``): XLA fuses the chain into one pass, so it is
    the fast executor on CPU (and a portable fallback anywhere).

``resolve_backend`` maps "auto" to fused on TPU/GPU and xla elsewhere;
forcing "fused" where interpret mode would run emits a one-line warning.
Tiling knobs (``block``, ``lanes``, ``interpret``) live in
``configs.base.EngineConfig``.

Round execution
---------------

``Engine.round_step(state, grads_k)`` makes the *communication round* the
unit of compilation: k local steps run under one ``lax.scan`` over
pre-flattened (k, ...) gradient buffers — no per-step python dispatch, no
host sync — followed by ``round_end`` (flat: the sync; hierarchical: the
level-1 sync plus the level-2 sync whenever the k2 cadence is due, which
requires k2 % k1 == 0).  Jit it with ``donate_argnums=(0,)`` and the
compiled HLO aliases every state buffer in place (asserted in
``tests/test_round_scan.py``); on a mesh the whole round still lowers to
exactly one sync collective per k steps
(``tests/test_engine_collectives.py``).

Rounds take k from the leading axis of the grads stack, so a stagewise
``CommSchedule`` (``core/schedule.py``, ``VRLConfig.comm_schedule``) just
feeds differently-sized stacks per stage: ``RoundCache`` keys one compiled
round executable per distinct k, so a whole stagewise run compiles at most
``len(stages)`` rounds, and the sync math stays exact at any period because
it uses the true elapsed k_eff.

Compressed sync (bytes-per-round)
---------------------------------

``VRLConfig.compress`` / ``compress2`` (``repro.comm.CompressorSpec``:
``none`` | ``int8`` per-row-scaled quantization | ``topk`` fixed-k
sparsification, optional error feedback) compress the payload of every
communication event: each worker transmits its DRIFT against a shared
reference (the value every participant holds after the previous sync,
carried in a ``CommState.ref`` buffer), the decompressed drifts are
averaged by the SAME single flat all-reduce, and the compression error is
carried per worker in a donated ``CommState.resid`` buffer (EF-SGD).
S-SGD, whose communication is the per-step gradient all-reduce, compresses
the gradient itself (ref ≡ 0).  The hierarchy compresses per level —
``compress`` drives the intra-pod sync1, ``compress2`` the slow cross-pod
sync2 (``HierCommState`` carries per-level ref/resid) — and ``none`` /
``topk`` at rate 1 resolve to the ORIGINAL code path, bitwise, with no
extra buffers.  Executors: Pallas ``kernels/vrl_update.fused_ef_*`` (one
HBM pass builds payload → decompressed + residual), jnp twins in
``kernels/xla_update``, and per-leaf ``repro.comm.compressors.ef_leaf`` on
the reference path.

Overlapped rounds (``VRLConfig.overlap``)
-----------------------------------------

The blocking round waits on the sync collective at every boundary.  With
``overlap=True`` the round driver instead issues THE sync all-reduce at
round START, over the positions every participant transmitted at the
PREVIOUS boundary (``types.OverlapState.pend``), so the collective's data
dependencies are all ready before the k-step ``lax.scan`` begins and the
scheduler can run wire and compute concurrently; the one-round-stale mean
is folded in at round end (``kernels/*.fused_fold_overlap*``):

  c_i = x̂_stale − pend_i;   p' = p + c_i;   Δ' = Δ + c_i/(pend_k_i·γ)

Σ_i c_i = 0, so the worker-mean trajectory is untouched and Σ_i Δ_i stays
0 — VRL-SGD's Δ is already a previous-round quantity, so the staleness
rides the existing math.  The compiled round still lowers to exactly one
sync all-reduce per k steps.  ``deadline`` adds straggler tolerance: each
round each participant misses its capture with that probability
(simulated), keeps its last transmitted position (absolute positions make
misses self-healing), and — under compressed sync — parks the missed
payload in its EF residual.  Hierarchical runs overlap the cross-pod
sync2 (the slow DCI tier) only; sync1 stays blocking.  ``overlap=False``
builds the exact blocking program (no new buffers or ops, bitwise).  Only
the round drivers (``round_step``/``round_begin``+``round_fold``)
overlap; the per-step ``train_step`` path stays blocking and should not
be mixed with overlapped rounds (it would not maintain ``pend``).

Elastic membership (``VRLConfig.membership``)
---------------------------------------------

Real workers crash and rejoin.  With ``membership=True`` the state carries
a ``types.MemberState`` (an active-worker {0,1} mask plus the active
counts) and every sync mean runs over the ACTIVE workers only: dead rows
are excluded with a ``where`` (a multiply would propagate a crashed
worker's NaNs as ``NaN * 0``) and the divisor is the state-carried count,
so the masked sync is STILL exactly one all-reduce per round — no second
collective to count survivors.  Dead rows stay allocated (layouts and
compiled programs never change); ``Engine.set_membership(state, active)``
is the out-of-round repair step that makes a membership change safe:

  * continuing workers: Δ (and BVR's B) recentred to mean zero over the
    continuing set — algebraically identical to redistributing every
    dropped worker's Δ across the survivors, but computed without reading
    a dropped row, so crash NaNs cannot leak — keeping Σ_i Δ_i = 0 exact;
  * dropped + rejoining workers: params (and overlap ``pend``) re-seeded
    from the continuing consensus x̂, Δ/B/moments/EF residuals zeroed — a
    rejoiner restarts from the current reference point.

With the mask fully active the trajectory is bitwise the
``membership=False`` path.  Hierarchical runs mask per level: intra-pod
means divide by per-pod active counts and the cross-pod mean is uniform
over ALIVE pods (the weighting that keeps Σ_p Δ2 = 0 through pod churn).
easgd's center update assumes a fixed worker count and refuses the mask.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import compressors as comm_mod
from repro.configs.base import HierConfig, VRLConfig
from repro.core import flat
from repro.core import schedule as schedule_mod
from repro.core.types import (CommState, HierCommState, HierState,
                              MemberState, OverlapState, WorkerState)
from repro.kernels import vrl_update as vu
from repro.kernels import xla_update as xu
from repro.optim.optimizers import AdamState, SM3Pair, make_inner


BACKENDS = ("auto", "fused", "xla", "reference")


def resolve_backend(cfg_or_name) -> str:
    """Resolve ``update_backend`` to a concrete executor name.

    "auto" picks the Pallas kernels where they compile (TPU/GPU) and the
    XLA executor elsewhere (CPU) — never the interpret-mode fallback.
    Accepts a VRLConfig or a bare string.
    """
    name = getattr(cfg_or_name, "update_backend", cfg_or_name)
    if name not in BACKENDS:
        raise ValueError(f"unknown update_backend {name!r}; known: "
                         f"{BACKENDS}")
    if name == "auto":
        return "fused" if jax.default_backend() in ("tpu", "gpu") else "xla"
    return name


# ===================================================================== specs
class AlgoSpec(NamedTuple):
    """An algorithm as a description over the shared engine.

    ``sync`` names the rule that runs at period boundaries; "vrl2" is the
    two-level rule (intra-pod "vrl" at k1, cross-pod "vrl" at k2) whose
    state lives on a pod-major worker grid instead of a flat worker axis;
    "bvr" is the VRL rule plus BVR-L-SGD's bias-variate EMA.
    """

    name: str
    use_delta: bool        # local step applies v = g − Δ (eq. 6)
    grad_all_reduce: bool  # S-SGD: mean gradients over workers every step
    sync: str              # "vrl" | "average" | "elastic" | "none" | "vrl2"
                           # | "bvr"
    has_center: bool       # EASGD center variable x̃
    warmup_aware: bool     # honors VRLConfig.warmup (first period k=1)
    use_bias: bool = False  # BVR-L-SGD: local step also subtracts B
    stagewise: bool = False  # STL-SGD: default to a stagewise CommSchedule


ALGO_SPECS = {
    "vrl_sgd": AlgoSpec("vrl_sgd", use_delta=True, grad_all_reduce=False,
                        sync="vrl", has_center=False, warmup_aware=True),
    "local_sgd": AlgoSpec("local_sgd", use_delta=False, grad_all_reduce=False,
                          sync="average", has_center=False,
                          warmup_aware=False),
    "ssgd": AlgoSpec("ssgd", use_delta=False, grad_all_reduce=True,
                     sync="none", has_center=False, warmup_aware=False),
    "easgd": AlgoSpec("easgd", use_delta=False, grad_all_reduce=False,
                      sync="elastic", has_center=True, warmup_aware=False),
    "hier_vrl_sgd": AlgoSpec("hier_vrl_sgd", use_delta=True,
                             grad_all_reduce=False, sync="vrl2",
                             has_center=False, warmup_aware=False),
    # STL-SGD (Shen et al., 2020): Local SGD whose communication period
    # grows stagewise — the update structure IS local_sgd's; the stagewise
    # cadence comes from the CommSchedule (comm_schedule() below), so with
    # a constant schedule the trajectory is bitwise local_sgd.
    "stl_sgd": AlgoSpec("stl_sgd", use_delta=False, grad_all_reduce=False,
                        sync="average", has_center=False,
                        warmup_aware=False, stagewise=True),
    # BVR-L-SGD (Murata & Suzuki, 2021): VRL-SGD plus a bias-corrected
    # control variate.  The engine sees one gradient per step, so the
    # paper's same-sample anchor-gradient correction is carried in its
    # parameter-motion form: B_i is an EMA (rate cfg.bvr_beta) of the
    # per-round realized drift u_i = (x̂ − x_i)/(k_eff γ), subtracted in
    # every local step alongside Δ_i.  Σ_i B_i = 0 after every sync (same
    # argument as Δ), and bvr_beta=0 disables the correction at trace time
    # — the trajectory is then bitwise vrl_sgd.
    "bvr_l_sgd": AlgoSpec("bvr_l_sgd", use_delta=True,
                          grad_all_reduce=False, sync="bvr",
                          has_center=False, warmup_aware=True,
                          use_bias=True),
}


def flat_algorithms() -> Tuple[str, ...]:
    """Registry-derived names of the flat (non-hierarchical) algorithms —
    tests iterate this so new specs are covered automatically."""
    return tuple(n for n, s in sorted(ALGO_SPECS.items())
                 if s.sync != "vrl2")


def comm_schedule(cfg: VRLConfig):
    """The round schedule driving this config's sync cadence.

    ``cfg.comm_schedule`` when set; stl_sgd defaults to the STL-SGD
    stagewise-doubling ramp 1 → ``comm_period``; None otherwise (the
    constant ``comm_period`` cadence, the seed behaviour).  A schedule
    supersedes ``warmup`` — express a warm start as an initial k=1 stage.
    """
    if cfg.comm_schedule is not None:
        return cfg.comm_schedule
    if get_spec(cfg.algorithm).stagewise:
        return schedule_mod.stagewise_doubling(k0=1, k_max=cfg.comm_period)
    return None


def use_bias(spec: AlgoSpec, cfg: VRLConfig) -> bool:
    """True when the BVR bias variate is active.  ``bvr_beta == 0`` turns
    the whole B machinery off at trace time, so the compiled program (and
    trajectory) is bitwise the underlying VRL-SGD."""
    return spec.use_bias and bool(cfg.bvr_beta)


def hier_config(cfg: VRLConfig) -> HierConfig:
    """The two-level periods/grid; defaults to the flat period at k1=k2."""
    if cfg.hier is not None:
        return cfg.hier
    return HierConfig(k1=cfg.comm_period, k2=cfg.comm_period)


def get_spec(name: str) -> AlgoSpec:
    if name not in ALGO_SPECS:
        raise KeyError(f"unknown algorithm {name!r}; known: "
                       f"{sorted(ALGO_SPECS)}")
    return ALGO_SPECS[name]


def should_sync(spec: AlgoSpec, cfg: VRLConfig, step: jax.Array,
                last_sync: jax.Array) -> jax.Array:
    """True when ``step`` (post-increment) completes a communication period.

    With a ``CommSchedule`` the period is the schedule's for the round
    starting at ``last_sync`` (stage boundaries are compile-time constants,
    so this stays one jit); otherwise the constant ``comm_period``.
    VRL-SGD-W (Remark 5.3): with ``warmup`` the first period runs k=1.
    """
    sched = comm_schedule(cfg)
    if sched is not None:
        k = sched.period_starting_at(last_sync)
    elif spec.warmup_aware:
        k = jnp.where(cfg.warmup & (last_sync == 0) & (step <= 1),
                      1, cfg.comm_period)
    else:
        k = cfg.comm_period
    return (step - last_sync) >= k


# ======================================================== reference executor
# Per-leaf tree math — the oracle path.  Exactly the seed implementations,
# now generic over AlgoSpec.

def _bcast(tree, w: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (w, *x.shape)).copy(),
                        tree)


def worker_mean(tree):
    return jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=True), tree)


def average_model(state) -> Any:
    """x̂ — the evaluation model (paper reports metrics on the average)."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)


def _ref_payload(tree_x, ref, resid):
    """Per-leaf compression payload: x − ref + resid in fp32 (``ref`` /
    ``resid`` trees optional; ref leaves broadcast against the worker
    axes)."""
    def one(x, *rest):
        p = x.astype(jnp.float32)
        i = 0
        if ref is not None:
            p = p - rest[i]
            i += 1
        if resid is not None:
            p = p + rest[i]
        return p

    extra = ([ref] if ref is not None else []) \
        + ([resid] if resid is not None else [])
    return jax.tree.map(one, tree_x, *extra)


def _leaf_rt(comp, payload_tree, n_lead: int):
    """Per-leaf EF round-trip over a payload tree → (dec tree, resid
    tree), tracing ``ef_leaf`` once per leaf."""
    outer = jax.tree.structure(payload_tree)
    pairs = jax.tree.map(
        lambda x: comm_mod.ef_leaf(comp, x, n_lead), payload_tree)
    return jax.tree_util.tree_transpose(
        outer, jax.tree.structure((0, 0)), pairs)


def ref_init(spec: AlgoSpec, cfg: VRLConfig, params: Any,
             num_workers: int) -> WorkerState:
    stacked = _bcast(params, num_workers)
    delta_dt = jnp.dtype(cfg.delta_dtype)
    delta = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=delta_dt), stacked)
    inner = make_inner(cfg).init(stacked)
    center = (jax.tree.map(lambda x: x[0].astype(jnp.float32), stacked)
              if spec.has_center else None)
    bias = (jax.tree.map(lambda x: jnp.zeros_like(x, dtype=delta_dt),
                         stacked) if use_bias(spec, cfg) else None)
    comp, _ = comm_mod.resolve_pair(cfg)
    comm = ()
    if comp is not None:
        # residuals in fp32 so the EF invariant (resid + dec == payload)
        # is exact; ref is the shared post-sync value (init: the broadcast
        # params themselves) — () for S-SGD's gradient compression
        resid = (jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                              stacked) if comp.error_feedback else ())
        ref = (() if (spec.grad_all_reduce or spec.sync == "none")
               else jax.tree.map(lambda x: x.astype(jnp.float32), params))
        comm = CommState(resid=resid, ref=ref)
    return WorkerState(params=stacked, delta=delta, inner=inner,
                       center=center, step=jnp.zeros((), jnp.int32),
                       last_sync=jnp.zeros((), jnp.int32), bias=bias,
                       comm=comm)


def corrected_grads(state: WorkerState, grads: Any) -> Any:
    """v_i = g_i − Δ_i  (eq. 6)."""
    return jax.tree.map(lambda g, d: g - d.astype(g.dtype), grads,
                        state.delta)


def ref_local_step(spec: AlgoSpec, cfg: VRLConfig, state: WorkerState,
                   grads: Any) -> WorkerState:
    opt = make_inner(cfg)
    if spec.grad_all_reduce:
        # S-SGD's "local" step IS a train step: that's the point of the paper.
        comp, _ = comm_mod.resolve_pair(cfg)
        new_comm = state.comm
        if comp is not None:
            # the gradient IS the communicated payload: compress it (ref≡0)
            e = state.comm.resid if comp.error_feedback else None
            dec, res = _leaf_rt(comp, _ref_payload(grads, None, e), 1)
            gbar = jax.tree.map(
                lambda d: jnp.mean(d, axis=0, keepdims=True), dec)
            if comp.error_feedback:
                new_comm = state.comm._replace(resid=res)
        else:
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True),
                                grads)
        gbar = jax.tree.map(lambda g, x: jnp.broadcast_to(g, x.shape),
                            gbar, state.params)
        new_params, new_inner = opt.update(state.params, gbar, state.inner)
        return state._replace(params=new_params, inner=new_inner,
                              step=state.step + 1, last_sync=state.step + 1,
                              comm=new_comm)
    v = corrected_grads(state, grads) if spec.use_delta else grads
    if use_bias(spec, cfg):
        v = jax.tree.map(lambda g, b: g - b.astype(g.dtype), v, state.bias)
    new_params, new_inner = opt.update(state.params, v, state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def ref_sync(spec: AlgoSpec, cfg: VRLConfig, state: WorkerState
             ) -> WorkerState:
    if spec.sync == "none":
        return state._replace(last_sync=state.step)

    # compressed sync: transmit per-worker drift against the shared ref,
    # average the decompressed drifts (mean_i x_i = ref + mean_i(x_i − ref))
    comp, _ = comm_mod.resolve_pair(cfg)
    new_comm = state.comm
    xbar = None
    if comp is not None:
        e = state.comm.resid if comp.error_feedback else None
        payload = _ref_payload(state.params, state.comm.ref, e)
        dec, res = _leaf_rt(comp, payload, 1)
        ref_new = jax.tree.map(lambda r, d: r + jnp.mean(d, axis=0),
                               state.comm.ref, dec)
        xbar = jax.tree.map(lambda x: x[None], ref_new)
        new_comm = CommState(resid=(res if comp.error_feedback else ()),
                             ref=ref_new)

    if spec.sync == "elastic":
        # Zhang et al. parameterize elasticity as beta/N (beta = easgd_alpha).
        n = jax.tree.leaves(state.params)[0].shape[0]
        a = cfg.easgd_alpha / n

        def upd_worker(x, c):
            return (x.astype(jnp.float32)
                    - a * (x.astype(jnp.float32) - c)).astype(x.dtype)

        if xbar is None:
            def upd_center(c, x):
                xb = jnp.mean(x.astype(jnp.float32), axis=0)
                return (1.0 - n * a) * c + n * a * xb

            new_center = jax.tree.map(upd_center, state.center, state.params)
        else:
            new_center = jax.tree.map(
                lambda c, xb: (1.0 - n * a) * c + n * a * xb[0],
                state.center, xbar)
        new_params = jax.tree.map(upd_worker, state.params, state.center)
        return state._replace(params=new_params, center=new_center,
                              last_sync=state.step, comm=new_comm)

    if xbar is None:
        xbar = worker_mean(state.params)                # the all-reduce
    new_params = jax.tree.map(
        lambda x, xb: jnp.broadcast_to(xb, x.shape).astype(x.dtype),
        state.params, xbar)
    if spec.sync == "average":
        return state._replace(params=new_params, last_sync=state.step,
                              comm=new_comm)

    # "vrl"/"bvr": Δ_i ← Δ_i + u_i, u_i = (x̂ − x_i)/(k_eff γ)  (eq. 4)
    k_eff = jnp.maximum(state.step - state.last_sync, 1).astype(jnp.float32)

    def drift(x, xb):
        return ((xb.astype(jnp.float32) - x.astype(jnp.float32))
                / (k_eff * cfg.learning_rate))

    def upd_delta(d, x, xb):
        return (d.astype(jnp.float32) + drift(x, xb)).astype(d.dtype)

    new_delta = jax.tree.map(upd_delta, state.delta, state.params, xbar)
    new_bias = state.bias
    if spec.sync == "bvr" and use_bias(spec, cfg):
        # B_i ← (1−β)·B_i + β·u_i — the bias-variate EMA of realized drift
        beta = cfg.bvr_beta

        def upd_bias(b, x, xb):
            return ((1.0 - beta) * b.astype(jnp.float32)
                    + beta * drift(x, xb)).astype(b.dtype)

        new_bias = jax.tree.map(upd_bias, state.bias, state.params, xbar)
    return state._replace(params=new_params, delta=new_delta,
                          bias=new_bias, last_sync=state.step,
                          comm=new_comm)


def ref_train_step(spec: AlgoSpec, cfg: VRLConfig, state: WorkerState,
                   grads: Any) -> WorkerState:
    state = ref_local_step(spec, cfg, state, grads)
    if spec.sync == "none":
        return state
    return jax.lax.cond(
        should_sync(spec, cfg, state.step, state.last_sync),
        lambda s: ref_sync(spec, cfg, s), lambda s: s, state)


# ---------------------------------------------- reference executor ("vrl2")
# The two-level rule over a pod-major (P, D, ...) tree state — the oracle
# for the fused hierarchical path (``core/hierarchical.py`` is a thin
# wrapper over these).

def ref_hier_init(cfg: VRLConfig, params: Any,
                  grid: Tuple[int, int]) -> HierState:
    p, d = grid
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (p, d, *x.shape)).copy(), params)
    dt = jnp.dtype(cfg.delta_dtype)
    z = lambda x: jnp.zeros_like(x, dtype=dt)
    d2 = jax.tree.map(lambda x: jnp.zeros((p, 1, *x.shape[2:]), dt), stacked)
    inner = make_inner(cfg).init(stacked)
    comp1, comp2 = comm_mod.resolve_pair(cfg)
    comm = ()
    if comp1 is not None or comp2 is not None:
        f32z = lambda t: jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), t)
        comm = HierCommState(
            resid1=(f32z(stacked) if comp1 and comp1.error_feedback
                    else ()),
            ref1=(jax.tree.map(lambda x: jnp.broadcast_to(
                x.astype(jnp.float32), (p, 1, *x.shape)).copy(), params)
                if comp1 else ()),
            resid2=(jax.tree.map(lambda x: jnp.zeros(
                (p, 1, *x.shape), jnp.float32), params)
                if comp2 and comp2.error_feedback else ()),
            ref2=(jax.tree.map(lambda x: x.astype(jnp.float32), params)
                  if comp2 else ()))
    return HierState(params=stacked, delta1=jax.tree.map(z, stacked),
                     delta2=d2, inner=inner,
                     step=jnp.zeros((), jnp.int32),
                     last_sync1=jnp.zeros((), jnp.int32),
                     last_sync2=jnp.zeros((), jnp.int32), comm=comm)


def ref_hier_local_step(cfg: VRLConfig, state: HierState,
                        grads: Any) -> HierState:
    """x ← inner_opt(x, g − Δ1 − Δ2): zero cross-worker communication."""
    v = jax.tree.map(
        lambda g, d1, d2: g - d1.astype(g.dtype) - d2.astype(g.dtype),
        grads, state.delta1, state.delta2)
    new_params, new_inner = make_inner(cfg).update(state.params, v,
                                                  state.inner)
    return state._replace(params=new_params, inner=new_inner,
                          step=state.step + 1)


def ref_hier_sync1(cfg: VRLConfig, state: HierState) -> HierState:
    """Intra-pod sync: mean over axis 1 (the pod-internal worker axis)."""
    k_eff = jnp.maximum(state.step - state.last_sync1, 1).astype(jnp.float32)
    comp1, _ = comm_mod.resolve_pair(cfg)
    new_comm = state.comm
    if comp1 is not None:
        e = state.comm.resid1 if comp1.error_feedback else None
        payload = _ref_payload(state.params, state.comm.ref1, e)
        dec, res = _leaf_rt(comp1, payload, 2)
        xbar = jax.tree.map(
            lambda r, d: r + jnp.mean(d, axis=1, keepdims=True),
            state.comm.ref1, dec)
        new_comm = state.comm._replace(
            ref1=xbar, resid1=(res if comp1.error_feedback else ()))
    else:
        xbar = jax.tree.map(lambda x: jnp.mean(x, axis=1, keepdims=True),
                            state.params)

    def upd(d, x, xb):
        return (d.astype(jnp.float32)
                + (xb.astype(jnp.float32) - x.astype(jnp.float32))
                / (k_eff * cfg.learning_rate)).astype(d.dtype)

    new_d1 = jax.tree.map(upd, state.delta1, state.params, xbar)
    new_p = jax.tree.map(
        lambda x, xb: jnp.broadcast_to(xb, x.shape).astype(x.dtype),
        state.params, xbar)
    return state._replace(params=new_p, delta1=new_d1,
                          last_sync1=state.step, comm=new_comm)


def ref_hier_sync2(cfg: VRLConfig, state: HierState) -> HierState:
    """Cross-pod sync. Assumes a level-1 sync at the same step (so every
    worker already holds its pod average)."""
    k_eff = jnp.maximum(state.step - state.last_sync2, 1).astype(jnp.float32)
    comp1, comp2 = comm_mod.resolve_pair(cfg)
    new_comm = state.comm
    pod_avg = jax.tree.map(lambda x: jnp.mean(x, axis=1, keepdims=True),
                           state.params)
    if comp2 is not None:
        e = state.comm.resid2 if comp2.error_feedback else None
        payload = _ref_payload(pod_avg, state.comm.ref2, e)
        dec, res = _leaf_rt(comp2, payload, 2)
        glob_sm = jax.tree.map(lambda r, d: r + jnp.mean(d, axis=(0, 1)),
                               state.comm.ref2, dec)
        glob = jax.tree.map(lambda x: x[None, None], glob_sm)
        new_comm = new_comm._replace(
            ref2=glob_sm, resid2=(res if comp2.error_feedback else ()))
    else:
        glob = jax.tree.map(lambda x: jnp.mean(x, axis=(0, 1),
                                               keepdims=True), state.params)
    if comp1 is not None:
        # level-2 just moved every worker to x̂: re-anchor the level-1
        # drift reference so the next intra-pod payload is small again
        new_comm = new_comm._replace(ref1=jax.tree.map(
            lambda g, r1: jnp.broadcast_to(g.astype(jnp.float32), r1.shape),
            glob, new_comm.ref1))

    def upd(d2, pa, g):
        return (d2.astype(jnp.float32)
                + (g.astype(jnp.float32) - pa.astype(jnp.float32))
                / (k_eff * cfg.learning_rate)).astype(d2.dtype)

    new_d2 = jax.tree.map(upd, state.delta2, pod_avg, glob)
    new_p = jax.tree.map(
        lambda x, g: jnp.broadcast_to(g, x.shape).astype(x.dtype),
        state.params, glob)
    return state._replace(params=new_p, delta2=new_d2,
                          last_sync2=state.step, comm=new_comm)


def ref_hier_train_step(cfg: VRLConfig, state: HierState, grads: Any, *,
                        k1: Optional[int] = None,
                        k2: Optional[int] = None) -> HierState:
    hcfg = hier_config(cfg)
    k1 = hcfg.k1 if k1 is None else k1
    k2 = hcfg.k2 if k2 is None else k2
    state = ref_hier_local_step(cfg, state, grads)
    do1 = (state.step - state.last_sync1) >= k1
    do2 = (state.step - state.last_sync2) >= k2
    state = jax.lax.cond(do1 | do2, lambda s: ref_hier_sync1(cfg, s),
                         lambda s: s, state)
    return jax.lax.cond(do2, lambda s: ref_hier_sync2(cfg, s),
                        lambda s: s, state)


def hier_average_model(state: HierState) -> Any:
    """x̂ — the evaluation model, averaged over the whole (P, D) grid."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=(0, 1)), state.params)


# ============================================================ fused executor
class FlatWorkerState(NamedTuple):
    """Worker-stacked algorithm state as contiguous flat buffers.

    ``params``/``delta``/moments: (W, R, C); ``center``: (R, C) fp32
    (EASGD only); Δ is () for algorithms that never use it, as is ``bias``
    (BVR-L-SGD's (W, R, C) variate B) for every other algorithm.  The
    unravel spec (``flat.FlatSpec``) lives on the Engine, not in the state
    — it is static layout, checkpointed as metadata
    (``checkpoint.save_flat_state``).
    """

    params: jax.Array
    delta: Any
    inner: Any
    center: Any
    step: jax.Array
    last_sync: jax.Array
    bias: Any = ()
    comm: Any = ()              # compressed-sync CommState: resid (W, R, C)
                                # fp32, ref (R, C) fp32 — () uncompressed
    overlap: Any = ()           # overlapped-round OverlapState: pend
                                # (W, R, C) fp32, pend_k (W, 1, 1) fp32 —
                                # () when cfg.overlap is off
    member: Any = ()            # elastic-membership MemberState: active
                                # (W, 1, 1) fp32 mask + n_active () fp32 —
                                # () when cfg.membership is off


class HierFlatState(NamedTuple):
    """Two-level algorithm state as pod-major contiguous flat buffers.

    ``params``/``delta1``/moments: (P, D, R, C); ``delta2``: (P, 1, R, C) —
    one shared cross-pod correction per pod, broadcast over the intra-pod
    axis by kernel index maps rather than materialized.  Invariants tested
    on this layout: Σ_d Δ1[p, d] = 0 within every pod after a level-1 sync,
    Σ_p Δ2[p] = 0 after a level-2 sync.
    """

    params: jax.Array
    delta1: jax.Array
    delta2: jax.Array
    inner: Any
    step: jax.Array
    last_sync1: jax.Array
    last_sync2: jax.Array
    comm: Any = ()              # per-level HierCommState: resid1
                                # (P, D, R, C), ref1 (P, 1, R, C), resid2
                                # (P, 1, R, C), ref2 (R, C) — () uncompressed
    overlap: Any = ()           # overlapped level-2 OverlapState: pend
                                # (P, 1, R, C) fp32, pend_k (P, 1, 1, 1)
                                # fp32 — () when cfg.overlap is off
    member: Any = ()            # elastic-membership MemberState: active
                                # (P, D, 1, 1) fp32, n_pod (P, 1, 1, 1)
                                # per-pod counts, n_active () = alive pods
                                # — () when cfg.membership is off


class Engine(NamedTuple):
    """Bound flat-buffer-executor closures for one (algorithm, model) pair."""

    algorithm: str
    spec: flat.FlatSpec
    algo: AlgoSpec
    init: Callable              # (params_tree, num_workers) -> state
    train_step: Callable        # (state, grads_tree) -> state
    local_step: Callable        # (state, grads_tree) -> state
    sync: Callable              # (state,) -> state (hier: level-1 + level-2)
    average_model: Callable     # (state,) -> single-model pytree
    params_tree: Callable       # (state,) -> worker-stacked params pytree
    sync1: Any = None           # hier only: intra-pod sync alone
    sync2: Any = None           # hier only: cross-pod sync alone
    grid: Any = None            # hier only: the (P, D) worker grid
    round_step: Any = None      # (state, grads_k) -> state: k scanned local
                                # steps + round_end, one compilation unit
    round_end: Any = None       # (state,) -> state: the round-closing sync
                                # (hier: sync1 + conditional k2-cadence sync2)
    round_step_flat: Any = None  # (state, gk_buf) -> state: round over a
                                 # pre-flattened (k, W/grid, R, C) buffer
    round_begin: Any = None     # overlap only: (state, k) -> x̂_stale, the
                                # round-START sync collective (flat engines
                                # ignore k; hier needs it for the k2
                                # cadence).  None when overlap is off —
                                # callers dispatch on that.
    round_fold: Any = None      # overlap only: (state, x̂_stale) -> state,
                                # the round-END stale fold (hier: blocking
                                # sync1 + conditional level-2 fold)
    backend: str = "fused"      # resolved executor: "fused" | "xla"
    compressors: Any = (None, None)  # resolved (level-1, level-2)
                                     # CompressorSpecs (None = identity)
    set_membership: Any = None  # membership only: (state, (W,) mask) ->
                                # state — the invariant-preserving repair
                                # for a changed active set (jit it with
                                # donate_argnums=(0,); NOT part of the
                                # compiled round).  None when
                                # cfg.membership is off.
    recenter_drift: Any = None  # client sampling: (state,) -> state —
                                # re-zero Σ Δ (and Σ B) over the worker
                                # rows currently loaded in the buffers.  A
                                # sampled cohort's corrections sum to the
                                # cohort mean, not zero (Σ_i Δ_i = 0 holds
                                # over ALL M clients, not over W of them);
                                # run this after a cohort gather, BEFORE
                                # the round, whenever the cohort is a
                                # strict subset.  jit with
                                # donate_argnums=(0,); None on the
                                # hierarchical engine (client sampling is
                                # a flat-engine construct).
    diagnostics: Any = None     # observability: (state,) -> dict of
                                # algorithm-health scalars (drift
                                # dispersion, Δ-dispersion ζ² proxy,
                                # Σ Δ / Σ B invariant residuals, EF and
                                # moment norms, non-finite worker count).
                                # READ-ONLY — its own jit, never part of
                                # the compiled round, so the round's
                                # one-sync-all-reduce HLO contract is
                                # untouched; it may spend a few extra
                                # collectives, which is fine at
                                # --log-every cadence.  None on the
                                # reference backend.


class RoundCache:
    """Per-k cache of compiled round executables.

    A stagewise ``CommSchedule`` changes the round length k between stages.
    Each distinct k is a distinct input shape, so it is its own compilation
    of ``round_step`` — this cache keys one jitted executable per k (state
    donated), so a stagewise run compiles at most ``len(stages)`` round
    executables and every later round of the same k reuses its executable
    (asserted in ``tests/test_round_scan.py``).

    Works over any round callable whose extra operands carry k on their
    leading axis: ``Engine.round_step`` / ``round_step_flat`` (grads
    stacks) and ``StepBundle.round_step`` (token/label stacks).

    ``compiles`` counts actual traces (incremented at trace time), so a
    retrace of an existing k — which would break the "one executable per
    stage" contract — is visible too.
    """

    def __init__(self, round_step: Callable, *, donate: bool = True):
        self._round = round_step
        self._donate = (0,) if donate else ()
        self._jits: dict = {}
        self.compiles = 0

    @staticmethod
    def round_k(*stacks) -> int:
        return int(jax.tree.leaves(stacks[0])[0].shape[0])

    def __call__(self, state, *stacks):
        k = self.round_k(*stacks)
        fn = self._jits.get(k)
        if fn is None:
            def traced(s, *rest):
                self.compiles += 1      # runs at trace time only
                return self._round(s, *rest)

            fn = jax.jit(traced, donate_argnums=self._donate)
            self._jits[k] = fn
        return fn(state, *stacks)

    @property
    def cached_ks(self) -> Tuple[int, ...]:
        return tuple(sorted(self._jits))


def _ef_op(ops, comp: comm_mod.CompressorSpec, lanes: int, *, grid: bool,
           block: int, interpret):
    """Bind the executor module's EF round-trip for one compressor:
    (payload_buf, ref, resid) -> (decompressed fp32, resid')."""
    name = {"int8": "fused_ef_int8", "topk": "fused_ef_topk"}[comp.name]
    if grid:
        name += "_grid"
    kwargs = dict(block=block, interpret=interpret)
    if comp.name == "topk":
        kwargs["k"] = comm_mod.topk_k(comp, lanes)
    return functools.partial(getattr(ops, name), **kwargs)


def _validate_overlap(cfg: VRLConfig, algo: AlgoSpec, comp_overlapped):
    """Reject config combinations the overlapped round cannot honor.
    ``comp_overlapped`` is the compressor of the sync the overlap defers
    (flat: ``compress``; hierarchical: the level-2 ``compress2``)."""
    if not cfg.overlap:
        if cfg.deadline:
            raise ValueError(
                "deadline is a property of the overlapped round; set "
                "overlap=True (--overlap) to use it")
        return
    if algo.sync in ("none", "elastic"):
        raise ValueError(
            f"overlap defers a mean-style round-closing sync; "
            f"{algo.name!r} (sync={algo.sync!r}) has none to defer")
    if not 0.0 <= cfg.deadline <= 1.0:
        raise ValueError(
            f"deadline is a per-round miss probability in [0, 1]; got "
            f"{cfg.deadline}")
    if (cfg.deadline and comp_overlapped is not None
            and not comp_overlapped.error_feedback):
        raise ValueError(
            "deadline misses park the skipped payload in the EF residual; "
            "the overlapped sync's compressor needs error_feedback=True")


def _validate_membership(cfg: VRLConfig, algo: AlgoSpec):
    if not getattr(cfg, "membership", False):
        return
    if algo.sync == "elastic":
        raise ValueError(
            "membership composes with mean-style syncs; easgd's center "
            f"update assumes a fixed worker count — {algo.name!r} cannot "
            "run with membership=True")


# Adam moment/bias-correction bases.  Must equal optimizers.adam's defaults
# (the reference executor) — the kernel gets these explicitly so the moment
# update and the bias correction can never use different betas.
_ADAM_B1, _ADAM_B2 = 0.9, 0.999


def _inner_kind(cfg: VRLConfig) -> Tuple[str, float]:
    """Mirror optimizers.make_inner dispatch for the fused kernels."""
    if cfg.inner_optimizer == "sgd":
        if cfg.momentum:
            return "momentum", cfg.momentum
        return "sgd", 0.0
    if cfg.inner_optimizer == "momentum":
        return "momentum", cfg.momentum or 0.9
    if cfg.inner_optimizer == "adam":
        return "adam", 0.0
    raise ValueError(cfg.inner_optimizer)


_MOMENT_DTYPES = ("float32", "bfloat16")


def _moment_opts(cfg: VRLConfig, kind: str):
    """Resolve (moment storage dtype, SM3 active) for the fused engine.

    The kernels compute fp32 in-register regardless; ``moment_dtype``
    only picks what persists between steps, so "float32" is bitwise the
    original path.  SM3 factors Adam's second moment only — sgd/momentum
    configs carry no nu, so the flag is inert there (same as the
    reference ``optimizers.adam``)."""
    name = getattr(cfg, "moment_dtype", "float32")
    if name not in _MOMENT_DTYPES:
        raise ValueError(f"unknown moment_dtype {name!r}; known: "
                         f"{_MOMENT_DTYPES}")
    sm3 = bool(getattr(cfg, "sm3", False)) and kind == "adam"
    return jnp.dtype(name), sm3


def _resolve_shard_axis(ecfg, mesh) -> Optional[str]:
    """The mesh axis the row dim splits over, or None.

    ``EngineConfig.shards > 1`` with a mesh carrying ``shard_axis`` at
    matching size activates real placement; without a mesh (or without
    the axis) the sharded row padding is layout-only — buffers stay
    device-local but hold the identical values, which is what the CPU
    parity tests exercise.  A size mismatch is a config error, loudly.
    """
    if mesh is None or ecfg.shards <= 1:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    sz = sizes.get(ecfg.shard_axis, 1)
    if sz == 1:
        return None
    if sz != ecfg.shards:
        raise ValueError(
            f"mesh axis {ecfg.shard_axis!r} has size {sz} but "
            f"EngineConfig.shards={ecfg.shards}; the row dim splits into "
            f"exactly one block-aligned piece per shard device")
    return ecfg.shard_axis


def _row_axis(shard_axis, shards: int):
    """Per-leaf model-shard placement rule: the row dim (-2) splits over
    ``shard_axis`` iff its extent divides into ``shards`` whole pieces and
    is not a broadcast dim of 1.  Every flat buffer's rows are padded to a
    multiple of ``block * shards`` (``flat.make_spec``), the SM3 lane stat
    carries exactly one row per shard, and size-1 dims (pend_k, Δ2's
    intra-pod dim) fall through to replicated — so one rule covers the
    whole state."""
    def row_ax(x):
        shape = tuple(getattr(x, "shape", ()))
        if (shard_axis is not None and shards > 1 and len(shape) >= 2
                and shape[-2] > 1 and shape[-2] % shards == 0):
            return shard_axis
        return None

    return row_ax


def _state_pspecs(state, axes, shard_axis=None, shards: int = 1) -> Any:
    """shard_map PartitionSpecs: worker-stacked (ndim 3) leaves shard over
    the worker axes, (R, C) leaves (center, comm ref) and every row dim
    over the model-shard axis when one is active; scalars replicate."""
    ax = tuple(axes) if isinstance(axes, (tuple, list)) else (axes,)
    ax = ax[0] if len(ax) == 1 else ax
    row_ax = _row_axis(shard_axis, shards)

    def one(x):
        nd = getattr(x, "ndim", 0)
        if nd == 3:
            return P(ax, row_ax(x), None)
        if nd == 2:
            return P(row_ax(x), None)
        return P(*([None] * nd))

    return jax.tree.map(one, state)


def _hier_pspecs(state: HierFlatState, pod_axis, data_axis,
                 shard_axis=None, shards: int = 1) -> HierFlatState:
    """PartitionSpecs for the pod-major state: (P, D, R, C) leaves shard
    (pod, data); the per-pod Δ2 shards only the pod axis (its intra-pod dim
    is 1); scalars replicate; row dims additionally split over the
    model-shard axis when one is active (``_row_axis``).  Compressed-sync
    buffers follow their level: per-worker residuals shard like params,
    per-pod ref1/resid2 like Δ2, the global ref2 replicates over workers
    (but shards its rows)."""
    row_ax = _row_axis(shard_axis, shards)
    wspec = lambda x: P(pod_axis, data_axis, row_ax(x), None)
    podspec = lambda x: P(pod_axis, None, row_ax(x), None)
    inner = jax.tree.map(
        lambda x: wspec(x) if getattr(x, "ndim", 0) == 4 else P(),
        state.inner)
    comm = state.comm
    cspec = ()
    if isinstance(comm, HierCommState):
        have = lambda x, f: () if isinstance(x, tuple) else f(x)
        cspec = HierCommState(resid1=have(comm.resid1, wspec),
                              ref1=have(comm.ref1, podspec),
                              resid2=have(comm.resid2, podspec),
                              ref2=have(comm.ref2,
                                        lambda x: P(row_ax(x), None)))
    ospec = ()
    if isinstance(state.overlap, OverlapState):
        # level-2 overlap buffers are per-pod (P, 1, ...): pod axis only
        ospec = OverlapState(pend=podspec(state.overlap.pend),
                             pend_k=podspec(state.overlap.pend_k))
    mspec = ()
    if isinstance(state.member, MemberState):
        mspec = MemberState(active=wspec(state.member.active),
                            n_active=P(),
                            n_pod=podspec(state.member.n_pod))
    return HierFlatState(params=wspec(state.params),
                         delta1=wspec(state.delta1),
                         delta2=podspec(state.delta2), inner=inner,
                         step=P(), last_sync1=P(), last_sync2=P(),
                         comm=cspec, overlap=ospec, member=mspec)


def state_partition_specs(state, worker_axes,
                          hier_axes: Tuple[str, str] = ("pod", "data"),
                          shard_axis=None, shards: int = 1):
    """PartitionSpec pytree for a fused-engine state (flat or hierarchical).

    The launch layer (``launch/dryrun.py``) and the HLO-collective tests use
    this to place engine states on the production mesh: flat (W, R, C)
    buffers shard their worker axis over ``worker_axes``; hierarchical
    (P, D, R, C) buffers shard pod-major over ``hier_axes``; with
    ``shard_axis``/``shards`` set, every buffer's row dim additionally
    splits over the model-shard axis (FSDP over the flat layout).
    """
    if isinstance(state, HierFlatState):
        return _hier_pspecs(state, *hier_axes, shard_axis=shard_axis,
                            shards=shards)
    return _state_pspecs(state, worker_axes, shard_axis=shard_axis,
                         shards=shards)


def make_engine(cfg: VRLConfig, template: Any, *, mesh=None,
                worker_axes: Tuple[str, ...] = ("data",)) -> Engine:
    """Build the fused engine for ``cfg.algorithm`` over ``template`` (a
    single-model pytree of arrays or ShapeDtypeStructs).

    ``mesh``: optional jax Mesh.  When given (and the worker axes span more
    than one device) the step functions run under ``shard_map`` over
    ``worker_axes`` and the sync's model average is a single ``psum`` of the
    flat buffer; otherwise the worker axis is purely local and the average
    is a ``jnp.mean`` (the single-device fallback).
    """
    algo = get_spec(cfg.algorithm)
    ecfg = cfg.engine
    fspec = flat.make_spec(template, lanes=ecfg.lanes, block=ecfg.block,
                           max_waste=ecfg.max_pad_waste, shards=ecfg.shards)
    interpret = (vu.default_interpret() if ecfg.interpret is None
                 else ecfg.interpret)
    backend = resolve_backend(cfg)
    if backend == "reference":
        raise ValueError("make_engine builds the flat-buffer executors; "
                         "the reference tree path lives in train_loop "
                         "(update_backend='reference')")
    if cfg.update_backend == "fused" and interpret:
        warnings.warn(
            f"update_backend='fused' runs interpret-mode Pallas on the "
            f"{jax.default_backend()!r} backend (orders of magnitude "
            f"slower); use update_backend='auto' to get the XLA executor "
            f"here", stacklevel=2)
    ops = vu if backend == "fused" else xu
    block = fspec.block
    kind, beta = _inner_kind(cfg)
    mdt, sm3 = _moment_opts(cfg, kind)
    lr, wd = cfg.learning_rate, cfg.weight_decay
    delta_dt = jnp.dtype(cfg.delta_dtype)
    comp, _comp2 = comm_mod.resolve_pair(cfg)
    _validate_overlap(cfg, algo, _comp2 if algo.sync == "vrl2" else comp)
    _validate_membership(cfg, algo)
    member_on = bool(getattr(cfg, "membership", False))

    if algo.sync == "vrl2":
        return _make_hier_engine(cfg, algo, fspec, mesh=mesh, ops=ops,
                                 backend=backend, kind=kind,
                                 beta=beta, lr=lr, wd=wd, delta_dt=delta_dt,
                                 block=block, interpret=interpret,
                                 mdt=mdt, sm3=sm3)

    axis_names = None
    axis_size = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        axis_size = math.prod(sizes[a] for a in worker_axes)
        if axis_size > 1:
            axis_names = tuple(worker_axes)
    shard_axis = _resolve_shard_axis(ecfg, mesh)
    on_mesh = axis_names is not None or shard_axis is not None

    def _wmean(buf, member=()):
        """Global worker mean of a (W_local, R, C) buffer -> (R, C).

        On the mesh this is THE communication event: one all-reduce over
        the flat buffer.  With a ``MemberState`` the mean runs over ACTIVE
        workers only: dead rows are excluded with a ``where`` (a multiply
        would propagate a crashed worker's NaNs as ``NaN * 0``) and the
        divisor is the state-carried active count — still the same single
        all-reduce, and bitwise the unmasked mean at a full mask."""
        if isinstance(member, MemberState):
            s = jnp.sum(jnp.where(member.active > 0, buf, 0), axis=0)
            if axis_names is not None:
                s = jax.lax.psum(s, axis_names)
            # Multiply by the reciprocal rather than divide: XLA folds the
            # unmasked ``sum / W`` into ``sum * (1/W)``, and bitwise parity
            # of the full-mask program requires the same op sequence here
            # (a runtime divide rounds differently once fused downstream).
            return s * (1.0 / member.n_active)
        if axis_names is None:
            return jnp.mean(buf, axis=0)
        total = buf.shape[0] * axis_size
        s = jax.lax.psum(jnp.sum(buf, axis=0), axis_names)
        return s / total

    # ------------------------------------------------------------- init
    bias_on = use_bias(algo, cfg)
    ef_rt = (None if comp is None else
             _ef_op(ops, comp, fspec.lanes, grid=False, block=block,
                    interpret=interpret))

    def init(params: Any, num_workers: int) -> FlatWorkerState:
        flat1 = flat.flatten_tree(fspec, params)
        stacked = jnp.broadcast_to(flat1, (num_workers, *flat1.shape)).copy()
        delta = (jnp.zeros(stacked.shape, delta_dt) if algo.use_delta else ())
        bias = jnp.zeros(stacked.shape, delta_dt) if bias_on else ()
        if kind == "sgd":
            inner = ()
        elif kind == "momentum":
            inner = jnp.zeros(stacked.shape, mdt)
        elif sm3:
            # factored nu: a (W, R, 1) row stat + a (W, S, C) lane stat
            # (one lane row per model shard's row span) replace the dense
            # (W, R, C) buffer — ~R·C/(R + S·C) times smaller
            nu = SM3Pair(
                row=jnp.zeros((num_workers, fspec.rows, 1), jnp.float32),
                col=jnp.zeros((num_workers, fspec.shards, fspec.lanes),
                              jnp.float32))
            inner = AdamState(jnp.zeros(stacked.shape, mdt), nu,
                              jnp.zeros((), jnp.int32))
        else:
            inner = AdamState(jnp.zeros(stacked.shape, mdt),
                              jnp.zeros(stacked.shape, mdt),
                              jnp.zeros((), jnp.int32))
        center = flat1.astype(jnp.float32) if algo.has_center else None
        comm = ()
        if comp is not None:
            # fp32 residuals keep the EF invariant exact; ref is the shared
            # post-sync value ((R, C)) — () for S-SGD gradient compression
            resid = (jnp.zeros(stacked.shape, jnp.float32)
                     if comp.error_feedback else ())
            ref = (() if (algo.grad_all_reduce or algo.sync == "none")
                   else flat1.astype(jnp.float32))
            comm = CommState(resid=resid, ref=ref)
        overlap = ()
        if cfg.overlap:
            # pend = the initial broadcast position (everyone "transmitted"
            # x0 before step 0), so the first fold's correction is exactly
            # zero; pend_k = 1 keeps its Δ scale finite
            overlap = OverlapState(
                pend=stacked.astype(jnp.float32).copy(),
                pend_k=jnp.ones((num_workers, 1, 1), jnp.float32))
        member = ()
        if member_on:
            # everyone starts active; the count rides in state so the
            # masked means never need a second collective
            member = MemberState(
                active=jnp.ones((num_workers, 1, 1), jnp.float32),
                n_active=jnp.asarray(float(num_workers), jnp.float32))
        return FlatWorkerState(params=stacked, delta=delta, inner=inner,
                               center=center,
                               step=jnp.zeros((), jnp.int32),
                               last_sync=jnp.zeros((), jnp.int32),
                               bias=bias, comm=comm, overlap=overlap,
                               member=member)

    # ------------------------------------------------- core step functions
    # These see LOCAL shards (W_local, R, C) when shard_mapped.
    def _core_local(state: FlatWorkerState, g: jax.Array) -> FlatWorkerState:
        if algo.grad_all_reduce:
            if comp is not None:
                # S-SGD: the per-step gradient IS the payload (ref ≡ 0)
                e = state.comm.resid if comp.error_feedback else None
                dec, e_out = ef_rt(g, None, e)
                g = jnp.broadcast_to(_wmean(dec, state.member)[None],
                                     g.shape)
                if comp.error_feedback:
                    state = state._replace(
                        comm=state.comm._replace(resid=e_out))
            else:
                g = jnp.broadcast_to(_wmean(g, state.member)[None], g.shape)
        d = state.delta if algo.use_delta else None
        b = state.bias if bias_on else None
        if kind == "sgd":
            new_p = ops.fused_local_sgd(state.params, g, d, b=b, lr=lr,
                                        wd=wd, block=block,
                                        interpret=interpret)
            new_inner = state.inner
        elif kind == "momentum":
            new_p, new_m = ops.fused_local_momentum(
                state.params, g, d, state.inner, b=b, lr=lr, beta=beta,
                wd=wd, block=block, interpret=interpret)
            new_inner = new_m
        else:
            count = state.inner.count + 1
            t = count.astype(jnp.float32)
            scal = jnp.stack([1.0 - _ADAM_B1 ** t, 1.0 - _ADAM_B2 ** t]
                             ).reshape(1, 2).astype(jnp.float32)
            if sm3:
                new_p, new_mu, new_row, new_col = ops.fused_local_adam_sm3(
                    state.params, g, d, state.inner.mu,
                    state.inner.nu.row, state.inner.nu.col, scal, b=b,
                    lr=lr, b1=_ADAM_B1, b2=_ADAM_B2, wd=wd, block=block,
                    interpret=interpret)
                new_inner = AdamState(new_mu, SM3Pair(new_row, new_col),
                                      count)
            else:
                new_p, new_mu, new_nu = ops.fused_local_adam(
                    state.params, g, d, state.inner.mu, state.inner.nu,
                    scal, b=b, lr=lr, b1=_ADAM_B1, b2=_ADAM_B2, wd=wd,
                    block=block, interpret=interpret)
                new_inner = AdamState(new_mu, new_nu, count)
        out = state._replace(params=new_p, inner=new_inner,
                             step=state.step + 1)
        if algo.grad_all_reduce:
            out = out._replace(last_sync=state.step + 1)
        return out

    def _comp_mean(state: FlatWorkerState):
        """Compressed-drift worker mean: one fused EF round-trip pass
        (payload = p − ref + resid → decompressed + residual', residual
        donated), then the SAME single flat all-reduce — over the
        decompressed drift.  ref is shared across workers, so
        mean_i(p_i) = ref + mean_i(p_i − ref) exactly."""
        cm = state.comm
        e = cm.resid if comp.error_feedback else None
        dec, e_out = ef_rt(state.params, cm.ref, e)
        xbar = cm.ref + _wmean(dec, state.member)
        cm = CommState(resid=(e_out if comp.error_feedback else ()),
                       ref=xbar)
        return xbar, state._replace(comm=cm)

    def _core_sync(state: FlatWorkerState) -> FlatWorkerState:
        if algo.sync == "none":
            return state._replace(last_sync=state.step)
        if algo.sync == "elastic":
            n = state.params.shape[0] * axis_size
            a = cfg.easgd_alpha / n
            if comp is not None:
                xbar, state = _comp_mean(state)
            else:
                xbar = _wmean(state.params.astype(jnp.float32))
            new_p, new_c = ops.fused_sync_easgd(
                state.params, xbar, state.center, a=a, na=n * a,
                block=block, interpret=interpret)
            return state._replace(params=new_p, center=new_c,
                                  last_sync=state.step)
        if comp is not None:
            xbar, state = _comp_mean(state)
        else:
            xbar = _wmean(state.params, state.member)
        if algo.sync == "average":
            new_p = jnp.broadcast_to(xbar[None], state.params.shape
                                     ).astype(state.params.dtype)
            return state._replace(params=new_p, last_sync=state.step)
        # "vrl"/"bvr": fused Δ (+ B) update + parameter broadcast, one pass
        k_eff = jnp.maximum(state.step - state.last_sync, 1
                            ).astype(jnp.float32)
        scal = (k_eff * lr).reshape(1, 1).astype(jnp.float32)
        if algo.sync == "bvr" and bias_on:
            new_p, new_d, new_b = ops.fused_sync_bvr(
                state.params, xbar.astype(state.params.dtype), state.delta,
                state.bias, scal, beta=cfg.bvr_beta, block=block,
                interpret=interpret)
            return state._replace(params=new_p, delta=new_d, bias=new_b,
                                  last_sync=state.step)
        new_p, new_d = ops.fused_sync_vrl(
            state.params, xbar.astype(state.params.dtype), state.delta,
            scal, block=block, interpret=interpret)
        return state._replace(params=new_p, delta=new_d,
                              last_sync=state.step)

    def _core_train(state: FlatWorkerState, g: jax.Array) -> FlatWorkerState:
        state = _core_local(state, g)
        if algo.sync == "none":
            return state
        return jax.lax.cond(
            should_sync(algo, cfg, state.step, state.last_sync),
            _core_sync, lambda s: s, state)

    def _core_round(state: FlatWorkerState, gk: jax.Array) -> FlatWorkerState:
        """k local steps under one scan over (k, W, R, C) grads, then the
        round-closing sync.  The round IS the communication period — the
        caller sizes gk (warmup's first k=1 period is a 1-step round)."""
        state, _ = jax.lax.scan(lambda s, g: (_core_local(s, g), None),
                                state, gk)
        return _core_sync(state)

    # ------------------------------------------------- overlapped round
    def _miss_mask(step: jax.Array, n: int) -> jax.Array:
        """Per-participant (n, 1) deadline-miss mask for the round ending
        at ``step``: 1 ⇒ the participant missed its capture deadline
        (simulated per participant per round — a single-host SPMD run has
        no real per-worker clock).  deadline=0 short-circuits to a
        constant at trace time, so the no-deadline program is bitwise
        identical."""
        if not cfg.deadline:
            return jnp.zeros((n, 1), jnp.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        if axis_names is not None:
            for a in axis_names:
                key = jax.random.fold_in(key, jax.lax.axis_index(a))
        u = jax.random.uniform(key, (n, 1))
        return (u < cfg.deadline).astype(jnp.float32)

    def _fold_overlap(state: FlatWorkerState, xbar: jax.Array
                      ) -> FlatWorkerState:
        """Apply the round-START collective's (one-round-stale) mean at
        round end: fold c = x̂_stale − pend into params/Δ (+B), then
        capture the new positions for the NEXT round's collective."""
        ov = state.overlap
        k_eff = jnp.maximum(state.step - state.last_sync, 1
                            ).astype(jnp.float32)
        km = _miss_mask(state.step, ov.pend.shape[0])          # (W_l, 1)
        inv = 1.0 / (ov.pend_k[:, :, 0] * lr)                  # (W_l, 1)
        wscal = jnp.concatenate([inv, km], axis=1).astype(jnp.float32)
        km3 = km[:, :, None]
        # a missed capture keeps pend and stretches the period it covers
        new_pend_k = km3 * (ov.pend_k + k_eff) + (1.0 - km3) * k_eff
        capture = comp is None
        xb = xbar.astype(state.params.dtype)
        if algo.sync == "average":
            out = ops.fused_fold_overlap_avg(
                state.params, xb, ov.pend, wscal, capture=capture,
                block=block, interpret=interpret)
            state = state._replace(params=out[0])
            new_pend = out[1] if capture else None
        elif algo.sync == "bvr" and bias_on:
            out = ops.fused_fold_overlap_bvr(
                state.params, xb, ov.pend, state.delta, state.bias,
                wscal, beta=cfg.bvr_beta, capture=capture, block=block,
                interpret=interpret)
            state = state._replace(params=out[0], delta=out[1],
                                   bias=out[2])
            new_pend = out[3] if capture else None
        else:
            out = ops.fused_fold_overlap(
                state.params, xb, ov.pend, state.delta, wscal,
                capture=capture, block=block, interpret=interpret)
            state = state._replace(params=out[0], delta=out[1])
            new_pend = out[2] if capture else None
        if comp is not None:
            # compressed capture: transmit the folded position's drift
            # against the stale mean through the EF round-trip; a missed
            # deadline returns the whole decompressed payload to the
            # residual (the worker never actually transmitted it)
            cm = state.comm
            e = cm.resid if comp.error_feedback else None
            dec, e_out = ef_rt(state.params, xbar, e)
            sent = xbar[None] + dec            # (W_l, R, C) absolute pos
            new_pend = km3 * ov.pend + (1.0 - km3) * sent
            resid = (e_out + km3 * dec if comp.error_feedback else ())
            state = state._replace(comm=CommState(resid=resid, ref=xbar))
        return state._replace(overlap=OverlapState(new_pend, new_pend_k),
                              last_sync=state.step)

    def _core_round_begin(state: FlatWorkerState) -> jax.Array:
        # masked: a dead worker's pend is retired from the collective
        # (not retransmitted forever) until it rejoins with a fresh one
        return _wmean(state.overlap.pend, state.member)

    def _core_round_overlap(state: FlatWorkerState, gk: jax.Array
                            ) -> FlatWorkerState:
        """Overlapped round: THE sync all-reduce is issued FIRST, over the
        previous boundary's transmitted positions — its operands are ready
        before the scan starts, so the scheduler can run the collective
        concurrently with the k local steps — and its stale result is
        folded in at the end.  Still one sync all-reduce per k steps."""
        xbar = _core_round_begin(state)
        state, _ = jax.lax.scan(lambda s, g: (_core_local(s, g), None),
                                state, gk)
        return _fold_overlap(state, xbar)

    # --------------------------------------------- membership repair
    def _core_set_membership(state: FlatWorkerState, new_active: jax.Array
                             ) -> FlatWorkerState:
        """Repair the state invariants for a changed active set.

        Mask-value-driven (the mask is an operand, not a trace constant),
        so one jit covers every drop/rejoin pattern.  Continuing workers:
        Δ (and B) recentred to mean zero over the continuing set —
        algebraically identical to redistributing each dropped worker's Δ
        across the survivors (Σ_cont Δ = −Σ_dropped Δ before the repair),
        but computed without ever reading a dropped row, so a crashed
        worker's NaNs cannot leak.  Dropped + rejoining workers: params
        (and overlap pend) re-seeded from the continuing consensus x̂;
        Δ/B/moments/EF residuals zeroed."""
        def _gsum(x):
            s = jnp.sum(x, axis=0)
            if axis_names is not None:
                s = jax.lax.psum(s, axis_names)
            return s

        old = state.member.active                          # (W_l, 1, 1)
        cont = old * new_active
        keep = cont > 0
        n_cont = jnp.maximum(jnp.sum(_gsum(cont)), 1.0)
        n_new = jnp.sum(_gsum(new_active))
        xhat = _gsum(jnp.where(keep, state.params.astype(jnp.float32), 0.0)
                     ) / n_cont                            # (R, C)
        params = jnp.where(keep, state.params,
                           xhat.astype(state.params.dtype)[None])

        def recenter(buf):
            shift = _gsum(jnp.where(keep, buf, 0)) / n_cont
            return jnp.where(keep, buf - shift.astype(buf.dtype)[None],
                             jnp.zeros((), buf.dtype))

        delta = recenter(state.delta) if algo.use_delta else state.delta
        bias = recenter(state.bias) if bias_on else state.bias
        inner = jax.tree.map(
            lambda x: (jnp.where(keep, x, jnp.zeros((), x.dtype))
                       if getattr(x, "ndim", 0) == 3 else x), state.inner)
        comm = state.comm
        if isinstance(comm, CommState) and not isinstance(comm.resid,
                                                          tuple):
            comm = comm._replace(resid=jnp.where(keep, comm.resid, 0.0))
        ov = state.overlap
        if isinstance(ov, OverlapState):
            ov = OverlapState(pend=jnp.where(keep, ov.pend, xhat[None]),
                              pend_k=jnp.where(keep, ov.pend_k, 1.0))
        member = MemberState(active=new_active, n_active=n_new)
        return state._replace(params=params, delta=delta, bias=bias,
                              inner=inner, comm=comm, overlap=ov,
                              member=member)

    # --------------------------------------------- cohort drift recentre
    def _core_recenter_drift(state: FlatWorkerState) -> FlatWorkerState:
        """Re-zero Σ Δ (and Σ B) over the rows currently in the buffers.

        Client sampling gathers a cohort of W rows out of M client rows;
        each client's Δ was recentred against ALL clients, so the cohort's
        corrections sum to the cohort mean rather than zero — the sync
        math would then drag x̂ by that mean every round.  Subtracting the
        cohort mean restores Σ Δ = 0 (the ``set_membership`` repair's
        recentre, minus the churn handling), masked over active rows when
        a ``MemberState`` rides along so a crashed slot's NaNs can't leak.
        """
        member = state.member
        keep = (member.active > 0 if isinstance(member, MemberState)
                else None)

        def recenter(buf):
            shift = _wmean(buf, member)
            if keep is None:
                return buf - shift.astype(buf.dtype)[None]
            return jnp.where(keep, buf - shift.astype(buf.dtype)[None],
                             buf)

        delta = recenter(state.delta) if algo.use_delta else state.delta
        bias = recenter(state.bias) if bias_on else state.bias
        return state._replace(delta=delta, bias=bias)

    # --------------------------------------------------------- diagnostics
    # The record layout is decided at TRACE time from the config — the
    # shard_map out_specs must be a statically-known pytree, so which keys
    # exist can never depend on runtime values.
    ef_on = comp is not None and bool(getattr(comp, "error_feedback",
                                              False))
    diag_keys = ["params_rms", "drift_sq_mean", "drift_max",
                 "drift_per_worker", "nonfinite_workers"]
    if algo.use_delta:
        diag_keys += ["delta_residual", "zeta_sq_proxy"]
    if bias_on:
        diag_keys += ["bias_residual"]
    if ef_on:
        diag_keys += ["ef_resid_rms"]
    if kind != "sgd":
        diag_keys += ["mu_rms"]
    if kind == "adam":
        diag_keys += ["nu_rms"]
    red_axes = tuple(axis_names or ()) + ((shard_axis,)
                                          if shard_axis is not None else ())

    def _core_diagnostics(state: FlatWorkerState) -> dict:
        """Algorithm-health figures in ONE read-only pass.

        Runs OUTSIDE the compiled round (its own jit, --log-every
        cadence), so its handful of collectives — worker-axis psums plus
        the shard-axis row reductions — never touch the round's
        one-all-reduce HLO contract.

        Paper grounding: ``zeta_sq_proxy`` is the across-worker
        dispersion of the control variates, (1/n) Σᵢ ‖Δᵢ − Δ̄‖² — the
        analysis has Δᵢ tracking ∇Fᵢ − ∇F, so this is the runtime proxy
        for ζ², the inter-worker gradient variance whose dependency
        VRL-SGD eliminates.  (Post-sync params COINCIDE under broadcast
        syncs, so a between-round drift dispersion would measure ~0 and
        proxy nothing; drift is still reported because it is the
        meaningful dispersion under overlap / membership / EASGD, where
        params do not re-coincide.)  ``delta_residual`` is
        ‖(1/n) Σᵢ Δᵢ‖∞ — the Σ Δ = 0 invariant's residual
        (``bias_residual`` the BVR Σ B = 0 twin); both sit at
        float-noise level on a healthy run.

        Dead rows are excluded with ``where`` (never multiply — a
        crashed worker's NaNs would survive ``NaN * 0``), so a masked-
        out slot neither counts as non-finite nor drags any mean.
        """
        member = state.member
        masked = isinstance(member, MemberState)
        n = (member.n_active if masked
             else jnp.asarray(float(state.params.shape[0] * axis_size),
                              jnp.float32))

        def keep(buf):
            if not masked:
                return buf.astype(jnp.float32)
            return jnp.where(member.active > 0, buf.astype(jnp.float32),
                             0.0)

        def _gsum(x):                       # scalar sum over EVERY axis
            s = jnp.sum(x)
            return jax.lax.psum(s, red_axes) if red_axes else s

        def _gmax(x):                       # scalar max over EVERY axis
            m = jnp.max(x)
            return jax.lax.pmax(m, red_axes) if red_axes else m

        def _per_worker(x):                 # (W_l, R_l, C) -> (W_l,)
            s = jnp.sum(x, axis=(1, 2))
            if shard_axis is not None:
                s = jax.lax.psum(s, shard_axis)
            return s

        def _wsum(x):                       # worker-axis sum -> (R_l, C)
            s = jnp.sum(x, axis=0)
            if axis_names is not None:
                s = jax.lax.psum(s, axis_names)
            return s

        def _wscalar(s):                    # scalar sum over worker axes
            return (jax.lax.psum(s, axis_names) if axis_names is not None
                    else s)

        elems = float(fspec.rows * fspec.lanes)  # padded per-worker count

        out = {}
        p32 = keep(state.params)
        bad = _per_worker((~jnp.isfinite(p32)).astype(jnp.float32))
        out["nonfinite_workers"] = _wscalar(
            jnp.sum((bad > 0).astype(jnp.float32)))
        out["params_rms"] = jnp.sqrt(_gsum(p32 * p32) / (n * elems))
        xhat = _wsum(p32) * (1.0 / n)
        dev = keep(p32 - xhat[None])
        drift_w = _per_worker(dev * dev)    # ‖xᵢ − x̂‖² per worker
        out["drift_sq_mean"] = _wscalar(jnp.sum(drift_w)) * (1.0 / n)
        out["drift_max"] = jnp.sqrt(_gmax(drift_w))
        out["drift_per_worker"] = jnp.sqrt(drift_w)

        def invariant(buf, res_key, disp_key=None):
            b32 = keep(buf)
            s = _wsum(b32)                  # Σᵢ over active workers
            out[res_key] = _gmax(jnp.abs(s)) * (1.0 / n)
            if disp_key is not None:
                d = keep(b32 - (s * (1.0 / n))[None])
                out[disp_key] = _gsum(d * d) * (1.0 / n)

        if algo.use_delta:
            invariant(state.delta, "delta_residual", "zeta_sq_proxy")
        if bias_on:
            invariant(state.bias, "bias_residual")
        if ef_on:
            r32 = keep(state.comm.resid)
            out["ef_resid_rms"] = jnp.sqrt(_gsum(r32 * r32) / (n * elems))
        if kind != "sgd":
            m32 = keep(state.inner if kind == "momentum"
                       else state.inner.mu)
            out["mu_rms"] = jnp.sqrt(_gsum(m32 * m32) / (n * elems))
        if kind == "adam":
            if sm3:
                row32 = keep(state.inner.nu.row)
                col32 = keep(state.inner.nu.col)
                cnt = n * float(fspec.rows + fspec.shards * fspec.lanes)
                out["nu_rms"] = jnp.sqrt((_gsum(row32 * row32)
                                          + _gsum(col32 * col32)) / cnt)
            else:
                n32 = keep(state.inner.nu)
                out["nu_rms"] = jnp.sqrt(_gsum(n32 * n32) / (n * elems))
        return {k: out[k] for k in diag_keys}

    # ----------------------------------------------------- shard_map wrap
    ax = None
    if axis_names is not None:
        ax = axis_names[0] if len(axis_names) == 1 else axis_names

    def _specs(state):
        return _state_pspecs(state, axis_names, shard_axis=shard_axis,
                             shards=ecfg.shards)

    def _sharded(fn, gspec: Optional[P] = None):
        if not on_mesh:
            return fn

        def wrapped(state, *rest):
            sspec = _specs(state)
            in_specs = (sspec,) if gspec is None else (sspec, gspec)
            return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                    out_specs=sspec,
                                    check_vma=False)(state, *rest)

        return wrapped

    local_core = _sharded(_core_local, gspec=P(ax, shard_axis, None))
    sync_core = _sharded(_core_sync)
    recenter_core = _sharded(_core_recenter_drift)

    def diagnostics(state: FlatWorkerState) -> dict:
        """One read-only jitted pass of algorithm-health scalars plus a
        (W,) per-worker drift vector (see ``_core_diagnostics``).  Jit
        WITHOUT donation — it must not consume the state."""
        if not on_mesh:
            return _core_diagnostics(state)
        out_specs = {k: (P(ax) if k == "drift_per_worker" else P())
                     for k in diag_keys}
        return compat.shard_map(_core_diagnostics, mesh=mesh,
                                in_specs=(_specs(state),),
                                out_specs=out_specs,
                                check_vma=False)(state)
    train_core = _sharded(_core_train, gspec=P(ax, shard_axis, None))
    round_core = _sharded(_core_round_overlap if cfg.overlap
                          else _core_round,
                          gspec=P(None, ax, shard_axis, None))

    round_begin = round_fold = None
    if cfg.overlap:
        def round_begin(state, k: int = 0):
            """The round-START collective: the stale mean the round will
            fold (k is unused by the flat engine; the hierarchical twin
            needs it for the k2 cadence)."""
            del k
            if not on_mesh:
                return _core_round_begin(state)
            sspec = _specs(state)
            return compat.shard_map(
                _core_round_begin, mesh=mesh, in_specs=(sspec,),
                out_specs=P(shard_axis, None), check_vma=False)(state)

        def round_fold(state, xbar):
            """Fold ``round_begin``'s result at round end (one round
            stale by the local steps run in between)."""
            if not on_mesh:
                return _fold_overlap(state, xbar)
            sspec = _specs(state)
            return compat.shard_map(
                _fold_overlap, mesh=mesh,
                in_specs=(sspec, P(shard_axis, None)), out_specs=sspec,
                check_vma=False)(state, xbar)

    set_membership = None
    if member_on:
        member_core = _sharded(_core_set_membership,
                               gspec=P(ax, None, None))

        def set_membership(state: FlatWorkerState, active
                           ) -> FlatWorkerState:
            """Change the active set to ``active`` ((W,) bools/floats),
            repairing the invariants: Σ Δ (and Σ B) over the new active
            set is exactly zero, rejoiners restart from the continuing
            consensus.  Call between rounds (jit with
            donate_argnums=(0,)); one jit covers every mask value."""
            m = jnp.asarray(active, jnp.float32).reshape(-1)[:, None, None]
            return member_core(state, m)

    # --------------------------------------------------------- public API
    def _gbuf(grads: Any) -> jax.Array:
        return flat.flatten_stacked(fspec, grads, dtype=fspec.dtype)

    def local_step(state: FlatWorkerState, grads: Any) -> FlatWorkerState:
        return local_core(state, _gbuf(grads))

    def train_step(state: FlatWorkerState, grads: Any) -> FlatWorkerState:
        return train_core(state, _gbuf(grads))

    def sync(state: FlatWorkerState) -> FlatWorkerState:
        return sync_core(state)

    def round_step(state: FlatWorkerState, grads_k: Any) -> FlatWorkerState:
        """One communication round: scan k local steps + sync, one jit unit.

        ``grads_k``: worker-stacked grads pytree with an extra leading step
        axis ((k, W, ...) leaves).  Jit with ``donate_argnums=(0,)`` so the
        flat state buffers update in place across rounds.
        """
        gk = jax.vmap(
            lambda t: flat.flatten_stacked(fspec, t, dtype=fspec.dtype)
        )(grads_k)
        return round_core(state, gk)

    def round_step_flat(state: FlatWorkerState, gk: jax.Array
                        ) -> FlatWorkerState:
        """``round_step`` over an already-flattened (k, W, R, C) grads
        buffer — no pytree-flatten pass (the layout-native hot path)."""
        return round_core(state, gk)

    def params_tree(state: FlatWorkerState) -> Any:
        """Worker-stacked parameter pytree view (for the model forward)."""
        return flat.unflatten_stacked(fspec, state.params)

    def avg_model(state: FlatWorkerState) -> Any:
        if isinstance(state.member, MemberState):
            s = jnp.sum(jnp.where(state.member.active > 0, state.params,
                                  0), axis=0)
            return flat.unflatten_tree(
                fspec, s * (1.0 / state.member.n_active))
        return flat.unflatten_tree(fspec, jnp.mean(state.params, axis=0))

    return Engine(algorithm=cfg.algorithm, spec=fspec, algo=algo,
                  init=init, train_step=train_step, local_step=local_step,
                  sync=sync, average_model=avg_model,
                  params_tree=params_tree,
                  round_step=round_step, round_end=sync,
                  round_step_flat=round_step_flat,
                  round_begin=round_begin, round_fold=round_fold,
                  backend=backend,
                  # store the resolve_pair form verbatim (level 2 is
                  # meaningless for flat algorithms but keeping the pair
                  # canonical means pair_meta(cfg) == pair_meta(engine
                  # .compressors) — checkpoint metadata agrees whichever
                  # form a caller derives it from)
                  compressors=(comp, _comp2),
                  set_membership=set_membership,
                  recenter_drift=recenter_core,
                  diagnostics=diagnostics)


# ================================================ fused executor ("vrl2")
def _make_hier_engine(cfg: VRLConfig, algo: AlgoSpec, fspec: flat.FlatSpec,
                      *, mesh, ops, backend: str, kind: str, beta: float,
                      lr: float, wd: float, delta_dt, block: int,
                      interpret: bool, mdt=jnp.float32,
                      sm3: bool = False) -> Engine:
    """The two-level engine over pod-major (P, D, R, C) flat buffers.

    Level-1 sync averages within each pod (one psum over the intra-pod mesh
    axis) and folds the Δ1 update into the same fused pass; level-2
    averages across pods (one psum over the cross-pod axis) and folds the
    Δ2 update in.  Local steps touch no cross-worker axis at all.
    """
    hcfg = hier_config(cfg)
    p_total, d_total = hcfg.grid
    k1, k2 = hcfg.k1, hcfg.k2
    comp1, comp2 = comm_mod.resolve_pair(cfg)
    ef1_rt = (None if comp1 is None else
              _ef_op(ops, comp1, fspec.lanes, grid=True, block=block,
                     interpret=interpret))
    ef2_rt = (None if comp2 is None else
              _ef_op(ops, comp2, fspec.lanes, grid=False, block=block,
                     interpret=interpret))
    pod_axis = data_axis = None
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if sizes.get(hcfg.axes[0], 1) > 1:
            pod_axis = hcfg.axes[0]
        if sizes.get(hcfg.axes[1], 1) > 1:
            data_axis = hcfg.axes[1]
    shard_axis = _resolve_shard_axis(cfg.engine, mesh)

    member_on = bool(getattr(cfg, "membership", False))

    def _pod_mean(buf, member=()):
        """(P_l, D_l, R, C) -> (P_l, 1, R, C).  THE intra-pod all-reduce.

        Masked form: mean over each pod's ACTIVE members (state-carried
        per-pod counts); an all-dead pod divides by 1 and is excluded
        from the cross-pod mean by its zero count."""
        if isinstance(member, MemberState):
            s = jnp.sum(jnp.where(member.active > 0, buf, 0), axis=1,
                        keepdims=True)
            if data_axis is not None:
                s = jax.lax.psum(s, data_axis)
            # reciprocal-multiply, matching XLA's fold of the unmasked
            # constant divide (bitwise parity at full mask)
            return s * (1.0 / jnp.maximum(member.n_pod, 1.0))
        s = jnp.sum(buf, axis=1, keepdims=True)
        if data_axis is not None:
            s = jax.lax.psum(s, data_axis)
        return s / d_total

    def _cross_mean(pod_avg, member=()):
        """(P_l, 1, R, C) pod averages -> (R, C).  THE cross-pod
        all-reduce.

        Masked form: uniform mean over ALIVE pods — the weighting that
        keeps Σ_p Δ2 = 0 exact through pod-level churn (``n_active`` is
        the alive-pod count on the hierarchical engine)."""
        if isinstance(member, MemberState):
            alive = member.n_pod > 0
            s = jnp.sum(jnp.where(alive, pod_avg, 0), axis=(0, 1))
            if pod_axis is not None:
                s = jax.lax.psum(s, pod_axis)
            # reciprocal-multiply (see _pod_mean): full-mask bitwise parity
            return s * (1.0 / member.n_active)
        s = jnp.sum(pod_avg, axis=(0, 1))
        if pod_axis is not None:
            s = jax.lax.psum(s, pod_axis)
        return s / p_total

    # ------------------------------------------------------------- init
    def init(params: Any, num_workers: int) -> HierFlatState:
        if num_workers != p_total * d_total:
            raise ValueError(
                f"hier grid {hcfg.grid} holds {p_total * d_total} workers, "
                f"init asked for {num_workers}")
        flat1 = flat.flatten_tree(fspec, params)
        stacked = jnp.broadcast_to(
            flat1, (p_total, d_total, *flat1.shape)).copy()
        delta1 = jnp.zeros(stacked.shape, delta_dt)
        delta2 = jnp.zeros((p_total, 1, *flat1.shape), delta_dt)
        if kind == "sgd":
            inner = ()
        elif kind == "momentum":
            inner = jnp.zeros(stacked.shape, mdt)
        elif sm3:
            nu = SM3Pair(
                row=jnp.zeros((p_total, d_total, fspec.rows, 1),
                              jnp.float32),
                col=jnp.zeros((p_total, d_total, fspec.shards, fspec.lanes),
                              jnp.float32))
            inner = AdamState(jnp.zeros(stacked.shape, mdt), nu,
                              jnp.zeros((), jnp.int32))
        else:
            inner = AdamState(jnp.zeros(stacked.shape, mdt),
                              jnp.zeros(stacked.shape, mdt),
                              jnp.zeros((), jnp.int32))
        comm = ()
        if comp1 is not None or comp2 is not None:
            comm = HierCommState(
                resid1=(jnp.zeros(stacked.shape, jnp.float32)
                        if comp1 and comp1.error_feedback else ()),
                ref1=(jnp.broadcast_to(flat1.astype(jnp.float32),
                                       (p_total, 1, *flat1.shape)).copy()
                      if comp1 else ()),
                resid2=(jnp.zeros((p_total, 1, *flat1.shape), jnp.float32)
                        if comp2 and comp2.error_feedback else ()),
                ref2=(flat1.astype(jnp.float32) if comp2 else ()))
        overlap = ()
        if cfg.overlap:
            # per-pod transmitted positions; pend = x0 so the first
            # level-2 fold's correction is exactly zero
            overlap = OverlapState(
                pend=jnp.broadcast_to(flat1.astype(jnp.float32),
                                      (p_total, 1, *flat1.shape)).copy(),
                pend_k=jnp.ones((p_total, 1, 1, 1), jnp.float32))
        member = ()
        if member_on:
            member = MemberState(
                active=jnp.ones((p_total, d_total, 1, 1), jnp.float32),
                n_active=jnp.asarray(float(p_total), jnp.float32),
                n_pod=jnp.full((p_total, 1, 1, 1), float(d_total),
                               jnp.float32))
        return HierFlatState(params=stacked, delta1=delta1, delta2=delta2,
                             inner=inner, step=jnp.zeros((), jnp.int32),
                             last_sync1=jnp.zeros((), jnp.int32),
                             last_sync2=jnp.zeros((), jnp.int32),
                             comm=comm, overlap=overlap, member=member)

    # ------------------------------------------------- core step functions
    def _core_local(state: HierFlatState, g: jax.Array) -> HierFlatState:
        if kind == "sgd":
            new_p = ops.fused_hier_local_sgd(
                state.params, g, state.delta1, state.delta2, lr=lr, wd=wd,
                block=block, interpret=interpret)
            new_inner = state.inner
        elif kind == "momentum":
            new_p, new_inner = ops.fused_hier_local_momentum(
                state.params, g, state.delta1, state.delta2, state.inner,
                lr=lr, beta=beta, wd=wd, block=block, interpret=interpret)
        else:
            count = state.inner.count + 1
            t = count.astype(jnp.float32)
            scal = jnp.stack([1.0 - _ADAM_B1 ** t, 1.0 - _ADAM_B2 ** t]
                             ).reshape(1, 2).astype(jnp.float32)
            if sm3:
                new_p, new_mu, new_row, new_col = \
                    ops.fused_hier_local_adam_sm3(
                        state.params, g, state.delta1, state.delta2,
                        state.inner.mu, state.inner.nu.row,
                        state.inner.nu.col, scal, lr=lr, b1=_ADAM_B1,
                        b2=_ADAM_B2, wd=wd, block=block,
                        interpret=interpret)
                new_inner = AdamState(new_mu, SM3Pair(new_row, new_col),
                                      count)
            else:
                new_p, new_mu, new_nu = ops.fused_hier_local_adam(
                    state.params, g, state.delta1, state.delta2,
                    state.inner.mu, state.inner.nu, scal, lr=lr,
                    b1=_ADAM_B1, b2=_ADAM_B2, wd=wd, block=block,
                    interpret=interpret)
                new_inner = AdamState(new_mu, new_nu, count)
        return state._replace(params=new_p, inner=new_inner,
                              step=state.step + 1)

    def _core_sync1(state: HierFlatState) -> HierFlatState:
        k_eff = jnp.maximum(state.step - state.last_sync1, 1
                            ).astype(jnp.float32)
        if comp1 is not None:
            # compressed intra-pod drift: per-pod ref1 is shared within
            # each averaging group, so the pod mean reconstructs exactly
            cm = state.comm
            e = cm.resid1 if comp1.error_feedback else None
            dec, e_out = ef1_rt(state.params, cm.ref1, e)
            xbar = cm.ref1 + _pod_mean(dec, state.member)
            state = state._replace(comm=cm._replace(
                ref1=xbar,
                resid1=(e_out if comp1.error_feedback else ())))
        else:
            xbar = _pod_mean(state.params, state.member)
        scal = (k_eff * lr).reshape(1, 1).astype(jnp.float32)
        new_p, new_d1 = ops.fused_sync_hier1(
            state.params, xbar.astype(state.params.dtype), state.delta1,
            scal, block=block, interpret=interpret)
        return state._replace(params=new_p, delta1=new_d1,
                              last_sync1=state.step)

    def _core_sync2(state: HierFlatState) -> HierFlatState:
        # Assumes a level-1 sync at this step: params ARE the pod averages,
        # so the global mean needs only the cross-pod axis.
        k_eff = jnp.maximum(state.step - state.last_sync2, 1
                            ).astype(jnp.float32)
        if comp2 is not None:
            # compressed cross-pod drift against the global ref2 — the
            # slow-DCI-tier payload, typically compressed the hardest
            cm = state.comm
            pod = state.params[:, 0]                    # (P_l, R, C)
            e = (cm.resid2[:, 0] if comp2.error_feedback else None)
            dec, e_out = ef2_rt(pod, cm.ref2, e)
            glob = cm.ref2 + _cross_mean(dec[:, None], state.member)
            state = state._replace(comm=cm._replace(
                ref2=glob,
                resid2=(e_out[:, None] if comp2.error_feedback else ())))
        else:
            glob = _cross_mean(state.params[:, :1], state.member)
        if comp1 is not None:
            # level-2 moves every worker to x̂: re-anchor ref1 so the next
            # intra-pod payload is small again
            cm = state.comm
            state = state._replace(comm=cm._replace(ref1=jnp.broadcast_to(
                glob.astype(jnp.float32), cm.ref1.shape)))
        scal = (k_eff * lr).reshape(1, 1).astype(jnp.float32)
        new_p, new_d2 = ops.fused_sync_hier2(
            state.params, glob.astype(state.params.dtype), state.delta2,
            scal, block=block, interpret=interpret)
        return state._replace(params=new_p, delta2=new_d2,
                              last_sync2=state.step)

    def _core_sync(state: HierFlatState) -> HierFlatState:
        return _core_sync2(_core_sync1(state))

    def _core_train(state: HierFlatState, g: jax.Array) -> HierFlatState:
        state = _core_local(state, g)
        do1 = (state.step - state.last_sync1) >= k1
        do2 = (state.step - state.last_sync2) >= k2
        state = jax.lax.cond(do1 | do2, _core_sync1, lambda s: s, state)
        return jax.lax.cond(do2, _core_sync2, lambda s: s, state)

    def _core_round_end(state: HierFlatState) -> HierFlatState:
        """Round-closing sync: a round is one k1 period, so level-1 always
        fires; level-2 fires whenever the k2 cadence is due (k2 % k1 == 0,
        checked at the public boundary — the per-step oracle is
        ``_core_train``)."""
        state = _core_sync1(state)
        do2 = (state.step - state.last_sync2) >= k2
        return jax.lax.cond(do2, _core_sync2, lambda s: s, state)

    def _core_round(state: HierFlatState, gk: jax.Array) -> HierFlatState:
        state, _ = jax.lax.scan(lambda s, g: (_core_local(s, g), None),
                                state, gk)
        return _core_round_end(state)

    # ------------------------------------------------- overlapped round
    # Only the cross-pod sync2 — the slow DCI tier the roofline prices —
    # is overlapped; the intra-pod sync1 stays blocking (ICI is cheap and
    # the level-2 fold needs post-sync1 pod-uniform params).
    def _miss_mask2(step: jax.Array, n: int) -> jax.Array:
        """Per-pod (n, 1) deadline-miss mask (level 2's participants are
        pods).  Same contract as the flat ``_miss_mask``."""
        if not cfg.deadline:
            return jnp.zeros((n, 1), jnp.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        if pod_axis is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(pod_axis))
        u = jax.random.uniform(key, (n, 1))
        return (u < cfg.deadline).astype(jnp.float32)

    def _core_round_begin(state: HierFlatState, k: int) -> jax.Array:
        """The level-2 collective issued at round START — only when this
        round's closing step will land on the k2 cadence (the fold's
        matching cond recomputes the same predicate after the scan
        advanced ``step`` by k); otherwise zeros, which the fold never
        reads."""
        do2 = (state.step + k - state.last_sync2) >= k2
        zeros = jnp.zeros(state.overlap.pend.shape[2:], jnp.float32)
        return jax.lax.cond(
            do2, lambda s: _cross_mean(s.overlap.pend, s.member),
            lambda s: zeros, state)

    def _fold2(state: HierFlatState, glob: jax.Array) -> HierFlatState:
        """Apply the stale cross-pod mean: c_p = x̂_stale − pend2_p folds
        into every worker of pod p (post-sync1, so the whole pod moves
        together), Δ2 updates over the period pend covered, and the new
        per-pod positions are captured for the next level-2 collective."""
        ov = state.overlap
        k_eff = jnp.maximum(state.step - state.last_sync2, 1
                            ).astype(jnp.float32)
        km = _miss_mask2(state.step, ov.pend.shape[0])         # (P_l, 1)
        inv = 1.0 / (ov.pend_k[:, 0, :, 0] * lr)               # (P_l, 1)
        wscal = jnp.concatenate([inv, km], axis=1).astype(jnp.float32)
        km4 = km[:, :, None, None]
        new_pend_k = km4 * (ov.pend_k + k_eff) + (1.0 - km4) * k_eff
        capture = comp2 is None
        if comp1 is not None:
            # the fold shifts every worker of pod p by c_p: shift the
            # shared intra-pod reference the same way so the next
            # level-1 payload stays small
            c_p = glob[None, None] - ov.pend
            state = state._replace(
                comm=state.comm._replace(ref1=state.comm.ref1 + c_p))
        out = ops.fused_fold_overlap_hier2(
            state.params, glob.astype(state.params.dtype), ov.pend,
            state.delta2, wscal, capture=capture, block=block,
            interpret=interpret)
        state = state._replace(params=out[0], delta2=out[1])
        if capture:
            new_pend = out[2]
        else:
            # compressed level-2 capture: EF round-trip of the folded
            # pod position's drift against the stale global mean
            cm = state.comm
            pod = state.params[:, 0]                         # (P_l, R, C)
            e = cm.resid2[:, 0] if comp2.error_feedback else None
            dec, e_out = ef2_rt(pod, glob, e)
            sent = glob[None] + dec
            new_pend = km4 * ov.pend + (1.0 - km4) * sent[:, None]
            resid2 = ((e_out + km[:, :, None] * dec)[:, None]
                      if comp2.error_feedback else ())
            state = state._replace(comm=cm._replace(ref2=glob,
                                                    resid2=resid2))
        return state._replace(overlap=OverlapState(new_pend, new_pend_k),
                              last_sync2=state.step)

    def _core_round_end_overlap(state: HierFlatState, glob: jax.Array
                                ) -> HierFlatState:
        """Round-closing sync under overlap: the blocking level-1 sync,
        then — iff this step lands on the k2 cadence — the stale level-2
        fold of the round-START collective's result."""
        state = _core_sync1(state)
        do2 = (state.step - state.last_sync2) >= k2
        return jax.lax.cond(do2, lambda s: _fold2(s, glob),
                            lambda s: s, state)

    def _core_round_overlap(state: HierFlatState, gk: jax.Array
                            ) -> HierFlatState:
        glob = _core_round_begin(state, gk.shape[0])
        state, _ = jax.lax.scan(lambda s, g: (_core_local(s, g), None),
                                state, gk)
        return _core_round_end_overlap(state, glob)

    # --------------------------------------------- membership repair
    def _core_set_membership(state: HierFlatState, new_active: jax.Array
                             ) -> HierFlatState:
        """Two-level twin of the flat repair: Δ1 recentred per pod over
        that pod's continuing members (Σ_d Δ1 = 0 within every pod with
        survivors), Δ2 recentred over the pods that stay alive
        (Σ_p Δ2 = 0 over the new alive set); dropped/rejoining workers —
        and fully-replaced pods' per-pod buffers — re-seeded from the
        continuing consensus x̂."""
        def _data_sum(x):                      # (P_l, D_l, ...) → (P_l, 1, ...)
            s = jnp.sum(x, axis=1, keepdims=True)
            if data_axis is not None:
                s = jax.lax.psum(s, data_axis)
            return s

        def _pod_sum(x):                       # data-replicated (P_l, 1, ...)
            s = jnp.sum(x, axis=(0, 1))
            if pod_axis is not None:
                s = jax.lax.psum(s, pod_axis)
            return s

        def _all_sum(x):                       # raw (P_l, D_l, ...) → global
            s = jnp.sum(x, axis=(0, 1))
            axes = tuple(a for a in (pod_axis, data_axis) if a is not None)
            if axes:
                s = jax.lax.psum(s, axes)
            return s

        old = state.member.active                      # (P_l, D_l, 1, 1)
        cont = old * new_active
        keep = cont > 0
        n_cont = jnp.maximum(jnp.sum(_all_sum(cont)), 1.0)
        n_cont_pod = _data_sum(cont)                   # (P_l, 1, 1, 1)
        pod_keep = n_cont_pod > 0
        n_new_pod = _data_sum(new_active)
        xhat = _all_sum(jnp.where(keep, state.params.astype(jnp.float32),
                                  0.0)) / n_cont       # (R, C)
        params = jnp.where(keep, state.params,
                           xhat.astype(state.params.dtype)[None, None])
        s1 = _data_sum(jnp.where(keep, state.delta1, 0)
                       ) / jnp.maximum(n_cont_pod, 1.0)
        delta1 = jnp.where(keep, state.delta1 - s1.astype(state.delta1.dtype),
                           jnp.zeros((), state.delta1.dtype))
        n_pods_cont = jnp.maximum(
            jnp.sum(_pod_sum(pod_keep.astype(jnp.float32))), 1.0)
        s2 = _pod_sum(jnp.where(pod_keep, state.delta2, 0)) / n_pods_cont
        delta2 = jnp.where(pod_keep,
                           state.delta2 - s2.astype(state.delta2.dtype
                                                    )[None, None],
                           jnp.zeros((), state.delta2.dtype))
        inner = jax.tree.map(
            lambda x: (jnp.where(keep, x, jnp.zeros((), x.dtype))
                       if getattr(x, "ndim", 0) == 4 else x), state.inner)
        comm = state.comm
        if isinstance(comm, HierCommState):
            have = lambda x: not isinstance(x, tuple)
            comm = HierCommState(
                resid1=(jnp.where(keep, comm.resid1, 0.0)
                        if have(comm.resid1) else ()),
                # a fully-replaced pod's shared intra-pod reference is
                # re-anchored to x̂ (its new members all start there)
                ref1=(jnp.where(pod_keep, comm.ref1, xhat[None, None])
                      if have(comm.ref1) else ()),
                resid2=(jnp.where(pod_keep, comm.resid2, 0.0)
                        if have(comm.resid2) else ()),
                ref2=comm.ref2)
        ov = state.overlap
        if isinstance(ov, OverlapState):
            ov = OverlapState(
                pend=jnp.where(pod_keep, ov.pend, xhat[None, None]),
                pend_k=jnp.where(pod_keep, ov.pend_k, 1.0))
        member = MemberState(
            active=new_active,
            n_active=jnp.sum(_pod_sum((n_new_pod > 0).astype(jnp.float32))),
            n_pod=n_new_pod)
        return state._replace(params=params, delta1=delta1, delta2=delta2,
                              inner=inner, comm=comm, overlap=ov,
                              member=member)

    # --------------------------------------------------------- diagnostics
    # Static key set — shard_map out_specs must be a statically-known
    # pytree (see the flat engine's twin).
    ef_on = comp1 is not None and bool(getattr(comp1, "error_feedback",
                                               False))
    diag_keys = ["params_rms", "drift_sq_mean", "drift_max",
                 "nonfinite_workers", "delta1_residual", "delta2_residual",
                 "zeta_sq_proxy"]
    if ef_on:
        diag_keys += ["ef_resid_rms"]
    if kind != "sgd":
        diag_keys += ["mu_rms"]
    if kind == "adam":
        diag_keys += ["nu_rms"]
    d_red = tuple(a for a in (pod_axis, data_axis, shard_axis)
                  if a is not None)

    def _core_diag_hier(state: HierFlatState) -> dict:
        """Two-level twin of the flat ``_core_diagnostics`` (same
        read-only / own-jit contract).  The invariant residuals follow
        the two-level structure: ``delta1_residual`` is the worst pod's
        ‖mean over its active members of Δ1‖∞ (Σ_d Δ1[p] = 0 within
        every pod), ``delta2_residual`` is ‖mean over alive pods of
        Δ2‖∞ (Σ_p Δ2 = 0); the ζ² proxy is the dispersion of the
        worker's TOTAL correction Δ1 + Δ2 — the quantity that tracks
        ∇Fᵢ − ∇F in the analysis."""
        member = state.member
        masked = isinstance(member, MemberState)
        worker_axes_ = tuple(a for a in (pod_axis, data_axis)
                             if a is not None)

        def keep(buf):                      # (P, D, ...) worker mask
            if not masked:
                return buf.astype(jnp.float32)
            return jnp.where(member.active > 0, buf.astype(jnp.float32),
                             0.0)

        def keep_pod(buf):                  # (P, 1, ...) alive-pod mask
            if not masked:
                return buf.astype(jnp.float32)
            return jnp.where(member.n_pod > 0, buf.astype(jnp.float32),
                             0.0)

        def _gsum(x):
            s = jnp.sum(x)
            return jax.lax.psum(s, d_red) if d_red else s

        def _gmax(x):
            m = jnp.max(x)
            return jax.lax.pmax(m, d_red) if d_red else m

        def _per_worker(x):                 # (P_l, D_l, R_l, C) -> (P_l, D_l)
            s = jnp.sum(x, axis=(2, 3))
            if shard_axis is not None:
                s = jax.lax.psum(s, shard_axis)
            return s

        def _grid_sum(x):                   # worker-axes sum -> (R_l, C)
            s = jnp.sum(x, axis=(0, 1))
            return jax.lax.psum(s, worker_axes_) if worker_axes_ else s

        def _wscalar(s):
            return (jax.lax.psum(s, worker_axes_) if worker_axes_ else s)

        def _data_sum(x):                   # (P_l, D_l, ...) -> (P_l, 1, ...)
            s = jnp.sum(x, axis=1, keepdims=True)
            if data_axis is not None:
                s = jax.lax.psum(s, data_axis)
            return s

        def _pod_sum(x):                    # data-replicated (P_l, 1, ...)
            s = jnp.sum(x, axis=(0, 1))
            if pod_axis is not None:
                s = jax.lax.psum(s, pod_axis)
            return s

        if masked:
            npod = jnp.sum(member.n_pod)
            if pod_axis is not None:
                npod = jax.lax.psum(npod, pod_axis)
            n = jnp.maximum(npod, 1.0)      # total ACTIVE workers
        else:
            n = float(p_total * d_total)
        elems = float(fspec.rows * fspec.lanes)

        out = {}
        p32 = keep(state.params)
        bad = _per_worker((~jnp.isfinite(p32)).astype(jnp.float32))
        out["nonfinite_workers"] = _wscalar(
            jnp.sum((bad > 0).astype(jnp.float32)))
        out["params_rms"] = jnp.sqrt(_gsum(p32 * p32) / (n * elems))
        xhat = _grid_sum(p32) * (1.0 / n)
        dev = keep(p32 - xhat[None, None])
        drift_w = _per_worker(dev * dev)
        out["drift_sq_mean"] = _wscalar(jnp.sum(drift_w)) * (1.0 / n)
        out["drift_max"] = jnp.sqrt(_gmax(drift_w))

        d1 = keep(state.delta1)
        s1 = _data_sum(d1)                  # (P_l, 1, R_l, C)
        mean1 = (s1 * (1.0 / jnp.maximum(member.n_pod, 1.0)) if masked
                 else s1 / float(d_total))
        out["delta1_residual"] = _gmax(jnp.abs(mean1))
        d2 = keep_pod(state.delta2)
        s2 = _pod_sum(d2)                   # (R_l, C)
        mean2 = (s2 * (1.0 / member.n_active) if masked
                 else s2 / float(p_total))
        out["delta2_residual"] = _gmax(jnp.abs(mean2))
        c = keep(d1 + d2.astype(jnp.float32))   # total correction per worker
        cmean = _grid_sum(c) * (1.0 / n)
        cdev = keep(c - cmean[None, None])
        out["zeta_sq_proxy"] = _gsum(cdev * cdev) * (1.0 / n)

        if ef_on:
            r32 = keep(state.comm.resid1)
            out["ef_resid_rms"] = jnp.sqrt(_gsum(r32 * r32) / (n * elems))
        if kind != "sgd":
            m32 = keep(state.inner if kind == "momentum"
                       else state.inner.mu)
            out["mu_rms"] = jnp.sqrt(_gsum(m32 * m32) / (n * elems))
        if kind == "adam":
            if sm3:
                row32 = keep(state.inner.nu.row)
                col32 = keep(state.inner.nu.col)
                cnt = n * float(fspec.rows + fspec.shards * fspec.lanes)
                out["nu_rms"] = jnp.sqrt((_gsum(row32 * row32)
                                          + _gsum(col32 * col32)) / cnt)
            else:
                n32 = keep(state.inner.nu)
                out["nu_rms"] = jnp.sqrt(_gsum(n32 * n32) / (n * elems))
        return {k: out[k] for k in diag_keys}

    # ----------------------------------------------------- shard_map wrap
    meshless = mesh is None or (pod_axis is None and data_axis is None
                                and shard_axis is None)

    def _specs(state):
        return _hier_pspecs(state, pod_axis, data_axis,
                            shard_axis=shard_axis, shards=cfg.engine.shards)

    def _sharded(fn, gspec: Optional[P] = None):
        if meshless:
            return fn

        def wrapped(state, *rest):
            sspec = _specs(state)
            in_specs = (sspec,) if gspec is None else (sspec, gspec)
            return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                    out_specs=sspec,
                                    check_vma=False)(state, *rest)

        return wrapped

    gspec = P(pod_axis, data_axis, shard_axis, None)
    local_core = _sharded(_core_local, gspec=gspec)
    train_core = _sharded(_core_train, gspec=gspec)
    sync_core = _sharded(_core_sync)
    sync1_core = _sharded(_core_sync1)
    sync2_core = _sharded(_core_sync2)
    round_core = _sharded(_core_round_overlap if cfg.overlap
                          else _core_round,
                          gspec=P(None, pod_axis, data_axis, shard_axis,
                                  None))
    round_end_core = _sharded(_core_round_end)

    def diagnostics(state: HierFlatState) -> dict:
        """One read-only jitted pass of two-level algorithm-health
        scalars (see ``_core_diag_hier``).  Jit WITHOUT donation."""
        if meshless:
            return _core_diag_hier(state)
        out_specs = {k: P() for k in diag_keys}
        return compat.shard_map(_core_diag_hier, mesh=mesh,
                                in_specs=(_specs(state),),
                                out_specs=out_specs,
                                check_vma=False)(state)

    round_begin = round_fold = None
    if cfg.overlap:
        def round_begin(state, k: int):
            """The round-START level-2 collective (zeros off the k2
            cadence); ``k`` is this round's length, needed to decide the
            cadence before the scan advances ``step``."""
            _check_round()
            if meshless:
                return _core_round_begin(state, k)
            sspec = _specs(state)
            return compat.shard_map(
                functools.partial(_core_round_begin, k=k), mesh=mesh,
                in_specs=(sspec,), out_specs=P(shard_axis, None),
                check_vma=False)(state)

        def round_fold(state, glob):
            """Blocking sync1 + (on the k2 cadence) the stale level-2
            fold of ``round_begin``'s result."""
            _check_round()
            if meshless:
                return _core_round_end_overlap(state, glob)
            sspec = _specs(state)
            return compat.shard_map(
                _core_round_end_overlap, mesh=mesh,
                in_specs=(sspec, P(shard_axis, None)), out_specs=sspec,
                check_vma=False)(state, glob)

    set_membership = None
    if member_on:
        member_core = _sharded(_core_set_membership,
                               gspec=P(pod_axis, data_axis, None, None))

        def set_membership(state: HierFlatState, active) -> HierFlatState:
            """Change the active set to ``active`` ((W,) or (P, D)
            bools/floats, pod-major), repairing the two-level invariants.
            Call between rounds (jit with donate_argnums=(0,))."""
            m = jnp.asarray(active, jnp.float32).reshape(
                p_total, d_total)[:, :, None, None]
            return member_core(state, m)

    # --------------------------------------------------------- public API
    def _gbuf(grads: Any) -> jax.Array:
        return flat.flatten_grid(fspec, grads, dtype=fspec.dtype)

    def local_step(state, grads):
        return local_core(state, _gbuf(grads))

    def train_step(state, grads):
        return train_core(state, _gbuf(grads))

    def _check_round():
        if k2 % k1:
            raise ValueError(
                f"round execution treats one k1 period as the unit and "
                f"nests the level-2 cadence, which needs k2 % k1 == 0; "
                f"got k1={k1}, k2={k2}")

    def round_step(state, grads_k):
        """One k1 round: scan k1 local steps + sync1 (+ sync2 when the k2
        cadence is due).  ``grads_k``: grid-stacked grads pytree with an
        extra leading step axis ((k1, P, D, ...) leaves)."""
        _check_round()
        gk = jax.vmap(
            lambda t: flat.flatten_grid(fspec, t, dtype=fspec.dtype)
        )(grads_k)
        return round_core(state, gk)

    def round_step_flat(state, gk):
        """``round_step`` over an already-flattened (k1, P, D, R, C)
        grads buffer — no pytree-flatten pass."""
        _check_round()
        return round_core(state, gk)

    def round_end(state):
        _check_round()
        return round_end_core(state)

    def params_tree(state):
        """Grid-stacked parameter pytree view ((P, D, ...) leaves)."""
        return flat.unflatten_grid(fspec, state.params)

    def avg_model(state):
        if isinstance(state.member, MemberState):
            m = state.member.active
            s = jnp.sum(jnp.where(m > 0, state.params, 0), axis=(0, 1))
            return flat.unflatten_tree(
                fspec, s * (1.0 / jnp.maximum(jnp.sum(m), 1.0)))
        return flat.unflatten_tree(fspec,
                                   jnp.mean(state.params, axis=(0, 1)))

    return Engine(algorithm=cfg.algorithm, spec=fspec, algo=algo,
                  init=init, train_step=train_step, local_step=local_step,
                  sync=lambda s: sync_core(s), average_model=avg_model,
                  params_tree=params_tree,
                  sync1=lambda s: sync1_core(s),
                  sync2=lambda s: sync2_core(s),
                  grid=(p_total, d_total),
                  round_step=round_step, round_end=round_end,
                  round_step_flat=round_step_flat,
                  round_begin=round_begin, round_fold=round_fold,
                  backend=backend,
                  compressors=(comp1, comp2),
                  set_membership=set_membership,
                  diagnostics=diagnostics)
