"""Granite-3.0-2B — dense GQA. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    vocab_size=49_155,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    mlp_act="silu",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
