"""The paper's own transfer-learning model (§6.1): MLP on 2048-d Inception-V3
features, one hidden layer of 1024, 200 output classes, relu. Used by the
convergence benchmarks (Fig. 1/2 analogs), not by the dry-run matrix.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class MLPConfig:
    name: str = "paper-mlp"
    in_dim: int = 2048
    hidden: int = 1024
    classes: int = 200


CONFIG = MLPConfig()
