"""Mamba2-370M — SSD (state-space duality), attention-free. [arXiv:2405.21060]

48L, d_model=1024, ssm_state=128, expand=2 (d_inner=2048, 32 SSD heads of
head_dim 64), vocab 50280. O(1)-state decode: runs long_500k natively.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)
