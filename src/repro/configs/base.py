"""Config dataclasses for the repro framework.

Everything is a plain frozen dataclass so configs hash, compare, and print
cleanly, and so jit cache keys are stable.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition.

    ``family`` selects the block type:
      dense  — pre-norm transformer, GQA attention + gated MLP
      moe    — dense attention + top-k routed expert MLP
      ssm    — Mamba2 SSD blocks (attention-free)
      hybrid — Hymba-style parallel attention + SSM heads per block
      vlm    — dense backbone consuming early-fusion (text+VQ-image) tokens
      audio  — dense backbone consuming codec-token embeddings
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    attn_window: Optional[int] = None          # sliding-window size; None = full
    long_context_window: int = 8192            # window used for long_500k variant
    rope_theta: float = 10_000.0
    # mlp
    d_ff: int = 0
    mlp_act: str = "silu"                      # silu | geglu | gelu
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    moe_d_ff: int = 0                          # expert hidden size (kimi: 2048)
    num_shared_experts: int = 0
    # ssm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # embeddings / misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # sequence-parallel activations: constrain the residual stream's seq
    # dim to the tensor axis between blocks (Megatron-SP; reduce-scatter +
    # all-gather instead of all-reduce, and norm/elementwise run seq-sharded)
    seq_shard_acts: bool = False
    # modality frontend stub: if set, inputs are precomputed embeddings
    # of shape (batch, seq, frontend_dim) instead of token ids.
    frontend: Optional[str] = None             # None | "vision" | "codec"
    frontend_dim: int = 0
    # citation for the config (public pool provenance)
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch natively supports O(<<L^2) long-context decode."""
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        per_layer = 0
        if self.family != "ssm":
            hd = self.head_dim
            per_layer += d * (self.num_heads * hd)          # q
            per_layer += 2 * d * (self.num_kv_heads * hd)   # k, v
            per_layer += (self.num_heads * hd) * d          # o
            if self.qkv_bias:
                per_layer += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.family in ("dense", "vlm", "audio", "hybrid"):
            mult = 3 if self.mlp_act in ("silu", "geglu") else 2
            per_layer += mult * d * self.d_ff
        if self.family == "moe":
            ff = self.moe_d_ff or self.d_ff
            per_layer += 3 * d * ff * self.num_experts
            per_layer += 3 * d * ff * self.num_shared_experts
            per_layer += d * self.num_experts               # router
        if self.family in ("ssm", "hybrid"):
            di, ds, nh = self.ssm_d_inner, self.ssm_state, self.ssm_num_heads
            per_layer += d * (2 * di + 2 * ds * (di // self.ssm_head_dim) + nh)
            per_layer += di * d                              # out proj
            per_layer += self.conv_kernel * (di + 2 * ds * nh)
        per_layer += 2 * d  # norms
        return total + L * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        ff = self.moe_d_ff or self.d_ff
        dense_experts = self.experts_per_token + self.num_shared_experts
        inactive = 3 * self.d_model * ff * (
            self.num_experts + self.num_shared_experts - dense_experts)
        return self.param_count() - self.num_layers * inactive


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class EngineConfig:
    """Knobs for the flat-buffer fused-update engine (core/engine.py).

    ``block=0`` auto-sizes the Pallas row tile (pad toward 1024-row
    multiples, capping padding waste at ``max_pad_waste``); ``interpret=None``
    runs kernel bodies in python everywhere except real TPU/GPU backends.
    ``round_scan=True`` makes the *round* the unit of compilation in the
    launch drivers: k local steps run under a single ``lax.scan`` (state
    donated, losses buffered device-side) followed by the round-closing
    sync, compiled once per (k, shape) instead of k python dispatches.
    ``shards`` row-block-shards every (W, R, C) engine buffer over a model
    mesh axis: rows pad to a multiple of ``block * shards`` so each shard
    holds whole Pallas tiles, per-device engine HBM drops by the shard
    count, and the round-closing sync becomes a per-shard all-reduce over
    the worker axes only (still exactly ONE collective per round).
    ``shards=1`` is bitwise the replicated path.
    """

    block: int = 0                  # Pallas tile height; 0 = auto
    lanes: int = 256                # flat-buffer lane (last-dim) width
    interpret: Optional[bool] = None
    max_pad_waste: float = 0.25
    round_scan: bool = True         # launch drivers use round_step
    shards: int = 1                 # model-axis shard count for engine state
    shard_axis: str = "shard"       # mesh axis name backing the shards (the
                                    # production mesh reuses "model")


@dataclass(frozen=True)
class HierConfig:
    """Two-level hierarchical VRL-SGD (beyond-paper, STL-SGD direction).

    The worker population is a pod-major ``grid = (P pods, D workers/pod)``.
    Intra-pod sync (cheap ICI links) runs every ``k1`` steps, cross-pod sync
    (slow DCI links) every ``k2 >= k1``; each level carries its own VRL
    correction (Δ1 per worker, Δ2 per pod).  ``axes`` names the mesh axes
    backing each level as (cross-pod axis, intra-pod axis): level-1 sync
    lowers to one psum over ``axes[1]``, level-2 to one psum over
    ``axes[0]``.
    """

    k1: int = 5
    k2: int = 20
    grid: Tuple[int, int] = (2, 4)
    axes: Tuple[str, str] = ("pod", "data")


@dataclass(frozen=True)
class VRLConfig:
    """The paper's algorithm knobs."""

    # vrl_sgd | local_sgd | ssgd | easgd | hier_vrl_sgd | stl_sgd | bvr_l_sgd
    algorithm: str = "vrl_sgd"
    comm_period: int = 20           # k
    warmup: bool = True             # VRL-SGD-W (Remark 5.3): first period k=1
    learning_rate: float = 0.01
    weight_decay: float = 1e-4
    inner_optimizer: str = "sgd"    # sgd | momentum | adam (beyond-paper)
    clip_norm: float = 0.0          # per-worker global-norm gradient clip
    momentum: float = 0.0
    # storage dtype for the inner-optimizer moment buffers (momentum /
    # Adam mu+nu).  The update math stays fp32 in-register on every
    # executor; only what persists in HBM between steps is quantized.
    # "float32" (default) is bitwise the current path; "bfloat16" halves
    # moment HBM at sub-1e-2 trajectory drift.
    moment_dtype: str = "float32"   # float32 | bfloat16
    # SM3-style factored second moment for the adam inner optimizer: nu's
    # (W, R, C) buffer is replaced by row stats (W, R, 1) + lane stats
    # (W, 1, C) — v̂ = min(row, lane) bounds nu from above and both stats
    # accumulate the max of the fresh v̂ over their span (Anil et al. 2019)
    # — shrinking second-moment HBM by ~C/1.  adam-only; ignored by
    # sgd/momentum.
    sm3: bool = False
    easgd_alpha: float = 0.3        # elastic coefficient (EASGD baseline)
    # bvr_l_sgd: EMA rate of the bias control variate B (0 disables the
    # correction — the trajectory is then bitwise vrl_sgd)
    bvr_beta: float = 0.5
    # stagewise round schedule (a ``repro.core.schedule.CommSchedule``;
    # stored untyped to keep configs import-free).  None = the constant
    # ``comm_period`` cadence, except stl_sgd which defaults to the
    # stagewise-doubling ramp 1 → comm_period (resolution:
    # ``core.engine.comm_schedule``).  Supersedes ``warmup`` when set.
    comm_schedule: Optional[object] = None
    delta_dtype: str = "float32"    # accumulator dtype for Δ
    # execution backend for the update math over flat buffers:
    #   "fused"     — Pallas kernels (one explicit HBM pass per local step;
    #                 interpret-mode python on backends without Pallas)
    #   "xla"       — the same (W, R, C) elementwise math as plain jnp (XLA
    #                 fuses the chain; no interpret-mode penalty)
    #   "auto"      — fused on TPU/GPU, xla elsewhere (CPU)
    #   "reference" — the per-leaf jax.tree.map oracle path
    # Resolution lives in core.engine.resolve_backend.
    update_backend: str = "auto"    # auto | fused | xla | reference
    engine: EngineConfig = EngineConfig()
    # two-level hierarchical periods/grid (required when algorithm ==
    # "hier_vrl_sgd"; ignored by the flat algorithms)
    hier: Optional[HierConfig] = None
    # sync-payload compression (a ``repro.comm.CompressorSpec``; stored
    # untyped to keep configs import-free).  ``compress`` drives the flat
    # sync (and the hierarchical intra-pod sync1); ``compress2`` overrides
    # the cross-pod sync2 so the slow DCI tier can compress harder, and
    # falls back to ``compress`` when unset.  None / "none" / topk at
    # rate 1 resolve to the uncompressed path, bitwise (resolution:
    # ``repro.comm.compressors.resolve_pair``).
    compress: Optional[object] = None
    compress2: Optional[object] = None
    # overlapped rounds: issue the sync collective at round START over the
    # positions transmitted at the previous round boundary, so the
    # all-reduce runs concurrently with the next round's local steps and
    # its result is folded in one round stale (Δ is already a
    # previous-round quantity, so the staleness rides the existing math).
    # Engine/round-driver only (``round_step``); the per-step ``train_step``
    # path stays blocking.  Hierarchical: overlaps the cross-pod sync2
    # (the slow DCI tier) only; sync1 stays blocking.
    overlap: bool = False
    # straggler deadline: probability in [0, 1] that a participant misses
    # a round's capture deadline (simulated per participant per round —
    # single-host SPMD has no real per-worker clock).  A miss keeps the
    # participant's last transmitted position in the overlap buffer (its
    # stale value is what the next collective averages) and, under
    # compressed sync, parks the missed payload in the EF residual.
    # Requires ``overlap=True``; with compression, requires an
    # error-feedback compressor.  0.0 disables (bitwise no-deadline path).
    deadline: float = 0.0
    # elastic membership: thread an active-worker mask through every sync
    # mean so workers can drop (crash) and rejoin mid-run without poisoning
    # the shared mean.  The state carries a ``MemberState`` (mask + active
    # counts); ``Engine.set_membership`` repairs the invariants on every
    # change (Σ_i Δ_i = 0 over the survivors, rejoiners re-seeded from the
    # current reference point).  With the mask fully active the trajectory
    # is bitwise the membership=False path, and the compiled round still
    # lowers to exactly ONE sync all-reduce (the counts ride in state, no
    # second collective).  Engine backends only; easgd's center update
    # assumes a fixed worker count and refuses the mask.
    membership: bool = False


@dataclass(frozen=True)
class MeshConfig:
    """How the physical mesh is carved up for one run."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    # VRL workers live across these axes (model averaging every k steps).
    worker_axes: Tuple[str, ...] = ("data",)
    # FSDP (param-shard within worker) across these axes.
    fsdp_axes: Tuple[str, ...] = ()
    # tensor-parallel axes.
    tensor_axes: Tuple[str, ...] = ("model",)

    @property
    def num_workers(self) -> int:
        sizes = dict(zip(self.axis_names, self.shape))
        return math.prod(sizes[a] for a in self.worker_axes) if self.worker_axes else 1

    @property
    def tensor_size(self) -> int:
        sizes = dict(zip(self.axis_names, self.shape))
        return math.prod(sizes[a] for a in self.tensor_axes) if self.tensor_axes else 1

    @property
    def fsdp_size(self) -> int:
        sizes = dict(zip(self.axis_names, self.shape))
        return math.prod(sizes[a] for a in self.fsdp_axes) if self.fsdp_axes else 1


SINGLE_POD = MeshConfig()
MULTI_POD = MeshConfig(
    shape=(2, 16, 16),
    axis_names=("pod", "data", "model"),
    worker_axes=("pod", "data"),
    fsdp_axes=(),
    tensor_axes=("model",),
)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    vrl: VRLConfig = field(default_factory=VRLConfig)
    mesh: MeshConfig = field(default_factory=lambda: SINGLE_POD)
    seq_len: int = 4096
    global_batch: int = 256
    steps: int = 100
    seed: int = 0
    remat: bool = True
    param_dtype: str = "bfloat16"


def pad_for_mesh(cfg: ModelConfig, tensor_size: int) -> ModelConfig:
    """Pad head counts / vocab / ff so every tensor-parallel dim divides.

    Padding is mathematically exact: padded q/kv heads are zero-initialised
    and their contribution is annihilated by the o-projection; padded vocab
    rows get -inf-masked logits in the loss. The FLOP overhead is reported by
    the roofline as (useful / compiled) ratio.
    """
    changes = {}
    if cfg.family != "ssm" and cfg.num_heads:
        # q/o projections are sharded over the tensor axis. Padding must
        # preserve the GQA group mapping (q head i -> kv head i // group), so
        # we pad the GROUP size: smallest g' >= g with (kv * g') % tensor == 0.
        # kv heads stay unpadded (replicated across tensor shards when not
        # divisible — standard GQA-TP treatment; kv is cheap). Padded q heads
        # are zero-initialised and annihilated by the o-projection.
        nkv = max(cfg.num_kv_heads, 1)
        g = max(1, cfg.num_heads // nkv)
        while (nkv * g) % tensor_size:
            g += 1
        nh_p = nkv * g
        if nh_p != cfg.num_heads:
            changes["num_heads"] = nh_p
    if cfg.vocab_size % 128:
        changes["vocab_size"] = _next_multiple(cfg.vocab_size, 128)
    if cfg.d_ff and cfg.d_ff % tensor_size:
        changes["d_ff"] = _next_multiple(cfg.d_ff, tensor_size)
    if cfg.moe_d_ff and cfg.moe_d_ff % tensor_size:
        changes["moe_d_ff"] = _next_multiple(cfg.moe_d_ff, tensor_size)
    return dataclasses.replace(cfg, **changes) if changes else cfg


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers, d<=512)."""
    small: dict = dict(num_layers=2, vocab_size=512)
    d = min(cfg.d_model, 128)
    small["d_model"] = d
    if cfg.num_heads:
        nh = min(cfg.num_heads, 4)
        group = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        nkv = max(1, nh // group) if cfg.num_kv_heads < cfg.num_heads else nh
        small.update(num_heads=nh, num_kv_heads=nkv, head_dim=32)
    if cfg.d_ff:
        small["d_ff"] = 4 * d
    if cfg.num_experts:
        small.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                     moe_d_ff=2 * d)
        small["num_shared_experts"] = min(1, cfg.num_shared_experts)
    if cfg.ssm_state:
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32, ssm_chunk=32)
    if cfg.frontend:
        small["frontend_dim"] = d
    small["attn_window"] = None if cfg.attn_window is None else 64
    small["long_context_window"] = 64
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
