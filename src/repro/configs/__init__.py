from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    MULTI_POD,
    SINGLE_POD,
    InputShape,
    MeshConfig,
    ModelConfig,
    TrainConfig,
    VRLConfig,
    pad_for_mesh,
    reduced,
)
