"""StableLM-3B — dense MHA (kv == q heads). [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    vocab_size=50_304,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    mlp_act="silu",
    tie_embeddings=False,
    source="hf:stabilityai/stablelm-2-1_6b",
)
