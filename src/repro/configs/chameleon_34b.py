"""Chameleon-34B — early-fusion VLM, VQ image tokens. [arXiv:2405.09818]

48L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=22016, vocab 65536.
Early fusion means images are VQ-quantized into the *same* token vocabulary,
so the backbone consumes plain token ids; the VQ-VAE image tokenizer is the
(stubbed) modality frontend — input_specs() provides interleaved text+image
token ids directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    vocab_size=65_536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    mlp_act="silu",
    tie_embeddings=False,
    frontend=None,  # VQ tokenizer emits ids into the unified vocab
    source="arXiv:2405.09818 (Chameleon)",
)
