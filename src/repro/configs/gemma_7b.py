"""Gemma-7B — dense, GeGLU, head_dim=256. [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    vocab_size=256_000,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    mlp_act="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma)",
)
