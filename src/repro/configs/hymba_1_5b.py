"""Hymba-1.5B — hybrid: parallel attention + mamba heads. [arXiv:2411.13676]

32L, d_model=1600, 25 q-heads (GQA kv=5, head_dim=64), d_ff=5504,
ssm_state=16. Each block runs attention heads and SSM heads in parallel on
the same input and fuses (mean of the two normalized branch outputs), per the
Hymba paper. Natively sub-quadratic for long-context (attention heads use a
sliding window; Hymba keeps a few global layers — we use windowed attention
for long_500k decode).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    vocab_size=32_001,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    mlp_act="silu",
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_window=1024,
    tie_embeddings=True,
    source="arXiv:2411.13676 (Hymba)",
)
