"""Qwen2-0.5B — dense GQA with QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    vocab_size=151_936,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    qkv_bias=True,
    d_ff=4864,
    mlp_act="silu",
    tie_embeddings=True,
    source="arXiv:2407.10671 (Qwen2)",
)
