"""Kimi K2 — trillion-parameter MoE (paper-table spec). [arXiv:2501.kimi2]

61L, d_model=7168, 64 q-heads (GQA kv=8, head_dim=112), 384 experts top-8
with expert d_ff=2048 + 1 shared expert, vocab 163840.

Assigned spec is GQA (not MLA); we follow the assignment exactly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    vocab_size=163_840,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=0,
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    capacity_factor=1.25,
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (Kimi K2)",
)
