"""Architecture registry: ``--arch <id>`` lookup + mesh-role policy.

Every assigned architecture is selectable by its public id. ``mesh_roles``
decides how VRL-SGD workers map onto the production mesh per arch size:
models whose per-worker replica does not fit 16-way tensor sharding on a
16 GB chip get FSDP within the worker (worker = whole pod).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    MeshConfig,
    ModelConfig,
    pad_for_mesh,
    reduced,
)

from repro.configs import (  # noqa: E402
    chameleon_34b,
    gemma_7b,
    granite_3_2b,
    hymba_1_5b,
    kimi_k2_1t_a32b,
    mamba2_370m,
    musicgen_large,
    phi3_5_moe_42b_a6_6b,
    qwen2_0_5b,
    stablelm_3b,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        kimi_k2_1t_a32b.CONFIG,
        qwen2_0_5b.CONFIG,
        stablelm_3b.CONFIG,
        hymba_1_5b.CONFIG,
        chameleon_34b.CONFIG,
        musicgen_large.CONFIG,
        granite_3_2b.CONFIG,
        mamba2_370m.CONFIG,
        gemma_7b.CONFIG,
        phi3_5_moe_42b_a6_6b.CONFIG,
    ]
}

# Archs too big to replicate one full copy per data-slice (params*2B / 16 TP
# shards must stay well under 16 GB HBM incl. Δ + optimizer state).
_FSDP_ARCHS = {"kimi-k2-1t-a32b", "chameleon-34b", "phi3.5-moe-42b-a6.6b"}
# Serving has no Δ/grads: only the 1T model still needs 2D param sharding.
# FSDP-sharded weights during serving make GSPMD replicate activations over
# the data axis (16x redundant compute) — see EXPERIMENTS.md §Perf pair B.
_FSDP_SERVE_ARCHS = {"kimi-k2-1t-a32b"}


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(shape_id: str) -> InputShape:
    if shape_id not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[shape_id]


def mesh_roles(arch_id: str, *, multi_pod: bool = False,
               serving: bool = False) -> MeshConfig:
    """Assign mesh axes to roles (VRL worker / FSDP / tensor) per arch."""
    big = arch_id in (_FSDP_SERVE_ARCHS if serving else _FSDP_ARCHS)
    if multi_pod:
        return MeshConfig(
            shape=(2, 16, 16),
            axis_names=("pod", "data", "model"),
            worker_axes=("pod",) if big else ("pod", "data"),
            fsdp_axes=("data",) if big else (),
            tensor_axes=("model",),
        )
    return MeshConfig(
        shape=(16, 16),
        axis_names=("data", "model"),
        worker_axes=() if big else ("data",),
        fsdp_axes=("data",) if big else (),
        tensor_axes=("model",),
    )


def padded_arch(arch_id: str, mesh: MeshConfig) -> ModelConfig:
    """Arch config padded for the mesh's tensor-parallel degree."""
    return pad_for_mesh(get_arch(arch_id), mesh.tensor_size)


def smoke_arch(arch_id: str, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    return reduced(get_arch(arch_id), **overrides)


def list_archs() -> list[str]:
    return sorted(ARCHS)


def describe(arch_id: str) -> str:
    c = get_arch(arch_id)
    n = c.param_count()
    na = c.active_param_count()
    extra = f", active={na/1e9:.2f}B" if na != n else ""
    return (f"{c.name} [{c.family}] {c.num_layers}L d={c.d_model} "
            f"params={n/1e9:.2f}B{extra}  ({c.source})")


if __name__ == "__main__":
    # `PYTHONPATH=src python -m repro.configs.registry` — list the pool
    for _a in list_archs():
        print(describe(_a))
