"""MusicGen-large — decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L, d_model=2048, 32H (MHA kv=32, head_dim=64), d_ff=8192, vocab 2048
(EnCodec codebook size). The EnCodec conv codec + codebook-interleaving
(delay pattern) is the stubbed modality frontend: input_specs() provides
precomputed summed-codebook frame embeddings (batch, seq, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    vocab_size=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    mlp_act="gelu",
    tie_embeddings=False,
    frontend="codec",
    frontend_dim=2048,
    source="arXiv:2306.05284 (MusicGen)",
)
