"""Distributed train-step factory.

``make_train_step`` binds (model config, algorithm config) into three jittable
functions over worker-stacked state:

  train_step(state, tokens, labels) -> (state, loss)
      one local iteration + conditional sync (the paper's Algorithm 1 body)
  local_step(state, tokens, labels) -> (state, loss)
      local iteration only — zero worker-axis collectives (dry-run accounting)
  sync_step(state) -> state
      model averaging + Δ update only (the per-period communication event)

Worker parallelism is a ``vmap`` over the leading worker axis; on the
production mesh that axis is sharded over the worker mesh axes so local steps
compile with no cross-worker collectives, which is exactly the property the
paper's communication complexity counts.

Hierarchical (``vrl_cfg.algorithm == "hier_vrl_sgd"``): the worker
population is the pod-major (P, D) grid of ``vrl_cfg.hier`` and the vmap is
doubled over it — tokens still arrive worker-stacked (W, ...) and are folded
to (P, D, ...) here.  ``sync1_step``/``sync2_step`` expose the per-level
syncs (intra-pod / cross-pod) for the dry-run's per-axis collective-bytes
artifacts.

Backend selection: ``vrl_cfg.update_backend``.

  "reference" — tree-structured WorkerState, per-leaf jax.tree.map update.
  "fused"     — flat-buffer engine (core/engine.py): state is a
                FlatWorkerState of contiguous (W, R, C) buffers, the update
                math runs as fused Pallas kernels (one HBM pass per local
                step), and with ``mesh=`` given the sync lowers to a single
                all-reduce of the flat buffer via shard_map.  The model
                forward still sees a normal pytree (engine.params_tree).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, VRLConfig
from repro.core import engine as engine_mod
from repro.core import get_algorithm
from repro.models import transformer
from repro.train.loss import chunked_cross_entropy_lm, cross_entropy_lm


def clip_by_global_norm(grads, max_norm: float):
    """Per-worker global-norm clipping (standard training substrate)."""
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads)


class StepBundle(NamedTuple):
    init_state: callable
    train_step: callable
    local_step: callable
    sync_step: callable
    grads_fn: callable
    average_model: Any = None   # (state,) -> single-model pytree
    engine: Any = None          # core.engine.Engine when backend == "fused"
    sync1_step: Any = None      # hierarchical only: intra-pod sync alone
    sync2_step: Any = None      # hierarchical only: cross-pod sync alone


def make_train_step(model_cfg: ModelConfig, vrl_cfg: VRLConfig,
                    *, remat: bool = True, unroll: int = 1,
                    param_dtype=jnp.float32,
                    chunked_ce: int = 0, mesh=None,
                    worker_axes=("data",)) -> StepBundle:
    """``chunked_ce > 0`` streams the LM loss over vocab chunks of that
    size — the (B, S, V) logits tensor is never materialized (a ~10x-S
    fp32 buffer at 256k vocab).  ``mesh``/``worker_axes`` only affect the
    fused backend (shard_map worker axis for the flat all-reduce)."""
    alg = get_algorithm(vrl_cfg.algorithm)

    def loss_fn(params, tokens, labels):
        if chunked_ce:
            hidden, aux = transformer.forward(model_cfg, params, tokens,
                                              remat=remat, unroll=unroll,
                                              return_hidden=True)
            head = (params["embed"] if model_cfg.tie_embeddings
                    else params["lm_head"])
            loss = chunked_cross_entropy_lm(
                hidden, head, labels, chunk=chunked_ce,
                head_is_embed=model_cfg.tie_embeddings)
        else:
            logits, aux = transformer.forward(model_cfg, params, tokens,
                                              remat=remat, unroll=unroll)
            loss = cross_entropy_lm(logits, labels)
        if model_cfg.num_experts:
            loss = loss + model_cfg.router_aux_loss * aux
        return loss

    def per_worker(params, tokens, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        if vrl_cfg.clip_norm:
            grads = clip_by_global_norm(grads, vrl_cfg.clip_norm)
        return grads, loss

    hier = engine_mod.get_spec(vrl_cfg.algorithm).sync == "vrl2"
    if hier:
        hcfg = engine_mod.hier_config(vrl_cfg)

        def stack_vmap(params, tokens, labels):
            """Pod-major grid: tokens arrive worker-stacked (W, b, s) and
            fold to (P, D, b, s); grads/losses carry (P, D) leading axes."""
            tok = tokens.reshape(hcfg.grid + tokens.shape[1:])
            lab = labels.reshape(hcfg.grid + labels.shape[1:])
            return jax.vmap(jax.vmap(per_worker))(params, tok, lab)
    else:
        def stack_vmap(params, tokens, labels):
            return jax.vmap(per_worker)(params, tokens, labels)

    if vrl_cfg.update_backend == "fused":
        template = jax.eval_shape(functools.partial(
            transformer.init_params, model_cfg, dtype=param_dtype),
            jax.random.PRNGKey(0))
        eng = engine_mod.make_engine(vrl_cfg, template, mesh=mesh,
                                     worker_axes=tuple(worker_axes))

        def grads_fn(state, tokens, labels):
            ptree = eng.params_tree(state)
            grads, losses = stack_vmap(ptree, tokens, labels)
            return grads, jnp.mean(losses)

        def train_step(state, tokens, labels):
            grads, loss = grads_fn(state, tokens, labels)
            return eng.train_step(state, grads), loss

        def local_step(state, tokens, labels):
            grads, loss = grads_fn(state, tokens, labels)
            return eng.local_step(state, grads), loss

        def init_state(key, num_workers: int):
            params = transformer.init_params(model_cfg, key,
                                             dtype=param_dtype)
            return eng.init(params, num_workers)

        return StepBundle(init_state, train_step, local_step, eng.sync,
                          grads_fn, eng.average_model, eng,
                          sync1_step=eng.sync1, sync2_step=eng.sync2)

    def grads_fn(state, tokens, labels):
        grads, losses = stack_vmap(state.params, tokens, labels)
        return grads, jnp.mean(losses)

    def train_step(state, tokens, labels):
        grads, loss = grads_fn(state, tokens, labels)
        return alg.train_step(vrl_cfg, state, grads), loss

    def local_step(state, tokens, labels):
        grads, loss = grads_fn(state, tokens, labels)
        return alg.local_step(vrl_cfg, state, grads), loss

    def sync_step(state):
        return alg.sync(vrl_cfg, state)

    def init_state(key, num_workers: int):
        params = transformer.init_params(model_cfg, key, dtype=param_dtype)
        return alg.init(vrl_cfg, params, num_workers)

    sync1 = sync2 = None
    if hier:
        from repro.core import hierarchical as H
        sync1 = lambda s: H.sync_level1(vrl_cfg, s)       # noqa: E731
        sync2 = lambda s: H.sync_level2(vrl_cfg, s)       # noqa: E731

    return StepBundle(init_state, train_step, local_step, sync_step,
                      grads_fn, alg.average_model,
                      sync1_step=sync1, sync2_step=sync2)
