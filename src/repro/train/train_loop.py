"""Distributed train-step factory.

``make_train_step`` binds (model config, algorithm config) into three jittable
functions over worker-stacked state:

  train_step(state, tokens, labels) -> (state, loss)
      one local iteration + conditional sync (the paper's Algorithm 1 body)
  local_step(state, tokens, labels) -> (state, loss)
      local iteration only — zero worker-axis collectives (dry-run accounting)
  sync_step(state) -> state
      model averaging + Δ update only (the per-period communication event)
  round_step(state, tokens_k, labels_k) -> (state, losses)
      ONE COMMUNICATION ROUND as a single compilation unit: k local steps
      under a ``lax.scan`` over (k, W, ...) token/label stacks — losses
      buffered device-side, no per-step python dispatch or host sync —
      followed by the round-closing sync.  Compiled once per (k, shape);
      jit with ``donate_argnums=(0,)`` so the state updates in place.
      Hierarchical: the round is one k1 period and the level-2 sync fires
      on its k2 cadence inside round_step (requires k2 % k1 == 0).
      Warmup (VRL-SGD-W): the caller sizes the first round k=1
      (``launch/train.py`` does).  Stagewise schedules
      (``vrl_cfg.comm_schedule``): the caller sizes each round from the
      schedule's stage and wraps round_step in ``engine.RoundCache`` so a
      run compiles one executable per distinct k; per-step ``train_step``
      reads the same schedule through ``engine.should_sync``, so the two
      drivers sync at identical steps.

Worker parallelism is a ``vmap`` over the leading worker axis; on the
production mesh that axis is sharded over the worker mesh axes so local steps
compile with no cross-worker collectives, which is exactly the property the
paper's communication complexity counts.

Hierarchical (``vrl_cfg.algorithm == "hier_vrl_sgd"``): the worker
population is the pod-major (P, D) grid of ``vrl_cfg.hier`` and the vmap is
doubled over it — tokens still arrive worker-stacked (W, ...) and are folded
to (P, D, ...) here.  ``sync1_step``/``sync2_step`` expose the per-level
syncs (intra-pod / cross-pod) for the dry-run's per-axis collective-bytes
artifacts.

Backend selection: ``vrl_cfg.update_backend`` (resolved by
``core.engine.resolve_backend``).

  "reference" — tree-structured WorkerState, per-leaf jax.tree.map update.
  "fused"     — flat-buffer engine (core/engine.py): state is a
                FlatWorkerState of contiguous (W, R, C) buffers, the update
                math runs as fused Pallas kernels (one HBM pass per local
                step), and with ``mesh=`` given the sync lowers to a single
                all-reduce of the flat buffer via shard_map.  The model
                forward still sees a normal pytree (engine.params_tree).
  "xla"       — the same flat-buffer engine with the update math as plain
                jnp (kernels/xla_update): XLA fuses the elementwise chain,
                so this is the fast executor where Pallas would interpret.
  "auto"      — fused on TPU/GPU, xla elsewhere (the default).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, VRLConfig
from repro.core import engine as engine_mod
from repro.core import get_algorithm
from repro.core.types import MemberState
from repro.models import transformer
from repro.train.loss import chunked_cross_entropy_lm, cross_entropy_lm


def clip_by_global_norm(grads, max_norm: float):
    """Per-worker global-norm clipping (standard training substrate)."""
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads)


class StepBundle(NamedTuple):
    init_state: callable
    train_step: callable
    local_step: callable
    sync_step: callable
    grads_fn: callable
    average_model: Any = None   # (state,) -> single-model pytree
    engine: Any = None          # core.engine.Engine on the engine backends
    sync1_step: Any = None      # hierarchical only: intra-pod sync alone
    sync2_step: Any = None      # hierarchical only: cross-pod sync alone
    round_step: Any = None      # (state, tokens_k, labels_k) ->
                                #   (state, (k,) losses): one scanned round
    round_step_fault: Any = None  # (state, tokens_k, labels_k, gmul) ->
                                #   (state, losses): round_step with a
                                #   (k, W) per-step/worker gradient
                                #   multiplier (1 = clean; NaN/Inf/scale
                                #   injects a fault on that worker) —
                                #   the chaos harness's entry point
    health: Any = None          # (state, loss) -> () bool: loss finite
                                #   AND every ACTIVE worker's params
                                #   finite (dead rows excluded) — the
                                #   divergence guard's predicate


def make_train_step(model_cfg: ModelConfig, vrl_cfg: VRLConfig,
                    *, remat: bool = True, unroll: int = 1,
                    param_dtype=jnp.float32,
                    chunked_ce: int = 0, mesh=None,
                    worker_axes=("data",)) -> StepBundle:
    """``chunked_ce > 0`` streams the LM loss over vocab chunks of that
    size — the (B, S, V) logits tensor is never materialized (a ~10x-S
    fp32 buffer at 256k vocab).  ``mesh``/``worker_axes`` only affect the
    fused backend (shard_map worker axis for the flat all-reduce)."""
    alg = get_algorithm(vrl_cfg.algorithm)

    def loss_fn(params, tokens, labels):
        if chunked_ce:
            hidden, aux = transformer.forward(model_cfg, params, tokens,
                                              remat=remat, unroll=unroll,
                                              return_hidden=True)
            head = (params["embed"] if model_cfg.tie_embeddings
                    else params["lm_head"])
            loss = chunked_cross_entropy_lm(
                hidden, head, labels, chunk=chunked_ce,
                head_is_embed=model_cfg.tie_embeddings)
        else:
            logits, aux = transformer.forward(model_cfg, params, tokens,
                                              remat=remat, unroll=unroll)
            loss = cross_entropy_lm(logits, labels)
        if model_cfg.num_experts:
            loss = loss + model_cfg.router_aux_loss * aux
        return loss

    def per_worker(params, tokens, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        if vrl_cfg.clip_norm:
            grads = clip_by_global_norm(grads, vrl_cfg.clip_norm)
        return grads, loss

    hier = engine_mod.get_spec(vrl_cfg.algorithm).sync == "vrl2"
    if hier:
        hcfg = engine_mod.hier_config(vrl_cfg)

        def stack_vmap(params, tokens, labels):
            """Pod-major grid: tokens arrive worker-stacked (W, b, s) and
            fold to (P, D, b, s); grads/losses carry (P, D) leading axes."""
            tok = tokens.reshape(hcfg.grid + tokens.shape[1:])
            lab = labels.reshape(hcfg.grid + labels.shape[1:])
            return jax.vmap(jax.vmap(per_worker))(params, tok, lab)
    else:
        def stack_vmap(params, tokens, labels):
            return jax.vmap(per_worker)(params, tokens, labels)

    def _make_round(grads_fn, local_fn, round_end_fn):
        """Round factory shared by all backends: scan k (tokens, labels)
        pairs through local steps, close with the round-ending sync, and
        return the per-step losses as a (k,) device array."""

        def round_step(state, tokens_k, labels_k):
            def body(s, tl):
                grads, loss = grads_fn(s, tl[0], tl[1])
                return local_fn(s, grads), loss

            state, losses = jax.lax.scan(body, state,
                                         (tokens_k, labels_k))
            return round_end_fn(state), losses

        return round_step

    def _grad_mul(grads, m):
        """Scale a worker-stacked grad pytree by a per-worker multiplier
        ``m`` (W,) — folded to the (P, D) grid on the hierarchical path.
        1.0 is a no-op; NaN/Inf poisons that worker's local step exactly
        like a sick accelerator would (clipping already happened, so the
        poison is not renormalized away)."""
        if hier:
            mg = m.reshape(hcfg.grid)
            return jax.tree.map(
                lambda g: g * mg.reshape(mg.shape + (1,) * (g.ndim - 2)
                                         ).astype(g.dtype), grads)
        return jax.tree.map(
            lambda g: g * m.reshape((-1,) + (1,) * (g.ndim - 1)
                                    ).astype(g.dtype), grads)

    def _make_round_fault(grads_fn, local_fn, round_end_fn):
        """Fault-injecting twin of ``_make_round``: the extra ``gmul``
        (k, W) array rides the same scan, so a chaos round compiles to
        the same one-sync program with one fused multiply added."""

        def round_step_fault(state, tokens_k, labels_k, gmul):
            def body(s, tl):
                grads, loss = grads_fn(s, tl[0], tl[1])
                return local_fn(s, _grad_mul(grads, tl[2])), loss

            state, losses = jax.lax.scan(
                body, state, (tokens_k, labels_k, gmul))
            return round_end_fn(state), losses

        return round_step_fault

    backend = engine_mod.resolve_backend(vrl_cfg)
    if backend == "reference" and getattr(vrl_cfg, "membership", False):
        raise ValueError(
            "membership (elastic fault tolerance) needs the flat-buffer "
            "engine's MemberState; update_backend='reference' has none — "
            "use 'auto', 'xla' or 'fused'")
    if backend == "reference" and vrl_cfg.overlap:
        raise ValueError(
            "overlap needs the flat-buffer engine (its double-buffered "
            "pend state); update_backend='reference' has no overlapped "
            "round — use 'auto', 'xla' or 'fused'")
    if backend != "reference":
        template = jax.eval_shape(functools.partial(
            transformer.init_params, model_cfg, dtype=param_dtype),
            jax.random.PRNGKey(0))
        eng = engine_mod.make_engine(vrl_cfg, template, mesh=mesh,
                                     worker_axes=tuple(worker_axes))

        def _loss_mean(state, losses):
            """Mean over ACTIVE workers when elastic membership is on —
            a dead worker's NaN loss must not poison the reported loss
            (or the divergence guard reading it).  Reciprocal-multiply so
            the full-mask program is bitwise ``jnp.mean``."""
            m = getattr(state, "member", ())
            if isinstance(m, MemberState):
                lm = m.active.reshape(losses.shape)
                n = (m.n_active if isinstance(m.n_pod, tuple)
                     else jnp.sum(m.n_pod))
                s = jnp.sum(jnp.where(lm > 0, losses, 0))
                return s * (1.0 / jnp.maximum(n, 1.0))
            return jnp.mean(losses)

        def grads_fn(state, tokens, labels):
            ptree = eng.params_tree(state)
            grads, losses = stack_vmap(ptree, tokens, labels)
            return grads, _loss_mean(state, losses)

        def health(state, loss):
            """() bool: loss finite and every ACTIVE worker's params
            finite.  Dead rows are excluded so a crashed worker's NaNs
            do not trip the guard after its drop."""
            p = state.params
            m = getattr(state, "member", ())
            if isinstance(m, MemberState):
                p = jnp.where(m.active > 0, p, 0)
            return jnp.isfinite(loss) & jnp.all(jnp.isfinite(p))

        def train_step(state, tokens, labels):
            grads, loss = grads_fn(state, tokens, labels)
            return eng.train_step(state, grads), loss

        def local_step(state, tokens, labels):
            grads, loss = grads_fn(state, tokens, labels)
            return eng.local_step(state, grads), loss

        def init_state(key, num_workers: int):
            params = transformer.init_params(model_cfg, key,
                                             dtype=param_dtype)
            return eng.init(params, num_workers)

        if eng.round_begin is not None:
            # overlapped round: issue the sync collective FIRST (over the
            # previous boundary's transmitted positions — no dependency on
            # this round's steps), scan the k local steps, fold the stale
            # result at the end.  Same signature as the blocking round, so
            # RoundCache/benches/drivers are agnostic.
            def round_step(state, tokens_k, labels_k):
                k = jax.tree.leaves(tokens_k)[0].shape[0]
                xbar = eng.round_begin(state, k)

                def body(s, tl):
                    grads, loss = grads_fn(s, tl[0], tl[1])
                    return eng.local_step(s, grads), loss

                state, losses = jax.lax.scan(body, state,
                                             (tokens_k, labels_k))
                return eng.round_fold(state, xbar), losses

            def round_step_fault(state, tokens_k, labels_k, gmul):
                k = jax.tree.leaves(tokens_k)[0].shape[0]
                xbar = eng.round_begin(state, k)

                def body(s, tl):
                    grads, loss = grads_fn(s, tl[0], tl[1])
                    return eng.local_step(s, _grad_mul(grads, tl[2])), loss

                state, losses = jax.lax.scan(
                    body, state, (tokens_k, labels_k, gmul))
                return eng.round_fold(state, xbar), losses
        else:
            round_step = _make_round(grads_fn,
                                     lambda s, g: eng.local_step(s, g),
                                     eng.round_end)
            round_step_fault = _make_round_fault(
                grads_fn, lambda s, g: eng.local_step(s, g), eng.round_end)
        return StepBundle(init_state, train_step, local_step, eng.sync,
                          grads_fn, eng.average_model, eng,
                          sync1_step=eng.sync1, sync2_step=eng.sync2,
                          round_step=round_step,
                          round_step_fault=round_step_fault,
                          health=health)

    def grads_fn(state, tokens, labels):
        grads, losses = stack_vmap(state.params, tokens, labels)
        return grads, jnp.mean(losses)

    def train_step(state, tokens, labels):
        grads, loss = grads_fn(state, tokens, labels)
        return alg.train_step(vrl_cfg, state, grads), loss

    def local_step(state, tokens, labels):
        grads, loss = grads_fn(state, tokens, labels)
        return alg.local_step(vrl_cfg, state, grads), loss

    def sync_step(state):
        return alg.sync(vrl_cfg, state)

    def init_state(key, num_workers: int):
        params = transformer.init_params(model_cfg, key, dtype=param_dtype)
        return alg.init(vrl_cfg, params, num_workers)

    sync1 = sync2 = None
    if hier:
        from repro.core import hierarchical as H
        sync1 = lambda s: H.sync_level1(vrl_cfg, s)       # noqa: E731
        sync2 = lambda s: H.sync_level2(vrl_cfg, s)       # noqa: E731

        def round_end(state):
            if hcfg.k2 % hcfg.k1:
                raise ValueError(
                    f"round execution needs k2 % k1 == 0; got "
                    f"k1={hcfg.k1}, k2={hcfg.k2}")
            state = H.sync_level1(vrl_cfg, state)
            do2 = (state.step - state.last_sync2) >= hcfg.k2
            return jax.lax.cond(
                do2, lambda s: H.sync_level2(vrl_cfg, s),
                lambda s: s, state)
    else:
        round_end = sync_step

    def health(state, loss):
        ok = jnp.isfinite(loss)
        for leaf in jax.tree.leaves(state.params):
            ok = ok & jnp.all(jnp.isfinite(leaf))
        return ok

    round_step = _make_round(grads_fn,
                             lambda s, g: alg.local_step(vrl_cfg, s, g),
                             round_end)
    round_step_fault = _make_round_fault(
        grads_fn, lambda s, g: alg.local_step(vrl_cfg, s, g), round_end)
    return StepBundle(init_state, train_step, local_step, sync_step,
                      grads_fn, alg.average_model,
                      sync1_step=sync1, sync2_step=sync2,
                      round_step=round_step,
                      round_step_fault=round_step_fault,
                      health=health)
