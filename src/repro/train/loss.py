"""Losses: causal LM cross-entropy (fp32 logsumexp) and classifier CE."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_lm(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., S, V) vs next-token labels (..., S) — mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def cross_entropy_cls(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., C) vs labels (...,) — mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def chunked_cross_entropy_lm(hidden: jax.Array, head: jax.Array,
                             labels: jax.Array, chunk: int = 8192,
                             head_is_embed: bool = False) -> jax.Array:
    """Vocab-streaming CE: never materializes the (..., V) logits.

    hidden: (..., S, d) post-final-norm activations;
    head: (d, V) lm head, or (V, d) tied embedding with head_is_embed=True;
    labels: (..., S). Computes a running logsumexp over vocab chunks with a
    lax.scan — peak memory O(S * chunk) instead of O(S * V). At gemma-7b's
    256k vocab this removes a ~10x-seq-length fp32 buffer from the loss.
    """
    if head_is_embed:
        head = head.T                                  # (d, V)
    d, v = head.shape
    pad = (-v) % chunk
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)), constant_values=0.0)
    nv = (v + pad) // chunk
    h32 = hidden.astype(jnp.float32)
    lead = hidden.shape[:-1]

    def body(carry, i):
        m, s, ll = carry
        w_c = jax.lax.dynamic_slice_in_dim(head, i * chunk, chunk, axis=1)
        logits_c = h32 @ w_c.astype(jnp.float32)       # (..., chunk)
        if pad:  # mask padded vocab rows
            col = i * chunk + jnp.arange(chunk)
            logits_c = jnp.where(col < v, logits_c, -1e30)
        m_c = jnp.max(logits_c, axis=-1)
        m_new = jnp.maximum(m, m_c)
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits_c - m_new[..., None]), axis=-1)
        # label logit if it falls in this chunk
        in_chunk = (labels >= i * chunk) & (labels < (i + 1) * chunk)
        idx = jnp.clip(labels - i * chunk, 0, chunk - 1)
        lab_logit = jnp.take_along_axis(logits_c, idx[..., None], -1)[..., 0]
        ll = jnp.where(in_chunk, lab_logit, ll)
        return (m_new, s, ll), None

    init = (jnp.full(lead, -1e30, jnp.float32),
            jnp.zeros(lead, jnp.float32),
            jnp.zeros(lead, jnp.float32))
    (m, s, ll), _ = jax.lax.scan(body, init, jnp.arange(nv))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    return jnp.mean(lse - ll)
