from repro.train.loss import accuracy, cross_entropy_cls, cross_entropy_lm  # noqa: F401
from repro.train.train_loop import StepBundle, make_train_step  # noqa: F401
