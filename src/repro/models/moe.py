"""Top-k routed mixture-of-experts MLP.

Dispatch is capacity-bounded and sort-based (no (tokens, experts, capacity)
one-hot einsum — at 384 experts that intermediate would be ~3e10 elements).
Tokens are scattered into an (experts, capacity, d) buffer, experts run as a
single batched matmul (expert-parallel: the leading E axis is tensor-sharded),
and results are combined back with router weights. Overflowing tokens are
dropped (standard capacity-factor semantics); the residual path keeps them
intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef
from repro.sharding.constrain import maybe_constrain


def moe_defs(cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        # expert weights use dedicated logical axes: 2D-sharded as
        # (experts -> tensor, expert_ff -> fsdp). Sharding the ff dim (not
        # d) keeps the gate/up matmuls collective-free and leaves one
        # (E, C, d) partial-sum all-reduce on the down-projection — vs
        # FSDP-on-d which all-reduces the (E, C, ff) hiddens every matmul.
        "w_gate": ParamDef((e, d, ff), ("experts", "expert_embed", "expert_ff")),
        "w_up": ParamDef((e, d, ff), ("experts", "expert_embed", "expert_ff")),
        "w_down": ParamDef((e, ff, d), ("experts", "expert_ff", "expert_embed")),
    }
    if cfg.num_shared_experts:
        s = cfg.num_shared_experts
        defs["shared_w_gate"] = ParamDef((d, s * ff), ("embed", "ff"))
        defs["shared_w_up"] = ParamDef((d, s * ff), ("embed", "ff"))
        defs["shared_w_down"] = ParamDef((s * ff, d), ("ff", "embed"))
    return defs


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(num_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def route(cfg: ModelConfig, router: jax.Array, x: jax.Array):
    """x: (T, d) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                # (E,)
    one_hot = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(fe * me)
    return weights, ids, aux


def moe_mlp(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (T, d) -> (y (T, d), aux_loss). Caller flattens batch*seq."""
    t, d = x.shape
    k = cfg.experts_per_token
    cap = capacity(cfg, t)
    weights, ids, aux = route(cfg, p["router"], x)

    flat_e = ids.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    # position of each entry within its expert's block
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first
    keep = pos < cap
    token = order // k

    # scatter tokens into the expert buffer (dropped tokens -> slot cap-1,
    # masked to zero so they contribute nothing)
    safe_pos = jnp.where(keep, pos, cap - 1)
    xk = x[token] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((cfg.num_experts, cap, d), x.dtype)
    buf = buf.at[sorted_e, safe_pos].add(xk)                  # (E, C, d)
    # Expert-parallel on E. The second sharding axis depends on scale:
    #   train (capacity large, divisible): shard CAPACITY over "data" so the
    #     expert matmuls stay collective-free and GSPMD FSDP-gathers the
    #     2D-sharded weights per layer (~2 GiB/layer on kimi-k2) instead of
    #     all-reducing (E, C, ff) hiddens (~127 GiB/layer at train capacity);
    #   decode (capacity tiny): co-shard d with the weights' FSDP axis so
    #     the contraction partial-sums a few-MB tensor.
    # maybe_constrain no-ops when the dim doesn't divide the axis.
    # Expert-parallel on E; d co-sharded with the weights' FSDP axis so the
    # contractions partial-sum. Best-known GSPMD layout for both decode and
    # train: the gather/scatter dispatch poisons sharding propagation for
    # every alternative we measured (EXPERIMENTS.md §Perf pair C — the
    # structural fix is a shard_map all-to-all dispatch, documented there).
    espec = ("model", None, "data")
    buf = maybe_constrain(buf, *espec)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = maybe_constrain(h, "model", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E, C, d)
    out_buf = maybe_constrain(out_buf, *espec)

    contrib = out_buf[sorted_e, safe_pos] * keep[:, None].astype(x.dtype)
    w = weights.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token].add(contrib * w[:, None])

    if cfg.num_shared_experts:
        hs = jax.nn.silu(x @ p["shared_w_gate"]) * (x @ p["shared_w_up"])
        y = y + hs @ p["shared_w_down"]
    return y, aux
