"""Parameter definition substrate.

Models declare their parameters once as a pytree of :class:`ParamDef`
(shape + logical axis names + initializer). From that single declaration we
derive:

  * materialized parameters  (``materialize``)
  * ``PartitionSpec`` trees  (``repro.sharding.specs.partition_specs``)
  * ``ShapeDtypeStruct`` trees for dry-runs (no allocation)

Logical axis vocabulary (mapped to mesh axes by sharding rules):
  layers   — stacked-layer leading axis (never sharded)
  worker   — VRL worker leading axis (sharded over worker mesh axes)
  vocab    — vocabulary rows (tensor-sharded, Megatron-style)
  embed    — the d_model dimension (FSDP-sharded when enabled)
  heads    — q/o attention head dim (tensor-sharded)
  kv_heads — k/v head dim (tensor-sharded only when divisible)
  ff       — MLP hidden (tensor-sharded)
  experts  — MoE expert dim (tensor-sharded = expert parallel)
  ssm_inner— SSD inner channels (tensor-sharded)
  null     — never sharded
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: Optional[float] = None  # stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _init_one(d: ParamDef, key: jax.Array, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, d.shape)).astype(dtype)


def materialize(defs, key: jax.Array, dtype=jnp.float32):
    """Materialize a ParamDef pytree into arrays with split PRNG keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree (for .lower() without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading axis (layers / worker) to every leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), (axis_name, *d.axes), d.init, d.scale),
        defs, is_leaf=is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def map_defs(fn: Callable[[ParamDef], Any], defs):
    return jax.tree.map(fn, defs, is_leaf=is_def)
