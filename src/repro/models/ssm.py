"""Mamba2 SSD (state-space duality) block: chunked training forward and
O(1)-state decode.

Layout notes
  d_inner = expand * d_model, heads h = d_inner / ssm_head_dim, state n.
  B, C are shared across heads (n_groups = 1, as in Mamba2 small configs).
  The inner channel dim is tensor-shardable ("ssm_inner"); B/C/dt projections
  are small and stay replicated.

Recurrence (discrete):
  a_t     = exp(dt_t * A_h)                      (per head)
  S_t     = a_t * S_{t-1} + dt_t * x_t ⊗ B_t     (S: (h, p, n))
  y_t     = S_t · C_t + D_h * x_t

Training uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state carry via lax.scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef


def ssm_defs(cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    h, k = cfg.ssm_num_heads, cfg.conv_kernel
    return {
        "wz": ParamDef((d, di), ("embed", "ssm_inner")),
        "wx": ParamDef((d, di), ("embed", "ssm_inner")),
        "wB": ParamDef((d, n), ("embed", None)),
        "wC": ParamDef((d, n), ("embed", None)),
        "wdt": ParamDef((d, h), ("embed", None)),
        "conv_x": ParamDef((k, di), (None, "ssm_inner"), scale=0.5),
        "conv_B": ParamDef((k, n), (None, None), scale=0.5),
        "conv_C": ParamDef((k, n), (None, None), scale=0.5),
        "A_log": ParamDef((h,), (None,), init="zeros"),
        "D": ParamDef((h,), (None,), init="ones"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "norm_w": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b, l, c); w: (K, c)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    l = x.shape[1]
    out = sum(pad[:, i:i + l, :] * w[i] for i in range(k))
    return jax.nn.silu(out)


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = y.dtype
    y = (y * jax.nn.silu(z)).astype(jnp.float32)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                state_in: jax.Array | None = None,
                return_state: bool = False):
    """Chunked SSD scan.

    x: (B, L, h, p)   dt: (B, L, h)   a_log: (h,)  (A = -exp(a_log))
    b, c: (B, L, n)   chunk: Q, must divide L.
    Returns y: (B, L, h, p) [, final_state (B, h, p, n)].
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = chunk
    nc = l // q
    assert nc * q == l, (l, q)
    f32 = jnp.float32

    xd = (x * dt[..., None]).astype(f32)                 # dt folded into x
    la = dt.astype(f32) * (-jnp.exp(a_log.astype(f32)))  # (B, L, h) log-decay
    xd = xd.reshape(bsz, nc, q, h, p)
    la = la.reshape(bsz, nc, q, h)
    bc = b.astype(f32).reshape(bsz, nc, q, n)
    cc = c.astype(f32).reshape(bsz, nc, q, n)

    cum = jnp.cumsum(la, axis=2)                         # (B, nc, q, h)
    # --- intra-chunk (quadratic in q) --------------------------------------
    # decay[i, j] = exp(cum_i - cum_j) for j <= i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B, nc, i, j, h)
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, seg, -1e30))  # finite: NaN-safe gradients
    cb = jnp.einsum("bzin,bzjn->bzij", cc, bc)           # (B, nc, i, j)
    y_intra = jnp.einsum("bzij,bzijh,bzjhp->bzihp", cb, decay, xd)

    # --- chunk summary states ----------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B, nc, q, h)
    s_chunk = jnp.einsum("bzqn,bzqh,bzqhp->bzhpn", bc, decay_to_end, xd)
    lam = jnp.exp(cum[:, :, -1, :])                      # (B, nc, h) chunk decay

    # --- inter-chunk recurrence (scan over chunks) --------------------------
    if state_in is None:
        state_in = jnp.zeros((bsz, h, p, n), f32)

    def step(carry, inp):
        s_c, lam_c = inp                                  # (B,h,p,n), (B,h)
        out = carry                                       # state entering chunk
        new = lam_c[..., None, None] * carry + s_c
        return new, out

    s_swapped = jnp.moveaxis(s_chunk, 1, 0)               # (nc, B, h, p, n)
    lam_swapped = jnp.moveaxis(lam, 1, 0)                 # (nc, B, h)
    final_state, states_in = jax.lax.scan(step, state_in, (s_swapped, lam_swapped))
    states_in = jnp.moveaxis(states_in, 0, 1)             # (B, nc, h, p, n)

    # --- inter-chunk contribution -------------------------------------------
    decay_from_start = jnp.exp(cum)                       # (B, nc, q, h)
    y_inter = jnp.einsum("bzqn,bzhpn,bzqh->bzqhp", cc, states_in, decay_from_start)

    y = (y_intra + y_inter).reshape(bsz, l, h, p).astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssm_forward(cfg: ModelConfig, p: dict, u: jax.Array,
                return_cache: bool = False):
    """Full-sequence forward. u: (B, L, d_model)."""
    bsz, l, _ = u.shape
    h, hd = cfg.ssm_num_heads, cfg.ssm_head_dim
    z = u @ p["wz"]
    x_in, b_in, c_in = u @ p["wx"], u @ p["wB"], u @ p["wC"]
    x = _causal_conv(x_in, p["conv_x"])
    b = _causal_conv(b_in, p["conv_B"])
    c = _causal_conv(c_in, p["conv_C"])
    dt = jax.nn.softplus(u @ p["wdt"] + p["dt_bias"])     # (B, L, h)
    xh = x.reshape(bsz, l, h, hd)
    chunk = min(cfg.ssm_chunk, l)
    pad = (-l) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        cp = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    else:
        dtp, bp, cp = dt, b, c
    y = ssd_chunked(xh, dtp, p["A_log"], bp, cp, chunk,
                    return_state=return_cache)
    if return_cache:
        y, final_state = y
    if pad:
        y = y[:, :l]
        xh = xh[:, :l]
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, l, h * hd)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_cache:
        k = cfg.conv_kernel
        xbc = jnp.concatenate([x_in, b_in, c_in], -1)
        pad_w = jnp.pad(xbc, ((0, 0), (max(0, k - 1 - l), 0), (0, 0)))
        conv_window = pad_w[:, -(k - 1):, :]
        # NOTE: final_state from the padded scan includes zero-contribution
        # padding steps (dt-weighted x is zero there only if inputs were
        # zero-padded — dt padding is zero so decay exp(0)=1 and no update
        # from B=0? B padded zero => outer product zero; decay exp(dt*A)=1
        # since dt=0. So padding steps are exact no-ops. Safe.)
        return out, {"state": final_state,
                     "conv": conv_window.astype(out.dtype)}
    return out


# ---------------------------------------------------------------- decode
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    h, hd, n, k = (cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state,
                   cfg.conv_kernel)
    return {
        "state": jnp.zeros((batch, h, hd, n), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, cfg.ssm_d_inner + 2 * n), dtype),
    }


def ssm_decode_step(cfg: ModelConfig, p: dict, u: jax.Array, cache: dict):
    """One-token decode. u: (B, 1, d_model). Returns (y (B,1,d), cache)."""
    bsz = u.shape[0]
    h, hd, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    u0 = u[:, 0]
    z = u0 @ p["wz"]
    xbc = jnp.concatenate([u0 @ p["wx"], u0 @ p["wB"], u0 @ p["wC"]], -1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], -1)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :].astype(cache["conv"].dtype)], 1)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, conv_w))
    di = cfg.ssm_d_inner
    x, b, c = conv_out[:, :di], conv_out[:, di:di + n], conv_out[:, di + n:]
    dt = jax.nn.softplus(u0 @ p["wdt"] + p["dt_bias"]).astype(jnp.float32)  # (B, h)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"].astype(jnp.float32))))            # (B, h)
    xh = x.reshape(bsz, h, hd).astype(jnp.float32)
    outer = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], b.astype(jnp.float32))
    state = a[..., None, None] * cache["state"] + outer
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, h * hd).astype(u.dtype)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    y = (y @ p["out_proj"])[:, None, :]
    return y, {"state": state, "conv": window[:, 1:]}
