"""Shared low-level layers: RMSNorm, RoPE, gated MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef


# ----------------------------------------------------------------- rms norm
def rms_norm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- mlp
def mlp_defs(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_act in ("silu", "geglu"):
        return {
            "w_gate": ParamDef((d, ff), ("embed", "ff")),
            "w_up": ParamDef((d, ff), ("embed", "ff")),
            "w_down": ParamDef((ff, d), ("ff", "embed")),
        }
    return {
        "w_up": ParamDef((d, ff), ("embed", "ff")),
        "w_down": ParamDef((ff, d), ("ff", "embed")),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]
