"""Model assembly: embedding -> scan-over-layers blocks -> norm -> logits.

One code path serves all six families:
  dense / vlm / audio : attn + gated MLP blocks
  moe                 : attn + routed-expert MLP (aux loss threaded through scan)
  ssm                 : Mamba2 SSD blocks (no MLP, as in Mamba2)
  hybrid              : parallel attn+SSM block + MLP

Layer params are stacked on a leading "layers" axis and executed with
``lax.scan`` (keeps HLO size O(1) in depth — essential for compiling the
61-layer / 1T-param configs). ``remat=True`` wraps the block in
``jax.checkpoint`` for training.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, hybrid, moe, ssm
from repro.models.layers import mlp, mlp_defs, rms_norm, rms_norm_def
from repro.models.param import ParamDef, materialize, stack_defs
from repro.sharding.constrain import maybe_constrain


# --------------------------------------------------------------------- defs
def layer_defs(cfg: ModelConfig) -> dict:
    d = {}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        d["attn"] = attention.attention_defs(cfg)
        d["norm1"] = rms_norm_def(cfg.d_model)
        d["norm2"] = rms_norm_def(cfg.d_model)
        if cfg.family == "moe":
            d["moe"] = moe.moe_defs(cfg)
        else:
            d["mlp"] = mlp_defs(cfg)
    elif cfg.family == "ssm":
        d["ssm"] = ssm.ssm_defs(cfg)
        d["norm1"] = rms_norm_def(cfg.d_model)
    elif cfg.family == "hybrid":
        d["hyb"] = hybrid.hybrid_defs(cfg)
        d["norm1"] = rms_norm_def(cfg.d_model)
        d["norm2"] = rms_norm_def(cfg.d_model)
        d["mlp"] = mlp_defs(cfg)
    else:
        raise ValueError(cfg.family)
    return d


def model_defs(cfg: ModelConfig) -> dict:
    defs = {
        "layers": stack_defs(layer_defs(cfg), cfg.num_layers, "layers"),
        "final_norm": rms_norm_def(cfg.d_model),
    }
    if cfg.frontend == "codec":
        defs["frontend_proj"] = ParamDef(
            (cfg.frontend_dim, cfg.d_model), (None, "embed"))
    defs["embed"] = ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                             scale=0.02)
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), scale=0.02)
    return defs


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return materialize(model_defs(cfg), key, dtype)


# ------------------------------------------------------------------- blocks
def _block(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
           window: Optional[int]):
    """One layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        x = x + attention.attend_full(
            cfg, p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), positions,
            window=window if window is not None else cfg.attn_window)
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            bsz, s, d = h.shape
            y, aux = moe.moe_mlp(cfg, p["moe"], h.reshape(bsz * s, d))
            x = x + y.reshape(bsz, s, d)
        else:
            x = x + mlp(cfg, p["mlp"], h)
    elif cfg.family == "ssm":
        x = x + ssm.ssm_forward(cfg, p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps))
    elif cfg.family == "hybrid":
        x = x + hybrid.hybrid_forward(
            cfg, p["hyb"], rms_norm(x, p["norm1"], cfg.norm_eps), positions,
            window=window)
        x = x + mlp(cfg, p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
    if cfg.seq_shard_acts and x.shape[-2] > 1:
        # Megatron-style sequence parallelism: the residual stream lives
        # seq-sharded on the tensor axis; XLA turns the surrounding
        # all-reduces into reduce-scatter + all-gather pairs and runs
        # norms/elementwise on 1/TP of the tokens.
        x = maybe_constrain(x, None, "model", None)
    return x, aux


def embed_inputs(cfg: ModelConfig, params: dict, inputs: jax.Array) -> jax.Array:
    """Token ids (B, S) int -> embeddings; or frontend embeddings pass-through."""
    if cfg.frontend == "codec":
        # stub modality frontend: inputs are precomputed frame embeddings
        return inputs @ params["frontend_proj"]
    return params["embed"][inputs]


def logits_out(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"])
    return x @ params["lm_head"]


def forward(cfg: ModelConfig, params: dict, inputs: jax.Array,
            positions: Optional[jax.Array] = None,
            window: Optional[int] = None, remat: bool = False,
            unroll: int = 1, return_hidden: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss) — or the
    post-final-norm hidden states with ``return_hidden`` (for the
    vocab-streaming chunked-CE loss, which never materializes logits)."""
    x = embed_inputs(cfg, params, inputs)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[-2]), x.shape[:-1])

    def body(carry, layer_p):
        h, aux = carry
        h, a = _block(cfg, layer_p, h, positions, window)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=unroll)
    if return_hidden:
        return rms_norm(x, params["final_norm"], cfg.norm_eps), \
            aux / cfg.num_layers
    return logits_out(cfg, params, x), aux / cfg.num_layers


# ------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, window: Optional[int] = None):
    """Per-layer caches stacked on a leading layer axis."""
    eff_len = min(cache_len, window) if window is not None else cache_len

    def one_layer():
        if cfg.family == "ssm":
            return ssm.init_ssm_cache(cfg, batch, dtype)
        if cfg.family == "hybrid":
            w = window if window is not None else cfg.attn_window
            alen = min(cache_len, w) if w else cache_len
            return hybrid.init_hybrid_cache(cfg, batch, alen, dtype)
        return attention.init_kv_cache(cfg, batch, eff_len, dtype)

    layer = one_layer()
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.num_layers, *leaf.shape)).copy(),
        layer)


def decode_step(cfg: ModelConfig, params: dict, cache, inputs: jax.Array,
                pos, window: Optional[int] = None, unroll: int = 1):
    """One-token decode against a cache. inputs: (B, 1) ids or (B, 1, F) embeds.

    Returns (logits (B, 1, V), new_cache).
    """
    x = embed_inputs(cfg, params, inputs)

    def body(h, scanned):
        layer_p, layer_c = scanned
        if cfg.family == "ssm":
            y, c = ssm.ssm_decode_step(
                cfg, layer_p["ssm"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                layer_c)
            h = h + y
        elif cfg.family == "hybrid":
            y, c = hybrid.hybrid_decode_step(
                cfg, layer_p["hyb"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                layer_c, pos, window=window)
            h = h + y
            h = h + mlp(cfg, layer_p["mlp"],
                        rms_norm(h, layer_p["norm2"], cfg.norm_eps))
        else:
            y, c = attention.decode_attend(
                cfg, layer_p["attn"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                layer_c, pos,
                window=window if window is not None else cfg.attn_window)
            h = h + y
            hh = rms_norm(h, layer_p["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                bsz, s, d = hh.shape
                ymoe, _ = moe.moe_mlp(cfg, layer_p["moe"], hh.reshape(bsz * s, d))
                h = h + ymoe.reshape(bsz, s, d)
            else:
                h = h + mlp(cfg, layer_p["mlp"], hh)
        return h, c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                unroll=unroll)
    return logits_out(cfg, params, x), new_cache


def prefill(cfg: ModelConfig, params: dict, inputs: jax.Array,
            cache_len: int, window: Optional[int] = None, unroll: int = 1,
            last_only: bool = False):
    """Cache-building prefill: full-sequence forward that also emits the
    decode cache (KV / SSM state / conv window) for every layer, stacked on
    the layer axis by the scan itself.

    Returns (logits (B, S, V), cache) — cache is layout-compatible with
    ``init_cache``/``decode_step``.
    """
    x = embed_inputs(cfg, params, inputs)
    positions = jnp.broadcast_to(jnp.arange(x.shape[-2]), x.shape[:-1])
    eff_window = window if window is not None else cfg.attn_window

    def body(h, layer_p):
        if cfg.family == "ssm":
            y, c = ssm.ssm_forward(
                cfg, layer_p["ssm"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                return_cache=True)
            h = h + y
            if cfg.seq_shard_acts and h.shape[-2] > 1:
                h = maybe_constrain(h, None, "model", None)
            return h, c
        if cfg.family == "hybrid":
            y, c = hybrid.hybrid_forward(
                cfg, layer_p["hyb"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                positions, window=window, return_cache=True,
                cache_len=cache_len)
            h = h + y
            h = h + mlp(cfg, layer_p["mlp"],
                        rms_norm(h, layer_p["norm2"], cfg.norm_eps))
            return h, c
        y, kv = attention.attend_full(
            cfg, layer_p["attn"], rms_norm(h, layer_p["norm1"], cfg.norm_eps),
            positions, window=eff_window, return_kv=True)
        c = attention.prefill_kv_cache(cfg, kv, cache_len, eff_window, h.dtype)
        h = h + y
        hh = rms_norm(h, layer_p["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            bsz, s, d = hh.shape
            ymoe, _ = moe.moe_mlp(cfg, layer_p["moe"], hh.reshape(bsz * s, d))
            h = h + ymoe.reshape(bsz, s, d)
        else:
            h = h + mlp(cfg, layer_p["mlp"], hh)
        if cfg.seq_shard_acts and h.shape[-2] > 1:
            h = maybe_constrain(h, None, "model", None)
        return h, c

    x, cache = jax.lax.scan(body, x, params["layers"], unroll=unroll)
    if last_only:
        # serving only needs the next-token distribution: computing logits
        # for every prefill position would be a (B, S, V) tensor — at 32k x
        # 64k-vocab that is ~10^2 GB of matmul + memory for nothing.
        x = x[..., -1:, :]
    return logits_out(cfg, params, x), cache
