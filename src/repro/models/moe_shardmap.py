"""shard_map MoE dispatch — the structural fix for the GSPMD limitation
measured in EXPERIMENTS.md §Perf pair C.

GSPMD cannot partition the sort/scatter dispatch against 2D-sharded expert
weights (it replicates via "involuntary full rematerialization"). Under
``shard_map`` the dispatch is LOCAL by construction:

  mesh axes: tokens sharded over "data", experts sharded over "model",
  expert weights stored 2D-sharded (E -> model, d -> data).

  per (data j, model i) device:
    1. all_gather its expert shard's weights over "data"  (FSDP gather,
       ~2.1 GiB/layer on kimi-k2 — amortizable/overlappable)
    2. route its LOCAL tokens; keep only assignments to its LOCAL experts
       (expected T_loc * k / model_size of them)
    3. sort/scatter dispatch entirely locally (no cross-shard scatter!)
    4. psum the partial outputs over "model" (each token's k experts live
       on specific shards)  — (T_loc, d) bf16 per layer.

Per-layer collective bytes on kimi-k2 train_4k (T_loc = 65536):
  3 x 2.1 GiB weight AG + 0.94 GiB psum  ≈ 3 GiB  vs the GSPMD baseline's
  ~127 GiB of hidden-state all-reduce — the napkin ~40x reduction that the
  §Perf pair-C iterations could not reach with constraint steering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import moe as moe_ref


def _local_dispatch_compute(cfg: ModelConfig, x: jax.Array,
                            weights: jax.Array, ids: jax.Array,
                            wg: jax.Array, wu: jax.Array, wd: jax.Array,
                            e_loc: int, shard: jax.Array) -> jax.Array:
    """Dispatch the local tokens to this shard's e_loc experts and compute.

    x: (T_loc, d); weights/ids: (T_loc, k) GLOBAL routing decisions;
    wg/wu: (e_loc, d, ff); wd: (e_loc, ff, d). Returns the PARTIAL output
    (T_loc, d) covering only the local experts (psum over "model" outside).
    """
    t, d = x.shape
    k = cfg.experts_per_token
    cap = moe_ref.capacity(cfg, t)

    flat_e = ids.reshape(-1)
    is_local = (flat_e // e_loc) == shard
    local_e = jnp.where(is_local, flat_e - shard * e_loc, e_loc)  # e_loc = drop

    order = jnp.argsort(local_e)                      # non-local sort last
    sorted_e = local_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first
    keep = (sorted_e < e_loc) & (pos < cap)
    token = order // k

    safe_e = jnp.where(keep, sorted_e, 0)
    safe_pos = jnp.where(keep, pos, cap - 1)
    xk = x[token] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e_loc, cap, d), x.dtype).at[safe_e, safe_pos].add(xk)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)

    contrib = out_buf[safe_e, safe_pos] * keep[:, None].astype(x.dtype)
    w = weights.reshape(-1)[order].astype(x.dtype)
    return jnp.zeros((t, d), x.dtype).at[token].add(contrib * w[:, None])


def moe_mlp_shardmap(cfg: ModelConfig, p: dict, x: jax.Array, mesh,
                     data_axis: str = "data", model_axis: str = "model"):
    """Drop-in MoE layer under explicit shard_map.

    x: (T, d) global; expert weights 2D-sharded (E->model, d->data);
    router replicated. Returns (y (T, d), aux).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    e_loc = cfg.num_experts // sizes[model_axis]

    def block(x_loc, router, wg, wu, wd):
        # weights arrive d-sharded: FSDP-gather over the data axis
        wg = jax.lax.all_gather(wg, data_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, data_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, data_axis, axis=2, tiled=True)
        weights, ids, aux = moe_ref.route(cfg, router, x_loc)
        shard = jax.lax.axis_index(model_axis)
        y_part = _local_dispatch_compute(cfg, x_loc, weights, ids,
                                         wg, wu, wd, e_loc, shard)
        y = jax.lax.psum(y_part, model_axis)
        aux = jax.lax.pmean(aux, data_axis)
        return y, aux

    fn = compat.shard_map(
        block, mesh=mesh,
        in_specs=(P(data_axis, None),            # tokens
                  P(None, None),                 # router (replicated)
                  P(model_axis, data_axis, None),  # w_gate
                  P(model_axis, data_axis, None),  # w_up
                  P(model_axis, None, data_axis)),  # w_down
        out_specs=(P(data_axis, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
