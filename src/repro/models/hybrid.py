"""Hymba-style hybrid block: attention heads and SSM heads run in parallel
on the same input, their (individually normalized) outputs are averaged.
[arXiv:2411.13676 §2]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, ssm
from repro.models.layers import rms_norm, rms_norm_def
from repro.models.param import ParamDef


def hybrid_defs(cfg: ModelConfig) -> dict:
    return {
        "attn": attention.attention_defs(cfg),
        "ssm": ssm.ssm_defs(cfg),
        "attn_out_norm": rms_norm_def(cfg.d_model),
        "ssm_out_norm": rms_norm_def(cfg.d_model),
        # learnable fusion scale (Hymba's beta)
        "fuse_beta": ParamDef((2,), (None,), init="ones"),
    }


def _fuse(p: dict, ya: jax.Array, ys: jax.Array, eps: float) -> jax.Array:
    ya = rms_norm(ya, p["attn_out_norm"], eps)
    ys = rms_norm(ys, p["ssm_out_norm"], eps)
    beta = p["fuse_beta"].astype(ya.dtype)
    return 0.5 * (beta[0] * ya + beta[1] * ys)


def hybrid_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array, window=None,
                   return_cache: bool = False, cache_len: int = 0):
    w = window if window is not None else cfg.attn_window
    ya = attention.attend_full(cfg, p["attn"], x, positions, window=w,
                               return_kv=return_cache)
    ys = ssm.ssm_forward(cfg, p["ssm"], x, return_cache=return_cache)
    if return_cache:
        ya, kv = ya
        ys, ssm_cache = ys
        alen = min(cache_len, w) if w else cache_len
        attn_cache = attention.prefill_kv_cache(cfg, kv, alen, w, x.dtype)
        return _fuse(p, ya, ys, cfg.norm_eps), {"attn": attn_cache,
                                                "ssm": ssm_cache}
    return _fuse(p, ya, ys, cfg.norm_eps)


def init_hybrid_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return {
        "attn": attention.init_kv_cache(cfg, batch, cache_len, dtype),
        "ssm": ssm.init_ssm_cache(cfg, batch, dtype),
    }


def hybrid_decode_step(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                       pos, window=None):
    w = window if window is not None else cfg.attn_window
    ya, kv = attention.decode_attend(cfg, p["attn"], x, cache["attn"], pos, window=w)
    ys, st = ssm.ssm_decode_step(cfg, p["ssm"], x, cache["ssm"])
    return _fuse(p, ya, ys, cfg.norm_eps), {"attn": kv, "ssm": st}
