"""GQA attention: training/prefill forward and KV-cache decode.

Cache layouts
  full window : k/v (batch, seq_len, kv_heads, head_dim), append at position
  sliding     : same shape with seq_len = window, ring-buffer writes

Numerics: QK^T and softmax in fp32, PV in input dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.models.param import ParamDef

NEG_INF = -1e30


def attention_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
    return defs


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    q = jnp.einsum("...sd,dhk->...shk", x, p["wq"])
    k = jnp.einsum("...sd,dhk->...shk", x, p["wk"])
    v = jnp.einsum("...sd,dhk->...shk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _repeat_kv(x: jax.Array, group: int) -> jax.Array:
    """(..., s, kv, hd) -> (..., s, kv*group, hd)"""
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=-2)


def attend_full(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array,
                window: Optional[int] = None,
                return_kv: bool = False):
    """Training / prefill attention over a full sequence.

    x: (..., seq, d_model); positions: (..., seq) absolute positions.
    With ``return_kv`` also returns the roped (k, v) for cache prefill.
    """
    group = cfg.num_heads // cfg.num_kv_heads
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kv_cache = (k, v) if return_kv else None
    k = _repeat_kv(k, group)
    v = _repeat_kv(v, group)

    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("...qhk,...shk->...hqs", q, k).astype(jnp.float32) * scale
    qi = positions[..., None, :, None]   # (..., 1, q, 1)
    ki = positions[..., None, None, :]   # (..., 1, 1, s)
    mask = ki <= qi                      # (..., 1, q, s) broadcast over heads
    if window is not None:
        mask = mask & (ki > qi - window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("...hqs,...shk->...qhk", probs, v)
    out = jnp.einsum("...qhk,hkd->...qd", out, p["wo"])
    if return_kv:
        return out, kv_cache
    return out


def prefill_kv_cache(cfg: ModelConfig, kv, cache_len: int,
                     window: Optional[int], dtype):
    """Build a decode cache from prefill (k, v): (b, s, kvh, hd).

    For windowed attention the cache is a ring buffer of size ``window``
    whose slot layout matches ``decode_attend`` (slot = pos % window).
    """
    k, v = kv
    b, s = k.shape[0], k.shape[1]
    if window is not None:
        cache = init_kv_cache(cfg, b, window, dtype)
        take = min(window, s)
        pos = jnp.arange(s - take, s)
        slots = pos % window
        ck = cache["k"].at[:, slots].set(k[:, s - take:].astype(dtype))
        cv = cache["v"].at[:, slots].set(v[:, s - take:].astype(dtype))
        return {"k": ck, "v": cv}
    cache = init_kv_cache(cfg, b, cache_len, dtype)
    ck = cache["k"].at[:, :s].set(k.astype(dtype))
    cv = cache["v"].at[:, :s].set(v.astype(dtype))
    return {"k": ck, "v": cv}


# ------------------------------------------------------------------ decode
def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
    }


def decode_attend(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                  pos: jax.Array, window: Optional[int] = None):
    """One-token decode. x: (batch, 1, d); pos: scalar current position.

    Returns (out (batch, 1, d), new_cache). The cache holds positions
    [0, cache_len) for full attention, or a ring buffer of the last
    ``window`` positions when ``window`` is set (cache_len == window).
    """
    group = cfg.num_heads // cfg.num_kv_heads
    cache_len = cache["k"].shape[1]
    q, k, v = _project_qkv(cfg, p, x)                 # (b, 1, h/kv, hd)
    posv = jnp.full(x.shape[:-2] + (1,), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)

    slot = pos % cache_len if window is not None else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    scale = cfg.head_dim ** -0.5
    # (b, kv, g, hd) x (b, s, kv, hd) -> (b, kv, g, s)
    qh = q[:, 0].reshape(q.shape[0], cfg.num_kv_heads, group, cfg.head_dim)
    scores = jnp.einsum("bkgh,bskh->bkgs", qh, ck).astype(jnp.float32) * scale
    sidx = jnp.arange(cache_len)
    if window is not None:
        # ring buffer: slot s holds absolute position p' with p' % W == s,
        # the latest such p' <= pos:
        abs_pos = pos - ((pos - sidx) % cache_len)
        valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)
    else:
        valid = sidx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, cv)
    out = out.reshape(x.shape[0], 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return out, {"k": ck, "v": cv}
