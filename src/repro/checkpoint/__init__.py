from repro.checkpoint.checkpoint import (  # noqa: F401
    load_meta,
    moments_meta,
    restore,
    restore_flat_state,
    save,
    save_flat_state,
)
