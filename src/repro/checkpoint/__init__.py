from repro.checkpoint.checkpoint import load_meta, restore, save  # noqa: F401
