from repro.checkpoint.checkpoint import (  # noqa: F401
    SimulatedKill,
    kill_save,
    latest_step,
    load_meta,
    moments_meta,
    restore,
    restore_flat_state,
    save,
    save_flat_state,
    save_step,
    step_dir,
    validate_flat_meta,
)
from repro.checkpoint.reshard import (  # noqa: F401
    restore_resharded,
    saved_workers,
)
