"""Pytree checkpointing: flattened-key npz + json metadata.

Worker-aware: `save_state` stores the full worker-stacked WorkerState; on
restore the tree structure is rebuilt from the recorded key paths. No orbax
dependency (offline container) — npz is fine at smoke/example scale, and the
format records shard metadata so a real deployment can swap in a tensor-store
backend behind the same interface.

Atomicity
---------

A checkpoint is ONE file: ``arrays.npz`` with the json metadata embedded as
a ``__meta__`` uint8 entry.  ``save`` writes a temp file in the same
directory, fsyncs, and ``os.replace``s it over the final name — the rename
is the commit point, so a process killed mid-save (for real, or via
:func:`kill_save`) leaves either the previous complete checkpoint or a
stale ``arrays.npz.tmp.*`` that the next save sweeps up; never a torn
``arrays.npz``.  A sidecar ``meta.json`` is still written (best-effort,
after the commit) for human inspection, and ``load_meta`` falls back to it
for checkpoints from before the embedded format.

Step-dir layout (``save_step`` / ``latest_step``): a run's checkpoint root
holds ``ckpt-XXXXXXXX/`` per saved step plus an atomically-updated
``latest`` pointer file; ``retain`` prunes all but the newest N step dirs.
``--resume auto`` resolves through ``latest_step`` and survives a lost or
stale pointer by falling back to a directory scan.
"""
from __future__ import annotations

import contextlib
import glob
import json
import os
import shutil
from typing import Any, Callable, Optional

import jax
import numpy as np


SEP = "/"
META_KEY = "__meta__"
LATEST = "latest"


class SimulatedKill(RuntimeError):
    """Raised by a save under ``kill_save`` — stands in for SIGKILL at the
    worst moment of a checkpoint write (tests and the chaos harness catch
    it where a real kill would need a process restart)."""


_KILL = {"phase": None}


@contextlib.contextmanager
def kill_save(phase: str = "mid-write"):
    """Arm a one-shot simulated kill inside the next ``save``.

    ``phase="mid-write"``: the temp file is torn (truncated to half its
    bytes) and ``SimulatedKill`` raises BEFORE the commit rename — the
    published ``arrays.npz`` must be untouched.
    ``phase="pre-rename"``: the temp file is complete but the rename never
    happens — the checkpoint still must not be considered written.
    """
    if phase not in ("mid-write", "pre-rename"):
        raise ValueError(f"unknown kill_save phase {phase!r}")
    prev = _KILL["phase"]
    _KILL["phase"] = phase
    try:
        yield
    finally:
        _KILL["phase"] = prev


def _maybe_kill(phase: str, tmp: str | None = None) -> None:
    if _KILL["phase"] != phase:
        return
    _KILL["phase"] = None                      # one-shot: a kill fires once
    if phase == "mid-write" and tmp is not None:
        size = os.path.getsize(tmp)
        with open(tmp, "r+b") as f:
            f.truncate(max(size // 2, 1))
    raise SimulatedKill(f"simulated kill during checkpoint save ({phase})")


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    if META_KEY in flat:
        raise ValueError(f"tree key {META_KEY!r} collides with the "
                         f"embedded-metadata entry")
    treedef = jax.tree_util.tree_structure(tree)
    info = {
        "keys": list(flat.keys()),
        "treedef": str(treedef),
        "meta": meta or {},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    payload = np.frombuffer(json.dumps(info).encode("utf-8"), np.uint8)
    final = os.path.join(path, "arrays.npz")
    # sweep temp files orphaned by a previous kill — they were never
    # published, so they are garbage by construction
    for stale in glob.glob(final + ".tmp.*"):
        with contextlib.suppress(OSError):
            os.remove(stale)
    tmp = f"{final}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **{META_KEY: payload}, **flat)
        f.flush()
        os.fsync(f.fileno())
    # a SimulatedKill here leaves the temp behind, like a real SIGKILL
    # would — the published arrays.npz is untouched either way
    _maybe_kill("mid-write", tmp)
    _maybe_kill("pre-rename")
    os.replace(tmp, final)                       # the commit point
    with contextlib.suppress(OSError):           # sidecar: human-readable,
        with open(os.path.join(path, "meta.json"), "w") as f:  # best-effort
            json.dump(info, f, indent=1)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = load_meta(path).get("dtypes", {})
    flat_template = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_template[0]:
        key = SEP.join(_path_str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        # extension dtypes (bfloat16 via ml_dtypes) survive npz as raw
        # void bytes — re-view them with the recorded dtype, bitwise
        rec_dt = dtypes.get(key)
        if (rec_dt is not None and arr.dtype.kind == "V"
                and rec_dt != str(arr.dtype)):
            arr = arr.view(np.dtype(rec_dt))
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


def load_meta(path: str) -> dict:
    """Checkpoint metadata — embedded ``__meta__`` npz entry first (the
    atomic format), sidecar ``meta.json`` as the legacy fallback."""
    npz = os.path.join(path, "arrays.npz")
    if os.path.exists(npz):
        with np.load(npz) as data:
            if META_KEY in data:
                return json.loads(bytes(data[META_KEY]).decode("utf-8"))
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


# ------------------------------------------------------- step-dir layout

def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"ckpt-{step:08d}")


def _write_latest(root: str, name: str) -> None:
    tmp = os.path.join(root, f".latest.tmp.{os.getpid()}")
    with open(tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, LATEST))


def _complete(root: str, name: str) -> bool:
    return os.path.exists(os.path.join(root, name, "arrays.npz"))


def save_step(root: str, step: int, save_fn: Callable[[str], None],
              *, retain: int = 0) -> str:
    """Write one checkpoint under the step-dir layout.

    ``save_fn(path)`` does the actual write (``save`` /
    ``save_flat_state`` bound to the run's state) into the step dir;
    only after it returns is the ``latest`` pointer flipped — a save
    killed mid-write leaves the pointer on the previous good step.
    ``retain > 0`` then prunes all but the newest ``retain`` step dirs
    (the one just written always survives).
    """
    os.makedirs(root, exist_ok=True)
    d = step_dir(root, step)
    save_fn(d)
    _write_latest(root, os.path.basename(d))
    if retain > 0:
        _prune(root, retain)
    return d


def latest_step(root: str) -> Optional[tuple[int, str]]:
    """(step, path) of the newest COMPLETE checkpoint under ``root``, or
    None.  Trusts the ``latest`` pointer when it names a complete step
    dir; otherwise (pointer lost, stale, or torn) falls back to scanning
    the step dirs."""
    if not os.path.isdir(root):
        return None
    name = None
    lf = os.path.join(root, LATEST)
    if os.path.exists(lf):
        with open(lf) as f:
            cand = f.read().strip()
        if cand and _complete(root, cand):
            name = cand
    if name is None:
        steps = sorted(d for d in os.listdir(root)
                       if d.startswith("ckpt-") and _complete(root, d))
        if not steps:
            return None
        name = steps[-1]
    return int(name.rsplit("-", 1)[1]), os.path.join(root, name)


def _prune(root: str, retain: int) -> None:
    steps = sorted(d for d in os.listdir(root) if d.startswith("ckpt-"))
    for name in steps[:-retain]:
        shutil.rmtree(os.path.join(root, name), ignore_errors=True)


# ----------------------------------------------------- flat-engine states
# The fused engine's FlatWorkerState / HierFlatState is an ordinary pytree
# of buffers, so save()/restore() work unchanged — but a flat buffer is
# meaningless without its unravel spec (leaf paths/shapes/offsets + tiling),
# and a pod-major hierarchical buffer additionally without its (P, D)
# worker grid.  These helpers persist both alongside the arrays and refuse
# to restore into an engine whose layout disagrees (e.g. different lane
# width, model revision, block auto-choice, or pod grid).

def _carries_comm(state: Any) -> bool:
    """True when the state carries compressed-sync buffers (a non-empty
    ``comm`` field)."""
    comm = getattr(state, "comm", ())
    return len(jax.tree_util.tree_leaves(comm)) > 0


def moments_meta(cfg) -> dict:
    """JSON-safe moment-storage description of a VRLConfig: what dtype the
    inner-optimizer moments persist at and whether Adam's second moment is
    SM3-factored.  Recorded at save and validated at restore — bf16 / SM3
    buffers restored into an fp32 engine (or vice versa) would silently
    reinterpret state."""
    return {"moment_dtype": getattr(cfg, "moment_dtype", "float32"),
            "sm3": bool(getattr(cfg, "sm3", False))}


def save_flat_state(path: str, state: Any, spec, meta: dict | None = None,
                    grid=None, compressors: dict | None = None,
                    moments: dict | None = None) -> None:
    """Save a fused-engine state plus its flat.FlatSpec layout.

    ``grid``: the pod-major (P, D) worker grid for hierarchical states
    (``engine.Engine.grid``); omit for flat (W, R, C) states.
    ``compressors``: per-level sync-compressor metadata
    (``repro.comm.pair_meta``) — recorded (None for uncompressed) so a
    restore into a differently-compressed engine fails loudly instead of
    silently dropping or misreading the error-feedback residual buffers.
    ``moments``: moment-storage metadata (``moments_meta(cfg)``) — same
    loud-failure contract for bf16/SM3 moment buffers.  The shard layout
    needs no extra field: ``spec.meta()`` carries ``shards`` and a
    mismatch fails the flat_spec comparison.
    """
    if compressors is None and _carries_comm(state):
        raise ValueError(
            "state carries compressed-sync buffers (comm.resid/ref) but no "
            "compressor metadata was given — pass compressors=repro.comm"
            ".pair_meta(engine.compressors) so a restore can validate them")
    m = dict(meta or {})
    m["flat_spec"] = spec.meta()
    m["compressors"] = compressors
    if moments is not None:
        m["moments"] = moments
    if grid is not None:
        m["worker_grid"] = [int(g) for g in grid]
    save(path, state, meta=m)


def restore_flat_state(path: str, state_like: Any, spec, grid=None,
                       compressors: dict | None = None,
                       moments: dict | None = None) -> Any:
    """Restore a fused-engine state, validating the recorded unravel spec
    (and, for hierarchical states, the recorded (P, D) worker grid, the
    recorded per-level sync compressors, and the recorded moment storage).

    A compressor mismatch is a hard error: the compressed-sync residuals
    (and drift references) in the checkpoint only mean anything to an
    engine running the SAME compressors — restoring them elsewhere would
    silently drop the carried error feedback or corrupt the next sync.
    Shard-count and moment-dtype/SM3 mismatches fail the same way (the
    shard count rides in ``spec.meta()``; moments in the ``moments``
    record when the saver provided one).
    """
    if compressors is None and _carries_comm(state_like):
        raise ValueError(
            "restore target carries compressed-sync buffers (comm.resid/"
            "ref) but no compressor metadata was given — pass compressors="
            "repro.comm.pair_meta(engine.compressors) so the recorded "
            "compressors can be validated")
    recorded = load_meta(path)["meta"]
    validate_flat_meta(recorded, spec, compressors=compressors,
                       moments=moments, grid=grid)
    return restore(path, state_like)


def validate_flat_meta(recorded: dict, spec, *, compressors=None,
                       moments=None, grid=None) -> None:
    """The restore-compatibility gate shared by ``restore_flat_state``
    and the resharding restore: layout spec, sync compressors, moment
    storage and (hierarchical) worker grid must all match the target
    engine, each failing with a message naming the field and both
    values."""
    rec_spec = recorded.get("flat_spec")
    if rec_spec is not None and rec_spec != spec.meta():
        raise ValueError(
            "checkpoint flat-buffer layout does not match the engine's "
            f"unravel spec:\n  checkpoint: {rec_spec}\n  engine:     "
            f"{spec.meta()}")
    rec_comp = recorded.get("compressors")
    if rec_comp != compressors:
        raise ValueError(
            "checkpoint sync compressors do not match the engine's — "
            "refusing to restore (the error-feedback residuals would be "
            f"dropped or misread):\n  checkpoint: {rec_comp}\n"
            f"  engine:     {compressors}")
    rec_mom = recorded.get("moments")
    if rec_mom is not None and moments is not None and rec_mom != moments:
        raise ValueError(
            "checkpoint moment storage does not match the engine's — "
            "refusing to restore (bf16/SM3 moment buffers would be "
            f"reinterpreted):\n  checkpoint: {rec_mom}\n"
            f"  engine:     {moments}")
    rec_grid = recorded.get("worker_grid")
    if (rec_grid is not None and grid is not None
            and [int(g) for g in grid] != rec_grid):
        raise ValueError(
            f"checkpoint worker grid {rec_grid} does not match the "
            f"engine's grid {list(grid)}")
