"""Pytree checkpointing: flattened-key npz + json metadata.

Worker-aware: `save_state` stores the full worker-stacked WorkerState; on
restore the tree structure is rebuilt from the recorded key paths. No orbax
dependency (offline container) — npz is fine at smoke/example scale, and the
format records shard metadata so a real deployment can swap in a tensor-store
backend behind the same interface.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    info = {
        "keys": list(flat.keys()),
        "treedef": str(treedef),
        "meta": meta or {},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(info, f, indent=1)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = load_meta(path).get("dtypes", {})
    flat_template = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_template[0]:
        key = SEP.join(_path_str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        # extension dtypes (bfloat16 via ml_dtypes) survive npz as raw
        # void bytes — re-view them with the recorded dtype, bitwise
        rec_dt = dtypes.get(key)
        if (rec_dt is not None and arr.dtype.kind == "V"
                and rec_dt != str(arr.dtype)):
            arr = arr.view(np.dtype(rec_dt))
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


# ----------------------------------------------------- flat-engine states
# The fused engine's FlatWorkerState / HierFlatState is an ordinary pytree
# of buffers, so save()/restore() work unchanged — but a flat buffer is
# meaningless without its unravel spec (leaf paths/shapes/offsets + tiling),
# and a pod-major hierarchical buffer additionally without its (P, D)
# worker grid.  These helpers persist both alongside the arrays and refuse
# to restore into an engine whose layout disagrees (e.g. different lane
# width, model revision, block auto-choice, or pod grid).

def _carries_comm(state: Any) -> bool:
    """True when the state carries compressed-sync buffers (a non-empty
    ``comm`` field)."""
    comm = getattr(state, "comm", ())
    return len(jax.tree_util.tree_leaves(comm)) > 0


def moments_meta(cfg) -> dict:
    """JSON-safe moment-storage description of a VRLConfig: what dtype the
    inner-optimizer moments persist at and whether Adam's second moment is
    SM3-factored.  Recorded at save and validated at restore — bf16 / SM3
    buffers restored into an fp32 engine (or vice versa) would silently
    reinterpret state."""
    return {"moment_dtype": getattr(cfg, "moment_dtype", "float32"),
            "sm3": bool(getattr(cfg, "sm3", False))}


def save_flat_state(path: str, state: Any, spec, meta: dict | None = None,
                    grid=None, compressors: dict | None = None,
                    moments: dict | None = None) -> None:
    """Save a fused-engine state plus its flat.FlatSpec layout.

    ``grid``: the pod-major (P, D) worker grid for hierarchical states
    (``engine.Engine.grid``); omit for flat (W, R, C) states.
    ``compressors``: per-level sync-compressor metadata
    (``repro.comm.pair_meta``) — recorded (None for uncompressed) so a
    restore into a differently-compressed engine fails loudly instead of
    silently dropping or misreading the error-feedback residual buffers.
    ``moments``: moment-storage metadata (``moments_meta(cfg)``) — same
    loud-failure contract for bf16/SM3 moment buffers.  The shard layout
    needs no extra field: ``spec.meta()`` carries ``shards`` and a
    mismatch fails the flat_spec comparison.
    """
    if compressors is None and _carries_comm(state):
        raise ValueError(
            "state carries compressed-sync buffers (comm.resid/ref) but no "
            "compressor metadata was given — pass compressors=repro.comm"
            ".pair_meta(engine.compressors) so a restore can validate them")
    m = dict(meta or {})
    m["flat_spec"] = spec.meta()
    m["compressors"] = compressors
    if moments is not None:
        m["moments"] = moments
    if grid is not None:
        m["worker_grid"] = [int(g) for g in grid]
    save(path, state, meta=m)


def restore_flat_state(path: str, state_like: Any, spec, grid=None,
                       compressors: dict | None = None,
                       moments: dict | None = None) -> Any:
    """Restore a fused-engine state, validating the recorded unravel spec
    (and, for hierarchical states, the recorded (P, D) worker grid, the
    recorded per-level sync compressors, and the recorded moment storage).

    A compressor mismatch is a hard error: the compressed-sync residuals
    (and drift references) in the checkpoint only mean anything to an
    engine running the SAME compressors — restoring them elsewhere would
    silently drop the carried error feedback or corrupt the next sync.
    Shard-count and moment-dtype/SM3 mismatches fail the same way (the
    shard count rides in ``spec.meta()``; moments in the ``moments``
    record when the saver provided one).
    """
    if compressors is None and _carries_comm(state_like):
        raise ValueError(
            "restore target carries compressed-sync buffers (comm.resid/"
            "ref) but no compressor metadata was given — pass compressors="
            "repro.comm.pair_meta(engine.compressors) so the recorded "
            "compressors can be validated")
    recorded = load_meta(path)["meta"]
    rec_spec = recorded.get("flat_spec")
    if rec_spec is not None and rec_spec != spec.meta():
        raise ValueError(
            "checkpoint flat-buffer layout does not match the engine's "
            f"unravel spec:\n  checkpoint: {rec_spec}\n  engine:     "
            f"{spec.meta()}")
    rec_comp = recorded.get("compressors")
    if rec_comp != compressors:
        raise ValueError(
            "checkpoint sync compressors do not match the engine's — "
            "refusing to restore (the error-feedback residuals would be "
            f"dropped or misread):\n  checkpoint: {rec_comp}\n"
            f"  engine:     {compressors}")
    rec_mom = recorded.get("moments")
    if rec_mom is not None and moments is not None and rec_mom != moments:
        raise ValueError(
            "checkpoint moment storage does not match the engine's — "
            "refusing to restore (bf16/SM3 moment buffers would be "
            f"reinterpreted):\n  checkpoint: {rec_mom}\n"
            f"  engine:     {moments}")
    rec_grid = recorded.get("worker_grid")
    if (rec_grid is not None and grid is not None
            and [int(g) for g in grid] != rec_grid):
        raise ValueError(
            f"checkpoint worker grid {rec_grid} does not match the "
            f"engine's grid {list(grid)}")
    return restore(path, state_like)
