"""Pytree checkpointing: flattened-key npz + json metadata.

Worker-aware: `save_state` stores the full worker-stacked WorkerState; on
restore the tree structure is rebuilt from the recorded key paths. No orbax
dependency (offline container) — npz is fine at smoke/example scale, and the
format records shard metadata so a real deployment can swap in a tensor-store
backend behind the same interface.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    info = {
        "keys": list(flat.keys()),
        "treedef": str(treedef),
        "meta": meta or {},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(info, f, indent=1)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_template = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_template[0]:
        key = SEP.join(_path_str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


# ----------------------------------------------------- flat-engine states
# The fused engine's FlatWorkerState is an ordinary pytree of buffers, so
# save()/restore() work unchanged — but a flat buffer is meaningless without
# its unravel spec (leaf paths/shapes/offsets + tiling).  These helpers
# persist the spec's JSON description alongside the arrays and refuse to
# restore into an engine whose layout disagrees (e.g. different lane width,
# model revision, or block auto-choice).

def save_flat_state(path: str, state: Any, spec, meta: dict | None = None
                    ) -> None:
    """Save a core.engine.FlatWorkerState plus its flat.FlatSpec layout."""
    m = dict(meta or {})
    m["flat_spec"] = spec.meta()
    save(path, state, meta=m)


def restore_flat_state(path: str, state_like: Any, spec) -> Any:
    """Restore a FlatWorkerState, validating the recorded unravel spec."""
    recorded = load_meta(path)["meta"].get("flat_spec")
    if recorded is not None and recorded != spec.meta():
        raise ValueError(
            "checkpoint flat-buffer layout does not match the engine's "
            f"unravel spec:\n  checkpoint: {recorded}\n  engine:     "
            f"{spec.meta()}")
    return restore(path, state_like)
