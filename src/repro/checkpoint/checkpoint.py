"""Pytree checkpointing: flattened-key npz + json metadata.

Worker-aware: `save_state` stores the full worker-stacked WorkerState; on
restore the tree structure is rebuilt from the recorded key paths. No orbax
dependency (offline container) — npz is fine at smoke/example scale, and the
format records shard metadata so a real deployment can swap in a tensor-store
backend behind the same interface.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    info = {
        "keys": list(flat.keys()),
        "treedef": str(treedef),
        "meta": meta or {},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(info, f, indent=1)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_template = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_template[0]:
        key = SEP.join(_path_str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


# ----------------------------------------------------- flat-engine states
# The fused engine's FlatWorkerState / HierFlatState is an ordinary pytree
# of buffers, so save()/restore() work unchanged — but a flat buffer is
# meaningless without its unravel spec (leaf paths/shapes/offsets + tiling),
# and a pod-major hierarchical buffer additionally without its (P, D)
# worker grid.  These helpers persist both alongside the arrays and refuse
# to restore into an engine whose layout disagrees (e.g. different lane
# width, model revision, block auto-choice, or pod grid).

def save_flat_state(path: str, state: Any, spec, meta: dict | None = None,
                    grid=None) -> None:
    """Save a fused-engine state plus its flat.FlatSpec layout.

    ``grid``: the pod-major (P, D) worker grid for hierarchical states
    (``engine.Engine.grid``); omit for flat (W, R, C) states.
    """
    m = dict(meta or {})
    m["flat_spec"] = spec.meta()
    if grid is not None:
        m["worker_grid"] = [int(g) for g in grid]
    save(path, state, meta=m)


def restore_flat_state(path: str, state_like: Any, spec, grid=None) -> Any:
    """Restore a fused-engine state, validating the recorded unravel spec
    (and, for hierarchical states, the recorded (P, D) worker grid)."""
    recorded = load_meta(path)["meta"]
    rec_spec = recorded.get("flat_spec")
    if rec_spec is not None and rec_spec != spec.meta():
        raise ValueError(
            "checkpoint flat-buffer layout does not match the engine's "
            f"unravel spec:\n  checkpoint: {rec_spec}\n  engine:     "
            f"{spec.meta()}")
    rec_grid = recorded.get("worker_grid")
    if (rec_grid is not None and grid is not None
            and [int(g) for g in grid] != rec_grid):
        raise ValueError(
            f"checkpoint worker grid {rec_grid} does not match the "
            f"engine's grid {list(grid)}")
    return restore(path, state_like)
