"""Checkpointed resharding: restore a W-worker flat state onto W' workers.

An elastic restart rarely gets the same fleet back.  ``restore_resharded``
takes a flat-engine checkpoint saved at ``W`` workers and rebuilds a valid
``FlatWorkerState`` for an engine initialized at ``W' != W``, by
host-side row surgery on the (W, R, C) buffers:

  params / moments   new row j copies saved row ``j % W`` (tiling — every
                     new worker starts at a position the old run actually
                     held, and moments stay consistent with their params)
  delta / bias       tiled the same way, then RECENTRED to zero mean in
                     float64 — the paper's invariant Σ_i Δ_i = 0 (and
                     Σ_i B_i = 0 for BVR) is what makes the first post-
                     restart sync a correct VRL update, and tiling alone
                     breaks it whenever W' is not a multiple of W
  comm residuals     zeroed — error feedback accumulated by the old
                     membership has no meaningful owner in the new one
                     (the first post-restart sync simply compresses a
                     slightly larger payload)
  comm references    kept — the drift reference is membership-independent
  overlap pend       rebuilt from the resharded params (pend_k = 1): the
                     next overlapped collective averages real positions
  member             fresh full mask at W' (template's own init values)
  step counters      kept — the run resumes its global step

The unravel spec is W-independent (it describes one worker's (R, C)
layout), so the same compatibility gate as ``restore_flat_state`` applies
— layout, compressors, and moment storage must match, each failure naming
the field and both values.  Hierarchical (pod-grid) checkpoints are
refused: resharding a (P, D) grid is a topology decision, not row
surgery.  Data assignments are resharded separately with
``data.partition.repartition``.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

from repro.checkpoint.checkpoint import (SEP, _carries_comm, _path_str,
                                         load_meta, validate_flat_meta)


def saved_workers(path: str) -> int:
    """Leading worker-axis size of the checkpoint at ``path``."""
    shapes = load_meta(path).get("shapes", {})
    if "params" not in shapes:
        raise ValueError(
            f"checkpoint at {path!r} has no 'params' entry — not a "
            f"flat-engine state")
    return int(shapes["params"][0])


def _tile(arr: np.ndarray, w_new: int) -> np.ndarray:
    return arr[np.arange(w_new) % arr.shape[0]]


def _recenter(arr: np.ndarray) -> np.ndarray:
    shift = arr.astype(np.float64).mean(axis=0, keepdims=True)
    return (arr.astype(np.float64) - shift).astype(arr.dtype)


def restore_resharded(path: str, state_like: Any, spec, *,
                      compressors: dict | None = None,
                      moments: dict | None = None) -> Any:
    """Restore the checkpoint at ``path`` into ``state_like`` (a fresh
    ``engine.init`` state at the NEW worker count), resharding the
    worker axis per the module rules."""
    recorded = load_meta(path)["meta"]
    if recorded.get("worker_grid") is not None:
        raise ValueError(
            "resharding a hierarchical (pod-grid) checkpoint is not "
            f"supported — recorded grid {recorded['worker_grid']}; "
            "restore onto the same grid, or retrain the pod topology")
    if compressors is None and _carries_comm(state_like):
        raise ValueError(
            "restore target carries compressed-sync buffers (comm.resid/"
            "ref) but no compressor metadata was given — pass compressors="
            "repro.comm.pair_meta(engine.compressors) so the recorded "
            "compressors can be validated")
    validate_flat_meta(recorded, spec, compressors=compressors,
                       moments=moments)

    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = load_meta(path).get("dtypes", {})

    def _load(key):
        arr = data[key]
        rec_dt = dtypes.get(key)
        if (rec_dt is not None and arr.dtype.kind == "V"
                and rec_dt != str(arr.dtype)):
            arr = arr.view(np.dtype(rec_dt))
        return arr

    if "params" not in data:
        raise ValueError(f"checkpoint at {path!r} has no 'params' entry — "
                         f"not a flat-engine state")
    w_old = int(data["params"].shape[0])
    w_new = int(state_like.params.shape[0])
    new_params = _tile(_load("params"), w_new)

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(
        state_like)
    leaves = []
    for pth, leaf in flat_template:
        key = SEP.join(_path_str(p) for p in pth)
        top = key.split(SEP, 1)[0]
        tshape = tuple(getattr(leaf, "shape", ()))
        if top == "member":
            leaves.append(np.asarray(leaf))          # fresh full mask
            continue
        if top == "overlap":
            if key.endswith("pend"):
                leaves.append(new_params.astype(np.asarray(leaf).dtype))
            else:                                    # pend_k
                leaves.append(np.ones(tshape, np.float32))
            continue
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = _load(key)
        if top == "params":
            arr = new_params
        elif top in ("delta", "bias"):
            arr = _recenter(_tile(arr, w_new))
        elif top == "comm" and "resid" in key:
            arr = np.zeros(tshape, np.asarray(leaf).dtype)
        elif arr.ndim >= 1 and arr.shape[0] == w_old \
                and tshape[:1] == (w_new,):
            arr = _tile(arr, w_new)                  # moments & friends
        if tshape != tuple(arr.shape):
            raise ValueError(
                f"{key}: resharded shape {arr.shape} != template "
                f"{tshape} (saved at W={w_old}, restoring at W={w_new})")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
