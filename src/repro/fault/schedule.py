"""Deterministic fault-injection schedules for chaos runs.

A ``FaultSchedule`` is a seedable, replayable list of :class:`FaultEvent`
timed against the GLOBAL step counter, so a chaos run is exactly
reproducible in CI: same spec (or same ``--fault-seed``) → same faults at
the same steps, independent of wall clock, host, or retry count.

Event kinds
-----------

``nan`` / ``inf`` / ``scale``
    Poison worker *w*'s gradient at step *s* — the fault harness feeds a
    (k, W) multiplier into ``round_step_fault`` with NaN/Inf (or a finite
    scale factor) at that position, modeling a sick accelerator emitting
    garbage.  ``scale`` is the *silent* corruption: the gradient stays
    finite, so the finiteness health check never trips — only the
    driver's loss-blow-up guard catches it.  These are **consuming**
    events: ``grad_mul`` marks them fired, so when the divergence guard
    rolls back and replays the same data the fault does NOT re-fire (the
    real-world analogue: a transient fault plus deterministic data would
    otherwise be unescapable).
``crash`` / ``rejoin``
    Worker *w* leaves / re-enters the membership at step *s*.  These are
    **pure**: ``active_at(t)`` folds the full event history, so replaying
    any step range after a rollback reconstructs the same mask —
    membership is state, not an edge, and must survive retries.
``killsave``
    Simulate a process kill inside the first checkpoint save at or after
    step *s* (``checkpoint.kill_save``): the save raises
    :class:`repro.checkpoint.SimulatedKill` mid-write, exercising the
    atomic-rename torn-write guarantee.  Consuming, like the grad faults.

Spec grammar (the ``--faults`` flag)::

    spec    := event ("," event)*
    event   := kind "@" worker ":" step      # nan/inf/crash/rejoin
             | "scale" "@" worker ":" step ":" mult   # finite grad scale
             | "killsave" ":" step           # no worker
    example := "nan@1:12,scale@0:20:1e3,crash@1:30,rejoin@1:60,killsave:50"

``FaultSchedule.random(...)`` draws a spec from a seed with the same
semantics (crash/rejoin pairs that always leave >= 1 survivor, plus
gradient poison), for soak-style chaos sweeps.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import numpy as np

GRAD_KINDS = ("nan", "inf", "scale")
MEMBER_KINDS = ("crash", "rejoin")
KINDS = GRAD_KINDS + MEMBER_KINDS + ("killsave",)


class FaultEvent(NamedTuple):
    kind: str        # one of KINDS
    step: int        # global step index the event fires at
    worker: int = -1  # target worker; -1 for killsave
    mult: float = 1.0  # scale only: the finite gradient multiplier


def _parse_event(tok: str) -> FaultEvent:
    tok = tok.strip()
    if not tok:
        raise ValueError("empty fault event in spec")
    body, mult = tok, 1.0
    if tok.partition("@")[0].partition(":")[0].strip() == "scale":
        # three ':'-separated fields — peel the trailing multiplier so the
        # common kind@worker:step parse below sees its usual form
        body, sep, mult_s = tok.rpartition(":")
        if not sep or ":" not in body:
            raise ValueError(
                f"scale event {tok!r} needs a multiplier — "
                f"'scale@worker:step:mult' (e.g. 'scale@1:12:1e3')")
        try:
            mult = float(mult_s)
        except ValueError:
            raise ValueError(f"fault event {tok!r}: multiplier {mult_s!r} "
                             f"is not a float") from None
        if not np.isfinite(mult):
            raise ValueError(
                f"fault event {tok!r}: multiplier must be finite — use "
                f"'nan@'/'inf@' for non-finite poisons")
    head, sep, step_s = body.rpartition(":")
    if not sep:
        raise ValueError(
            f"fault event {tok!r} has no ':step' — expected "
            f"'kind@worker:step' (or 'killsave:step')")
    kind, sep, worker_s = head.partition("@")
    kind = kind.strip()
    if kind not in KINDS:
        raise ValueError(
            f"unknown fault kind {kind!r} in {tok!r}; known: {KINDS}")
    try:
        step = int(step_s)
    except ValueError:
        raise ValueError(f"fault event {tok!r}: step {step_s!r} is not an "
                         f"integer") from None
    if step < 0:
        raise ValueError(f"fault event {tok!r}: step must be >= 0")
    if kind == "killsave":
        if sep:
            raise ValueError(
                f"killsave takes no worker — write 'killsave:{step}', "
                f"got {tok!r}")
        return FaultEvent("killsave", step)
    if not sep:
        raise ValueError(
            f"fault event {tok!r} needs a worker — 'kind@worker:step'")
    try:
        worker = int(worker_s)
    except ValueError:
        raise ValueError(f"fault event {tok!r}: worker {worker_s!r} is not "
                         f"an integer") from None
    if worker < 0:
        raise ValueError(f"fault event {tok!r}: worker must be >= 0")
    return FaultEvent(kind, step, worker, mult)


class FaultSchedule:
    """An ordered fault plan plus the fired-set for consuming events."""

    def __init__(self, events: List[FaultEvent]):
        self.events = sorted(events, key=lambda e: (e.step, e.kind,
                                                    e.worker))
        self._fired = set()          # indices of consumed one-shot events

    # ------------------------------------------------------- constructors
    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        events = [_parse_event(tok) for tok in spec.split(",")
                  if tok.strip()]
        if not events:
            raise ValueError(f"fault spec {spec!r} contains no events")
        return cls(events)

    @classmethod
    def random(cls, steps: int, workers: int, *, seed: int,
               n_grad: int = 1, n_churn: int = 1,
               killsave: bool = False) -> "FaultSchedule":
        """Draw a deterministic schedule: ``n_grad`` NaN/Inf/scale
        poisons (scale draws a fixed 1e3 blow-up — finite, so only a
        loss guard catches it), ``n_churn`` crash→rejoin pairs (never
        the same worker twice at once, so with workers >= 2 at least one
        survivor always holds), and optionally one mid-save kill."""
        if workers < 2 and n_churn:
            raise ValueError("churn faults need >= 2 workers")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(n_grad):
            kind = GRAD_KINDS[int(rng.integers(len(GRAD_KINDS)))]
            events.append(FaultEvent(kind, int(rng.integers(1, steps)),
                                     int(rng.integers(workers)),
                                     1e3 if kind == "scale" else 1.0))
        victims = rng.choice(workers, size=min(n_churn, workers - 1),
                             replace=False)
        for w in victims:
            lo = int(rng.integers(1, max(steps - 1, 2)))
            hi = int(rng.integers(lo + 1, steps + 1))
            events.append(FaultEvent("crash", lo, int(w)))
            events.append(FaultEvent("rejoin", hi, int(w)))
        if killsave:
            events.append(FaultEvent("killsave", int(rng.integers(1,
                                                                  steps))))
        return cls(events)

    # ---------------------------------------------------------- queries
    def active_at(self, t: int, workers: int) -> np.ndarray:
        """(W,) float32 {0,1} membership mask at step ``t`` — pure fold
        of the crash/rejoin history, so replays after a rollback see the
        same mask (idempotent; never consumes)."""
        mask = np.ones(workers, np.float32)
        for e in self.events:
            if e.step > t:
                break
            if e.kind == "crash" and e.worker < workers:
                mask[e.worker] = 0.0
            elif e.kind == "rejoin" and e.worker < workers:
                mask[e.worker] = 1.0
        return mask

    def grad_mul(self, t0: int, k: int,
                 workers: int) -> Optional[np.ndarray]:
        """(k, W) gradient multiplier for the round covering steps
        [t0, t0 + k), or None if the round is clean (so the driver can
        run the plain fault-free ``round_step`` executable).  Consumes:
        each poison fires exactly once across the whole run, including
        rollback replays."""
        out = None
        for i, e in enumerate(self.events):
            if e.kind not in GRAD_KINDS or i in self._fired:
                continue
            if t0 <= e.step < t0 + k and e.worker < workers:
                if out is None:
                    out = np.ones((k, workers), np.float32)
                out[e.step - t0, e.worker] = (
                    np.nan if e.kind == "nan"
                    else np.inf if e.kind == "inf" else e.mult)
                self._fired.add(i)
        return out

    def killsave_at(self, t: int) -> bool:
        """True exactly once: the first query at/after a pending
        killsave event consumes it (a process dies only once per kill)."""
        for i, e in enumerate(self.events):
            if e.kind == "killsave" and i not in self._fired \
                    and e.step <= t:
                self._fired.add(i)
                return True
        return False

    # ------------------------------------------------------------- misc
    def membership_events(self) -> List[FaultEvent]:
        return [e for e in self.events if e.kind in MEMBER_KINDS]

    def events_in(self, t0: int, t1: int) -> List[dict]:
        """JSON-safe descriptions of the events firing in [t0, t1) —
        what the telemetry stream records for a faulted round."""
        out = []
        for e in self.events:
            if t0 <= e.step < t1:
                d = {"kind": e.kind, "step": int(e.step)}
                if e.worker >= 0:
                    d["worker"] = int(e.worker)
                if e.kind == "scale":
                    d["mult"] = float(e.mult)
                out.append(d)
        return out

    def describe(self) -> str:
        def one(e: FaultEvent) -> str:
            if e.kind == "killsave":
                return f"{e.kind}:{e.step}"
            if e.kind == "scale":
                return f"scale@{e.worker}:{e.step}:{e.mult:g}"
            return f"{e.kind}@{e.worker}:{e.step}"
        return ",".join(one(e) for e in self.events)

    def __len__(self) -> int:
        return len(self.events)
