# Deterministic fault injection for elastic fault-tolerant rounds:
# seedable schedules of gradient poison (NaN/Inf), worker crash/rejoin,
# and simulated mid-save kills, consumed by launch/train.py's chaos path.
from repro.fault.schedule import (  # noqa: F401
    FaultEvent,
    FaultSchedule,
    GRAD_KINDS,
    KINDS,
    MEMBER_KINDS,
)
