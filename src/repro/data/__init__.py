from repro.data.loader import WorkerLoader  # noqa: F401
from repro.data.partition import (  # noqa: F401
    assignment_from_meta,
    assignment_to_meta,
    class_shard_partition,
    contiguous_assignment,
    dirichlet_partition,
    iid_partition,
    label_skew,
    repartition,
)
from repro.data.synthetic import (  # noqa: F401
    ClassificationData,
    assigned_token_stream,
    feature_classification,
    gaussian_classification,
    lm_token_stream,
)
