from repro.data.loader import WorkerLoader  # noqa: F401
from repro.data.partition import (  # noqa: F401
    class_shard_partition,
    dirichlet_partition,
    iid_partition,
    label_skew,
)
from repro.data.synthetic import (  # noqa: F401
    ClassificationData,
    feature_classification,
    gaussian_classification,
    lm_token_stream,
)
