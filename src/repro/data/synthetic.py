"""Synthetic datasets (the container is offline — no MNIST/DBPedia downloads).

Three generators mirror the paper's three tasks structurally:

  gaussian_classification — class-conditional Gaussian clusters; the analog
      of the paper's feature-extracted tasks (LeNet/MNIST features,
      Inception/tiny-ImageNet features). With class-sharded workers the
      inter-worker gradient variance is large — the paper's hard regime.
  feature_classification — fixed random "pretrained extractor" features
      (the transfer-learning task: 2048-d features -> MLP).
  lm_token_stream — per-worker unigram-skewed token sequences for the
      transformer configs (non-iid language modeling).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ClassificationData:
    x: np.ndarray        # (n, dim) float32
    y: np.ndarray        # (n,) int32
    num_classes: int


def gaussian_classification(n: int = 4096, dim: int = 64, num_classes: int = 10,
                            sep: float = 3.0, seed: int = 0) -> ClassificationData:
    rng = np.random.RandomState(seed)
    centers = rng.randn(num_classes, dim).astype(np.float32) * sep / np.sqrt(dim)
    y = rng.randint(0, num_classes, size=n).astype(np.int32)
    x = centers[y] + rng.randn(n, dim).astype(np.float32)
    return ClassificationData(x=x, y=y, num_classes=num_classes)


def feature_classification(n: int = 8192, dim: int = 2048, num_classes: int = 200,
                           seed: int = 0) -> ClassificationData:
    """Transfer-learning analog: well-separated features from a frozen
    extractor (paper §6.1 uses Inception-V3 2048-d features, 200 classes)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(num_classes, dim).astype(np.float32) * 0.15
    y = rng.randint(0, num_classes, size=n).astype(np.int32)
    x = centers[y] + 0.05 * rng.randn(n, dim).astype(np.float32)
    return ClassificationData(x=x, y=y, num_classes=num_classes)


def lm_token_stream(num_workers: int, seq_len: int, vocab: int,
                    steps: int, batch: int, *, alpha: float = 0.1,
                    identical: bool = False, seed: int = 0) -> np.ndarray:
    """(steps, W, batch, seq_len) int32 token batches.

    Non-identical: each worker samples from its own Dirichlet-skewed unigram
    distribution over a shared vocabulary (plus a shared bigram-ish structure
    via sorted runs so the task is learnable).
    """
    rng = np.random.RandomState(seed)
    if identical:
        probs = np.ones((num_workers, vocab)) / vocab
    else:
        probs = rng.dirichlet([alpha] * vocab, size=num_workers)
    out = np.empty((steps, num_workers, batch, seq_len), np.int32)
    for w in range(num_workers):
        draws = rng.choice(vocab, size=(steps, batch, seq_len), p=probs[w])
        out[:, w] = np.sort(draws, axis=-1)  # monotone runs => predictable
    return out


def assigned_token_stream(assignment: list[np.ndarray], seq_len: int,
                          vocab: int, steps: int, batch: int, *,
                          alpha: float = 0.1, identical: bool = False,
                          seed: int = 0) -> np.ndarray:
    """(steps, U, batch, seq_len) int32 token batches for U units (physical
    workers or logical clients) under a persistent shard→unit assignment.

    The stream is backed by ``n_shards = Σ len(assignment[u])`` shard-level
    Dirichlet(α)-skewed unigram distributions drawn from ``seed`` alone;
    unit ``u`` samples from the MEAN of its assigned shards' distributions.
    The distributions therefore survive a resharded resume: re-splitting
    the saved assignment with ``data.partition.repartition`` keeps each
    shard's skew attached to whichever unit inherits it, instead of
    re-drawing the whole stream.  With the trivial assignment (unit u ↔
    shard u, ``partition.contiguous_assignment(U, U)``) the output is
    BITWISE :func:`lm_token_stream` — fresh runs are unchanged.
    """
    num_units = len(assignment)
    n_shards = int(sum(len(a) for a in assignment))
    rng = np.random.RandomState(seed)
    if identical:
        unit_probs = np.ones((num_units, vocab)) / vocab
    else:
        shard_probs = rng.dirichlet([alpha] * vocab, size=n_shards)
        unit_probs = np.stack(
            [shard_probs[np.asarray(a, dtype=np.int64)].mean(axis=0)
             for a in assignment])
    out = np.empty((steps, num_units, batch, seq_len), np.int32)
    for u in range(num_units):
        draws = rng.choice(vocab, size=(steps, batch, seq_len),
                           p=unit_probs[u])
        out[:, u] = np.sort(draws, axis=-1)
    return out
