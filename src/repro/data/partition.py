"""Non-identical data partitioning (the paper's central experimental regime).

The paper's *non-identical case* gives each worker a disjoint subset of
classes ("when 5 workers train on 10 classes, each worker accesses two").
We implement that exact scheme plus the standard Dirichlet(α) relaxation
used in the federated-learning literature, and a skew metric to report the
extent of non-iid.
"""
from __future__ import annotations

import numpy as np


def class_shard_partition(labels: np.ndarray, num_workers: int,
                          seed: int = 0) -> list[np.ndarray]:
    """Paper's scheme: classes split disjointly across workers."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    rng.shuffle(classes)
    chunks = np.array_split(classes, num_workers)
    out = []
    for ch in chunks:
        idx = np.flatnonzero(np.isin(labels, ch))
        rng.shuffle(idx)
        out.append(idx)
    return out


def dirichlet_partition(labels: np.ndarray, num_workers: int,
                        alpha: float = 0.1, seed: int = 0) -> list[np.ndarray]:
    """Dirichlet(α) label-skew partition; α→0 approaches class sharding,
    α→∞ approaches iid."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    buckets: list[list[int]] = [[] for _ in range(num_workers)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_workers)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for w, part in enumerate(np.split(idx, cuts)):
            buckets[w].extend(part.tolist())
    return [np.array(sorted(b)) for b in buckets]


def iid_partition(n: int, num_workers: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return list(np.array_split(idx, num_workers))


def repartition(parts: list[np.ndarray],
                num_workers: int) -> list[np.ndarray]:
    """Re-split an existing partition over a different worker count
    (checkpointed resharding: resume a W-worker run on W' workers).

    Concatenates the old assignment in worker order and ``array_split``s
    it — every index appears exactly once afterwards, and the old
    per-worker ordering (including any non-iid structure) is preserved
    as contiguous runs, which is the closest W'-way analogue of the
    original skew."""
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1, got {num_workers}")
    allidx = np.concatenate([np.asarray(p) for p in parts])
    return list(np.array_split(allidx, num_workers))


def label_skew(labels: np.ndarray, parts: list[np.ndarray]) -> float:
    """Mean total-variation distance between worker label dists and global."""
    classes = np.unique(labels)
    global_p = np.array([(labels == c).mean() for c in classes])
    tvs = []
    for idx in parts:
        lp = np.array([(labels[idx] == c).mean() for c in classes])
        tvs.append(0.5 * np.abs(lp - global_p).sum())
    return float(np.mean(tvs))
