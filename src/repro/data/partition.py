"""Non-identical data partitioning (the paper's central experimental regime).

The paper's *non-identical case* gives each worker a disjoint subset of
classes ("when 5 workers train on 10 classes, each worker accesses two").
We implement that exact scheme plus the standard Dirichlet(α) relaxation
used in the federated-learning literature, and a skew metric to report the
extent of non-iid.
"""
from __future__ import annotations

import numpy as np


def class_shard_partition(labels: np.ndarray, num_workers: int,
                          seed: int = 0) -> list[np.ndarray]:
    """Paper's scheme: classes split disjointly across workers."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    rng.shuffle(classes)
    chunks = np.array_split(classes, num_workers)
    out = []
    for ch in chunks:
        idx = np.flatnonzero(np.isin(labels, ch))
        rng.shuffle(idx)
        out.append(idx)
    return out


def dirichlet_partition(labels: np.ndarray, num_workers: int,
                        alpha: float = 0.1, seed: int = 0) -> list[np.ndarray]:
    """Dirichlet(α) label-skew partition; α→0 approaches class sharding,
    α→∞ approaches iid.

    Every worker is guaranteed at least one index (small α starves
    buckets; an empty bucket would otherwise come back as a float64
    array — ``np.array([])`` — and corrupt downstream fancy indexing),
    and every returned array is ``int64``.  Raises when there are fewer
    samples than workers, since the guarantee is then unsatisfiable.
    """
    if len(labels) < num_workers:
        raise ValueError(
            f"cannot give every worker an index: {len(labels)} samples "
            f"< {num_workers} workers")
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    buckets: list[list[int]] = [[] for _ in range(num_workers)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_workers)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for w, part in enumerate(np.split(idx, cuts)):
            buckets[w].extend(part.tolist())
    parts = [np.array(sorted(b), dtype=np.int64) for b in buckets]
    # deterministic repair: feed each starved bucket one index from the
    # currently-largest bucket (ties broken by lowest worker id)
    while any(len(p) == 0 for p in parts):
        empty = min(w for w in range(num_workers) if len(parts[w]) == 0)
        donor = max(range(num_workers), key=lambda w: (len(parts[w]), -w))
        parts[empty] = parts[donor][-1:]
        parts[donor] = parts[donor][:-1]
    return parts


def iid_partition(n: int, num_workers: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return list(np.array_split(idx, num_workers))


def repartition(parts: list[np.ndarray],
                num_workers: int) -> list[np.ndarray]:
    """Re-split an existing partition over a different worker count
    (checkpointed resharding: resume a W-worker run on W' workers).

    Concatenates the old assignment in worker order and ``array_split``s
    it — every index appears exactly once afterwards, and the old
    per-worker ordering (including any non-iid structure) is preserved
    as contiguous runs, which is the closest W'-way analogue of the
    original skew."""
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1, got {num_workers}")
    allidx = np.concatenate(
        [np.asarray(p, dtype=np.int64) for p in parts] or
        [np.empty(0, np.int64)])
    if len(allidx) < num_workers:
        raise ValueError(
            f"cannot give every worker an index: {len(allidx)} indices "
            f"< {num_workers} workers")
    return [np.asarray(p, dtype=np.int64)
            for p in np.array_split(allidx, num_workers)]


def contiguous_assignment(n_shards: int,
                          num_units: int) -> list[np.ndarray]:
    """Fresh shard→unit assignment: ``n_shards`` data shards split
    contiguously over ``num_units`` units (physical workers or logical
    clients).  This is the assignment a run starts from; a resumed run
    must NOT call this again — it re-splits the saved assignment with
    :func:`repartition` so per-unit data continuity survives a unit-count
    change (the resharded-resume path)."""
    if num_units < 1:
        raise ValueError(f"num_units must be >= 1, got {num_units}")
    if n_shards < num_units:
        raise ValueError(
            f"cannot give every unit a shard: {n_shards} shards "
            f"< {num_units} units")
    shards = np.arange(n_shards, dtype=np.int64)
    return [np.asarray(p, dtype=np.int64)
            for p in np.array_split(shards, num_units)]


def assignment_to_meta(parts: list[np.ndarray]) -> list[list[int]]:
    """JSON-safe form of an assignment, for embedding in checkpoint
    metadata (``launch.train`` threads it through ``--resume``)."""
    return [[int(i) for i in np.asarray(p).ravel()] for p in parts]


def assignment_from_meta(meta: list[list[int]]) -> list[np.ndarray]:
    return [np.asarray(p, dtype=np.int64) for p in meta]


def label_skew(labels: np.ndarray, parts: list[np.ndarray]) -> float:
    """Mean total-variation distance between worker label dists and global."""
    classes = np.unique(labels)
    global_p = np.array([(labels == c).mean() for c in classes])
    tvs = []
    for idx in parts:
        lp = np.array([(labels[idx] == c).mean() for c in classes])
        tvs.append(0.5 * np.abs(lp - global_p).sum())
    return float(np.mean(tvs))
