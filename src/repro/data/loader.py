"""Deterministic per-worker batch iterator over partitioned datasets."""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.data.partition import (
    class_shard_partition,
    dirichlet_partition,
    iid_partition,
)
from repro.data.synthetic import ClassificationData


class WorkerLoader:
    """Yields worker-stacked batches (W, b, ...) forever, deterministically.

    Each worker cycles through its own shard with an independent shuffle
    stream — the paper's experimental setup (per-GPU disjoint data).
    """

    def __init__(self, data: ClassificationData, num_workers: int, batch: int,
                 *, partition: str = "class_shard", alpha: float = 0.1,
                 seed: int = 0):
        self.data = data
        self.batch = batch
        self.num_workers = num_workers
        if partition == "class_shard":
            self.parts = class_shard_partition(data.y, num_workers, seed)
        elif partition == "dirichlet":
            self.parts = dirichlet_partition(data.y, num_workers, alpha, seed)
        elif partition == "iid":
            self.parts = iid_partition(len(data.y), num_workers, seed)
        else:
            raise ValueError(partition)
        self._rngs = [np.random.RandomState(seed + 1000 + w)
                      for w in range(num_workers)]
        self._cursors = [np.array([], dtype=np.int64)] * num_workers

    def _next_idx(self, w: int) -> np.ndarray:
        while len(self._cursors[w]) < self.batch:
            perm = self._rngs[w].permutation(self.parts[w])
            self._cursors[w] = np.concatenate([self._cursors[w], perm])
        idx, self._cursors[w] = (self._cursors[w][:self.batch],
                                 self._cursors[w][self.batch:])
        return idx

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        while True:
            idx = [self._next_idx(w) for w in range(self.num_workers)]
            xs = np.stack([self.data.x[i] for i in idx])   # (W, b, dim)
            ys = np.stack([self.data.y[i] for i in idx])   # (W, b)
            yield xs, ys
