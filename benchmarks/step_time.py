"""Paper §6.1 Metrics: 'VRL-SGD and Local SGD have the same training time in
one epoch'. We verify the claim on CPU: the VRL local step's overhead over
Local SGD's (the Δ subtraction) is a small fraction of step time, and the
fused Pallas vrl_update kernel removes most of it."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv, timeit
from repro.configs import registry
from repro.configs.base import VRLConfig
from repro.train.train_loop import make_train_step


def main() -> dict:
    cfg = registry.smoke_arch("granite-3-2b", num_layers=2, d_model=128,
                              d_ff=512, vocab_size=512)
    w, b, s = 4, 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(0), (w, b, s), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, -1)
    out = {}
    for alg in ["vrl_sgd", "local_sgd", "ssgd"]:
        vrl = VRLConfig(algorithm=alg, comm_period=20, learning_rate=0.01)
        bundle = make_train_step(cfg, vrl, remat=False)
        state = bundle.init_state(jax.random.PRNGKey(0), w)
        step = jax.jit(bundle.local_step)
        us = timeit(lambda: step(state, toks, labels), iters=20)
        out[alg] = us
        csv(f"step_time/local_step/{alg}", us, "smoke-scale CPU wall time")
    overhead = (out["vrl_sgd"] - out["local_sgd"]) / out["local_sgd"]
    csv("step_time/vrl_overhead_vs_local", 0.0,
        f"relative={overhead:+.3%} (paper claims ~0)")
    return out


if __name__ == "__main__":
    main()
