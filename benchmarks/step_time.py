"""Paper §6.1 Metrics: 'VRL-SGD and Local SGD have the same training time in
one epoch'. We verify the claim on CPU: the VRL local step's overhead over
Local SGD's (the Δ subtraction) is a small fraction of step time, and the
fused Pallas vrl_update kernel removes most of it.

Also benchmarks the flat-buffer engine (core/engine.py) against the
reference tree path — pure update math (no model forward/backward) at two
model sizes — and records the numbers in BENCH_engine.json so the perf
trajectory is tracked from PR 1 onward.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv, percentile, timeit, timeit_samples
from repro.comm import compressors as cc
from repro.configs import registry
from repro.configs.base import EngineConfig, HierConfig, VRLConfig
from repro.core import flat, get_algorithm, hierarchical, make_engine, \
    resolve_backend
from repro.launch import roofline as rl
from repro.train.train_loop import make_train_step

# the launch driver's default --log-every: one diagnostics pass per this
# many rounds — the cadence the amortized diag gate assumes
DIAG_CADENCE = 10


def _stats(samples) -> dict:
    """mean/p50/p95 of a µs sample list, rounded for the JSON artifact."""
    return {"round_us": round(sum(samples) / len(samples), 1),
            "round_p50_us": round(percentile(samples, 50), 1),
            "round_p95_us": round(percentile(samples, 95), 1)}


def main() -> dict:
    cfg = registry.smoke_arch("granite-3-2b", num_layers=2, d_model=128,
                              d_ff=512, vocab_size=512)
    w, b, s = 4, 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(0), (w, b, s), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, -1)
    out = {}
    for alg in ["vrl_sgd", "local_sgd", "ssgd"]:
        vrl = VRLConfig(algorithm=alg, comm_period=20, learning_rate=0.01)
        bundle = make_train_step(cfg, vrl, remat=False)
        state = bundle.init_state(jax.random.PRNGKey(0), w)
        step = jax.jit(bundle.local_step)
        us = timeit(lambda: step(state, toks, labels), iters=20)
        out[alg] = us
        csv(f"step_time/local_step/{alg}", us, "smoke-scale CPU wall time")
    overhead = (out["vrl_sgd"] - out["local_sgd"]) / out["local_sgd"]
    csv("step_time/vrl_overhead_vs_local", 0.0,
        f"relative={overhead:+.3%} (paper claims ~0)")
    return out


# --------------------------------------------------- engine update-math bench
def _mlp_template(key, dim: int):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (dim, dim)) * 0.02,
            "b1": jnp.zeros((dim,)),
            "w2": jax.random.normal(k2, (dim, dim)) * 0.02,
            "b2": jnp.zeros((dim,))}


def _tree_nbytes(tree) -> int:
    """Total bytes of a pytree's leaves (arrays or ShapeDtypeStructs)."""
    return int(sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def _skip_interpret(include_interpret: bool) -> bool:
    """True when the fused rows should be skipped: Pallas would run in
    interpret mode here (auto resolves away from it), the timings measure
    python dispatch rather than the kernel, and they dominate CI wall
    clock.  ``--include-interpret`` opts back in."""
    return not include_interpret and resolve_backend("auto") != "fused"


def bench_engine(*, workers: int = 4, dims=(256, 1024), iters: int = 10,
                 out_path: str = "BENCH_engine.json",
                 include_interpret: bool = False) -> dict:
    """Fused flat-buffer engine vs reference tree path, update math only.

    Times one local step and one sync at each model size (n_params ≈
    2·dim² + 2·dim per worker).  On CPU the Pallas kernels run in interpret
    mode — those fused rows measure python dispatch, not HBM traffic, so
    they are SKIPPED by default off-TPU/GPU (``--include-interpret`` opts
    back in); the dry-run/roofline artifacts carry the TPU story.  Each
    size row also records ``engine_state_bytes``, the total bytes the flat
    engine persists between steps (params + Δ + moments across workers).
    """
    skip = _skip_interpret(include_interpret)
    results = {"workers": workers, "sizes": {},
               "fused_skipped": skip}
    for dim in dims:
        params = _mlp_template(jax.random.PRNGKey(0), dim)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        grads = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sin(x), (workers, *x.shape)),
            params)
        row = {"n_params": int(n_params)}
        for backend in (["reference"] if skip else ["reference", "fused"]):
            cfg = VRLConfig(algorithm="vrl_sgd", comm_period=20,
                            learning_rate=0.01, weight_decay=1e-4,
                            update_backend=backend)
            if backend == "fused":
                eng = make_engine(cfg, jax.eval_shape(lambda: params))
                state = eng.init(params, workers)
                local = jax.jit(eng.local_step)
                sync = jax.jit(eng.sync)
                us_local = timeit(lambda: local(state, grads), iters=iters)
                us_sync = timeit(lambda: sync(state), iters=iters)
            else:
                alg = get_algorithm("vrl_sgd")
                state = alg.init(cfg, params, workers)
                local = jax.jit(lambda s, g: alg.local_step(cfg, s, g))
                sync = jax.jit(lambda s: alg.sync(cfg, s))
                us_local = timeit(lambda: local(state, grads), iters=iters)
                us_sync = timeit(lambda: sync(state), iters=iters)
            row[backend] = {"local_us": round(us_local, 1),
                            "sync_us": round(us_sync, 1)}
            csv(f"engine/{backend}/local_step/d{dim}", us_local,
                f"{n_params/1e6:.2f}M params x {workers} workers")
            csv(f"engine/{backend}/sync/d{dim}", us_sync, "")
        cfg_x = VRLConfig(algorithm="vrl_sgd", comm_period=20,
                          learning_rate=0.01, weight_decay=1e-4,
                          update_backend="xla")
        eng_x = make_engine(cfg_x, jax.eval_shape(lambda: params))
        row["engine_state_bytes"] = _tree_nbytes(
            jax.eval_shape(lambda: eng_x.init(params, workers)))
        results["sizes"][str(dim)] = row
    results["backend"] = jax.default_backend()
    _merge_json(out_path, results)
    return results


def _merge_json(out_path: str, updates: dict) -> None:
    """Update BENCH_engine.json in place (bench_engine and
    bench_hierarchical each own disjoint top-level keys)."""
    data = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                data = json.load(f)
        except ValueError:
            data = {}
    data.update(updates)
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1)
    print(f"wrote {os.path.abspath(out_path)}")


def bench_hierarchical(*, grid=(2, 2), k1: int = 2, k2: int = 4,
                       dims=(256, 1024), iters: int = 10,
                       out_path: str = "BENCH_engine.json",
                       include_interpret: bool = False) -> dict:
    """Two-level engine, flat-buffer executor vs reference tree path.

    Times one local step (both Δ corrections fused in), each sync level
    alone, and the composed k2-boundary — the numbers land under
    ``hierarchical`` in BENCH_engine.json next to bench_engine's flat rows.
    The engine rows run the fused Pallas executor on TPU/GPU; off those
    backends Pallas would interpret, so the rows fall back to the xla
    executor (``engine_backend`` records which; ``--include-interpret``
    forces fused anyway) — the rows stay keyed "fused" so the artifact's
    shape is stable across hosts.
    """
    p_, d_ = grid
    engine_backend = ("xla" if _skip_interpret(include_interpret)
                      else "fused")
    hier = {"grid": list(grid), "k1": k1, "k2": k2,
            "engine_backend": engine_backend, "sizes": {}}
    for dim in dims:
        params = _mlp_template(jax.random.PRNGKey(0), dim)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        grads = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sin(x), (p_, d_, *x.shape)),
            params)
        cfg = VRLConfig(algorithm="hier_vrl_sgd", learning_rate=0.01,
                        weight_decay=1e-4, update_backend=engine_backend,
                        hier=HierConfig(k1=k1, k2=k2, grid=grid))
        row = {"n_params": int(n_params)}

        eng = make_engine(cfg, jax.eval_shape(lambda: params))
        state = eng.init(params, p_ * d_)
        row["engine_state_bytes"] = _tree_nbytes(state)
        flocal = jax.jit(eng.local_step)
        fs1, fs2 = jax.jit(eng.sync1), jax.jit(eng.sync2)
        fsync = jax.jit(eng.sync)
        fused = {
            "local_us": timeit(lambda: flocal(state, grads), iters=iters),
            "sync1_us": timeit(lambda: fs1(state), iters=iters),
            "sync2_us": timeit(lambda: fs2(state), iters=iters),
            "sync_us": timeit(lambda: fsync(state), iters=iters),
        }

        rstate = hierarchical.init(cfg, params, grid)
        rlocal = jax.jit(lambda s, g: hierarchical.local_step(cfg, s, g))
        rs1 = jax.jit(lambda s: hierarchical.sync_level1(cfg, s))
        rs2 = jax.jit(lambda s: hierarchical.sync_level2(cfg, s))
        rsync = jax.jit(lambda s: hierarchical.sync(cfg, s))
        ref = {
            "local_us": timeit(lambda: rlocal(rstate, grads), iters=iters),
            "sync1_us": timeit(lambda: rs1(rstate), iters=iters),
            "sync2_us": timeit(lambda: rs2(rstate), iters=iters),
            "sync_us": timeit(lambda: rsync(rstate), iters=iters),
        }
        row["fused"] = {k: round(v, 1) for k, v in fused.items()}
        row["reference"] = {k: round(v, 1) for k, v in ref.items()}
        hier["sizes"][str(dim)] = row
        for backend, us in [("fused", fused), ("reference", ref)]:
            csv(f"engine/hier/{backend}/local_step/d{dim}", us["local_us"],
                f"{n_params/1e6:.2f}M params x {p_}x{d_} grid")
            csv(f"engine/hier/{backend}/sync1/d{dim}", us["sync1_us"], "")
            csv(f"engine/hier/{backend}/sync2/d{dim}", us["sync2_us"], "")
    _merge_json(out_path, {"hierarchical": hier})
    return hier


def _bench_rounds_alg(alg_name: str, *, workers: int, k: int, dims,
                      iters: int, fused_iters: int, auto: str,
                      include_interpret: bool = False) -> dict:
    """One algorithm's round timings per backend at every model size."""
    skip = _skip_interpret(include_interpret)
    sizes = {}
    for dim in dims:
        params = _mlp_template(jax.random.PRNGKey(0), dim)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        grads = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sin(x), (workers, *x.shape)),
            params)
        # per-step grads stack for the round path (materialized: the round
        # consumes a prefetched (k, W, ...) buffer, as launch/train does)
        scale = (1.0 + 0.01 * jnp.arange(k, dtype=jnp.float32))
        grads_k = jax.tree.map(
            lambda g: g[None] * scale.reshape((k,) + (1,) * g.ndim), grads)
        row = {"n_params": int(n_params)}

        cfg_ref = VRLConfig(algorithm=alg_name, comm_period=k,
                            learning_rate=0.01, weight_decay=1e-4,
                            update_backend="reference")
        alg = get_algorithm(alg_name)
        rstate = alg.init(cfg_ref, params, workers)
        local = jax.jit(lambda s, g: alg.local_step(cfg_ref, s, g))
        sync = jax.jit(lambda s: alg.sync(cfg_ref, s))

        def ref_round(s):
            for i in range(k):
                s = local(s, grads)
            return sync(s)

        row["reference"] = _stats(timeit_samples(
            lambda: ref_round(rstate), iters=iters))

        for backend in (["xla"] if skip else ["xla", "fused"]):
            cfg = VRLConfig(algorithm=alg_name, comm_period=k,
                            learning_rate=0.01, weight_decay=1e-4,
                            update_backend=backend)
            eng = make_engine(cfg, jax.eval_shape(lambda: params))
            gk_buf = jax.jit(lambda g: jax.vmap(
                lambda t: flat.flatten_stacked(eng.spec, t,
                                               dtype=eng.spec.dtype)
            )(g))(grads_k)
            rstep = jax.jit(eng.round_step_flat, donate_argnums=(0,))
            # donation chains: every call's input is the previous call's
            # (freshly allocated) output, so the donated buffers stay live
            box = [eng.init(params, workers)]
            if backend == "xla":
                row["engine_state_bytes"] = _tree_nbytes(box[0])

            def one_round():
                box[0] = rstep(box[0], gk_buf)
                return box[0]

            it = fused_iters if backend == "fused" else iters
            row[backend] = _stats(timeit_samples(one_round, iters=it,
                                                 warmup_iters=1))
            if backend == "xla":
                # telemetry overhead: one Engine.diagnostics pass (its
                # own jit, never part of the round) against the round it
                # rides along with.  The driver fires it every
                # --log-every rounds (default 10), so the gated figure is
                # the AMORTIZED per-round cost at that cadence;
                # diag_over_round keeps the raw one-pass ratio honest.
                diag_fn = jax.jit(eng.diagnostics)
                row["diag"] = _stats(timeit_samples(
                    lambda: diag_fn(box[0]), iters=it, warmup_iters=1))
                row["diag_over_round"] = round(
                    (row["xla"]["round_us"] + row["diag"]["round_us"])
                    / row["xla"]["round_us"], 3)
                row["diag_amortized"] = round(
                    (row["xla"]["round_us"]
                     + row["diag"]["round_us"] / DIAG_CADENCE)
                    / row["xla"]["round_us"], 3)
                csv(f"engine/rounds/{alg_name}/diag/d{dim}",
                    row["diag"]["round_us"],
                    f"diag_over_round={row['diag_over_round']};"
                    f"amortized_every{DIAG_CADENCE}="
                    f"{row['diag_amortized']}")
        for backend in ["reference", "xla", "fused"]:
            if backend not in row:
                continue
            csv(f"engine/rounds/{alg_name}/{backend}/d{dim}",
                row[backend]["round_us"],
                f"{n_params/1e6:.2f}M params x {workers} workers, k={k}")
        if "fused" in row:
            row["fused_over_reference"] = round(
                row["fused"]["round_us"] / row["reference"]["round_us"], 3)
        row["auto_over_reference"] = round(
            row[auto]["round_us"] / row["reference"]["round_us"], 3)
        sizes[str(dim)] = row
    return sizes


def bench_rounds(*, workers: int = 4, k: int = 8, dims=(256, 1024),
                 iters: int = 5, out_path: str = "BENCH_engine.json",
                 fused_iters: int = 1, include_interpret: bool = False,
                 algs=("vrl_sgd",)) -> dict:
    """Round execution per backend vs the reference per-step path.

    A "round" is one communication period: the reference path pays k
    python jit dispatches (one per local step) plus a sync dispatch; the
    engine's ``round_step`` compiles the whole period into one ``lax.scan``
    + sync.  Times one round of each at every model size for the fused
    (Pallas — interpret-mode on CPU, so expect it to lose there; those
    rows are skipped by default off-TPU/GPU, ``--include-interpret`` opts
    back in), xla, and reference executors, and records which backend
    "auto" resolves to.  Each size row carries ``engine_state_bytes`` —
    what the flat engine persists between steps for this algorithm.
    Each path gets grads in its native layout (tree for reference,
    pre-flattened (k, W, R, C) for the engine — ``round_step_flat``) and
    the engine round donates its state, exactly the launch-driver
    contract.

    Every backend row records mean AND p50/p95 per-round wall-clock
    (``round_us`` / ``round_p50_us`` / ``round_p95_us``) — the tails are
    what the overlapped round's straggler deadline is built to absorb, so
    the artifact has to show them, not average them away.

    ``algs`` extends the matrix beyond vrl_sgd (CI runs the engine-variant
    specs stl_sgd and bvr_l_sgd through the same gate); vrl_sgd's rows
    stay under the top-level "sizes" key so the PR-3 perf trajectory in
    BENCH_engine.json remains comparable, and every algorithm (vrl_sgd
    included) lands under "by_alg".

    This is the tracked number for the PR-1 regression BENCH_engine.json
    documents (interpret-mode "fused" ~30x slower than reference on CPU):
    CI gates on auto/reference <= 1.2 (``--bench rounds --gate-ratio``),
    and on CPU the auto (= xla) round must beat the reference path
    outright.  ``fused_iters`` keeps the interpret-mode timing affordable.
    """
    auto = resolve_backend("auto")
    rounds = {"workers": workers, "k": k, "auto_backend": auto,
              "fused_skipped": _skip_interpret(include_interpret),
              "by_alg": {}}
    for alg_name in algs:
        rounds["by_alg"][alg_name] = _bench_rounds_alg(
            alg_name, workers=workers, k=k, dims=dims, iters=iters,
            fused_iters=fused_iters, auto=auto,
            include_interpret=include_interpret)
    if "vrl_sgd" in rounds["by_alg"]:
        rounds["sizes"] = rounds["by_alg"]["vrl_sgd"]
    _merge_json(out_path, {"rounds": rounds})
    return rounds


def gate_rounds(rounds: dict, ratio: float) -> int:
    """CI gate: the auto backend's round must stay within ``ratio`` x the
    reference per-step path at every size, for every benched algorithm.
    Returns a process exit code."""
    by_alg = rounds.get("by_alg") or {"vrl_sgd": rounds["sizes"]}
    bad = []
    for alg_name, sizes in by_alg.items():
        for dim, row in sizes.items():
            if row["auto_over_reference"] > ratio:
                bad.append((alg_name, dim, row["auto_over_reference"]))
    if bad:
        print(f"ROUND GATE FAILED: auto ({rounds['auto_backend']}) round "
              f"exceeds {ratio}x the reference path at: "
              + ", ".join(f"{a}/d{d} ({r}x)" for a, d, r in bad))
        return 1
    print(f"round gate OK: auto ({rounds['auto_backend']}) / reference <= "
          f"{ratio} at all sizes for {sorted(by_alg)}")
    return 0


def gate_diag(rounds: dict, ratio: float) -> int:
    """CI gate: telemetry must not slow training past ``ratio`` x the
    bare round wall-clock.  The gated figure is the AMORTIZED per-round
    cost at the driver's default cadence (one diagnostics pass every
    ``DIAG_CADENCE`` = --log-every rounds), per benched algorithm at its
    LARGEST size: tiny sizes are dispatch-latency bound — there the diag
    pass's fixed python+dispatch cost rivals the round itself and the
    ratio measures the host, not the pass — so the gate reads the size
    where compute dominates.  Returns a process exit code."""
    by_alg = rounds.get("by_alg") or {"vrl_sgd": rounds["sizes"]}
    bad, checked = [], []
    for alg_name, sizes in by_alg.items():
        dims_here = [d for d, row in sizes.items()
                     if "diag_amortized" in row]
        if not dims_here:
            continue
        top = max(dims_here, key=lambda d: sizes[d]["n_params"])
        r = sizes[top]["diag_amortized"]
        checked.append((alg_name, top, r))
        if r > ratio:
            bad.append((alg_name, top, r))
    if not checked:
        print("DIAG GATE FAILED: no diag timings recorded (xla rows "
              "missing?)")
        return 1
    if bad:
        print(f"DIAG GATE FAILED: amortized (round + diag/"
              f"{DIAG_CADENCE}) / round exceeds {ratio}x at: "
              + ", ".join(f"{a}/d{d} ({r}x)" for a, d, r in bad))
        return 1
    print("diag gate OK (amortized, 1 pass per "
          f"{DIAG_CADENCE} rounds): "
          + ", ".join(f"{a}/d{d} {r}x" for a, d, r in checked)
          + f" <= {ratio}")
    return 0


# --------------------------------------------------- overlapped-round bench
def bench_overlap(*, workers: int = 8, k: int = 4, dims=(1024,),
                  iters: int = 20, out_path: str = "BENCH_engine.json",
                  algs=("vrl_sgd",)) -> dict:
    """Overlapped vs blocking round on a real multi-device mesh.

    Times, per algorithm and model size: the blocking round (sync at the
    end, on the critical path), the overlapped round (sync collective
    issued at round start over the previous boundary's transmitted
    positions, folded one-round-stale at the end), and the sync collective
    alone.  All three are sampled INTERLEAVED round-robin (paired
    back-to-back per iteration, order alternating) so machine-load drift
    cancels out of the ratios.  Records mean/p50/p95 of each, the overlap
    speedup, and a reconciliation of the measured overlapped round against
    ``launch.roofline.round_walltime`` in both regimes — collective hidden
    (async backends) and serial t_local + t_coll (XLA:CPU) — from the two
    measured pieces (t_local = blocking − sync, t_coll = sync).

    Needs >= ``workers`` devices for the collective to cost anything
    (CI: XLA_FLAGS=--xla_force_host_platform_device_count=8); with fewer
    it falls back to the meshless engine — the collective degenerates to
    a local mean and overlap can only tie, so the fallback is recorded
    (``mesh: false``) and the gate should be read accordingly.
    """
    devs = jax.devices()
    mesh = None
    if len(devs) >= workers:
        import numpy as np
        mesh = jax.sharding.Mesh(np.array(devs[:workers]), ("data",))
    else:
        print(f"bench_overlap: only {len(devs)} devices for {workers} "
              f"workers — meshless fallback (no real collective to hide)")
    out = {"workers": workers, "k": k, "mesh": mesh is not None,
           "auto_backend": resolve_backend("auto"), "by_alg": {}}
    for alg_name in algs:
        sizes = {}
        for dim in dims:
            params = _mlp_template(jax.random.PRNGKey(0), dim)
            n_params = sum(p.size for p in jax.tree.leaves(params))
            grads = jax.tree.map(
                lambda x: jnp.broadcast_to(jnp.sin(x), (workers, *x.shape)),
                params)
            scale = (1.0 + 0.01 * jnp.arange(k, dtype=jnp.float32))
            grads_k = jax.tree.map(
                lambda g: g[None] * scale.reshape((k,) + (1,) * g.ndim),
                grads)
            row = {"n_params": int(n_params)}
            # build BOTH engines up front and interleave the paired
            # measurements round-robin: blocking/overlap samples taken
            # back-to-back see the same machine load, so drift from other
            # processes cancels out of the ratio instead of landing on
            # whichever mode happened to run second
            rounds, syncs = {}, {}
            for mode in ("blocking", "overlap"):
                cfg = VRLConfig(algorithm=alg_name, comm_period=k,
                                learning_rate=0.01, weight_decay=1e-4,
                                update_backend="auto",
                                overlap=(mode == "overlap"))
                eng = make_engine(cfg, jax.eval_shape(lambda: params),
                                  mesh=mesh, worker_axes=("data",))
                gk_buf = jax.jit(lambda g: jax.vmap(
                    lambda t: flat.flatten_stacked(eng.spec, t,
                                                   dtype=eng.spec.dtype)
                )(g))(grads_k)
                rstep = jax.jit(eng.round_step_flat, donate_argnums=(0,))
                box = [eng.init(params, workers)]

                def one_round(box=box, rstep=rstep, gk_buf=gk_buf):
                    box[0] = rstep(box[0], gk_buf)
                    return box[0]

                rounds[mode] = one_round
                if mode == "blocking":
                    # the collective alone, same engine/mesh — the piece
                    # the overlapped round is trying to hide
                    sync = jax.jit(eng.sync)
                    st = eng.init(params, workers)
                    syncs["sync"] = lambda sync=sync, st=st: sync(st)
            fns = {**rounds, **syncs}
            for fn in fns.values():  # compile + warm every path first
                for _ in range(2):
                    jax.block_until_ready(fn())
            samples = {name: [] for name in fns}
            for i in range(iters):
                # alternate within-pair order too, so neither mode always
                # pays the cache-warming cost of running first
                order = list(fns) if i % 2 == 0 else list(fns)[::-1]
                for name in order:
                    t0 = time.perf_counter()
                    jax.block_until_ready(fns[name]())
                    samples[name].append((time.perf_counter() - t0) * 1e6)
            sync_stats = _stats(samples["sync"])
            for mode in ("blocking", "overlap"):
                row[mode] = _stats(samples[mode])
                csv(f"engine/overlap/{alg_name}/{mode}/d{dim}",
                    row[mode]["round_us"],
                    f"{n_params/1e6:.2f}M params x {workers} workers, "
                    f"k={k}, p50={row[mode]['round_p50_us']} "
                    f"p95={row[mode]['round_p95_us']}")
            row["sync"] = sync_stats
            row["speedup_p50"] = round(
                row["blocking"]["round_p50_us"]
                / row["overlap"]["round_p50_us"], 3)
            # wall-clock reconciliation against the roofline's round model
            # (p50s: CPU multi-device means are straggler-skewed — the p95
            # columns show by how much).  Two predictions: "hidden" is
            # round_walltime with the collective overlapped (async-
            # collective backends); "serial" is t_local + t_coll, which is
            # what XLA:CPU actually executes (synchronous all-reduce, in
            # schedule order) — overhead_vs_serial isolates the fold cost.
            t_local = max(row["blocking"]["round_p50_us"]
                          - sync_stats["round_p50_us"], 0.0)
            t_coll = sync_stats["round_p50_us"]
            hidden = rl.round_walltime(t_local, t_coll, overlap=True)
            serial = t_local + t_coll
            row["reconcile"] = {
                "t_local_us": round(t_local, 1),
                "t_coll_us": t_coll,
                "predicted_hidden_us": round(hidden, 1),
                "predicted_serial_us": round(serial, 1),
                "measured_us": row["overlap"]["round_p50_us"],
                "overhead_vs_serial": round(
                    row["overlap"]["round_p50_us"] / max(serial, 1e-9), 3)}
            sizes[str(dim)] = row
        out["by_alg"][alg_name] = sizes
    _merge_json(out_path, {"overlap": out})
    return out


def gate_overlap(res: dict, ratio: float) -> int:
    """CI gate over bench_overlap: the overlapped round's p50 must stay
    within ``ratio`` x the blocking round's p50 at every size, for every
    benched algorithm.  On XLA:CPU this is an OVERHEAD bound, not a
    speedup check: the CPU runtime executes each device's schedule in
    order with a synchronous all-reduce, so the collective is never
    actually hidden and the overlapped round pays t_local + t_coll + the
    fold — ``ratio`` caps that fold overhead (measured ~1.15x).  The
    hiding itself is gated structurally (the all-reduce must not depend
    on the local-step scan, tests/test_overlap.py) and modeled by
    ``launch.roofline.round_walltime`` for backends with async
    collectives.  Returns an exit code."""
    bad = []
    for alg_name, sizes in res["by_alg"].items():
        for dim, row in sizes.items():
            r = row["overlap"]["round_p50_us"] / row["blocking"]["round_p50_us"]
            if r > ratio:
                bad.append(f"{alg_name}/d{dim} overlap p50 {r:.3f}x "
                           f"blocking > {ratio}x")
    if bad:
        print("OVERLAP GATE FAILED: " + "; ".join(bad))
        return 1
    print(f"overlap gate OK: overlapped round p50 <= {ratio}x blocking "
          f"at all sizes for {sorted(res['by_alg'])} "
          f"(mesh={res['mesh']})")
    return 0


# ------------------------------------------------- compressed-sync bench
def bench_compressed(*, workers: int = 4, k: int = 8, dims=(256, 1024),
                     iters: int = 3, out_path: str = "BENCH_engine.json",
                     compressors=("none", "int8", "topk")) -> dict:
    """Compressed rounds (repro.comm): measured bytes/round + round time.

    For each compressor this runs real vrl_sgd rounds on the auto backend
    (state donated, pre-flattened grads — the launch-driver contract) and
    then MEASURES the sync wire bytes on the actual end-of-round payload:
    ``repro.comm.compress`` builds the real wire representation arrays
    (int8 values + per-row scales / fixed-k values + indices, tile-padding
    rows elided) and ``rep_nbytes`` counts their bytes — no formulas.  The
    raw baseline is the padded flat buffer the uncompressed all-reduce
    carries.  Results land under "compressed" in BENCH_engine.json; the CI
    gate (``gate_compressed``) holds the headline claim: >= 4x for int8
    and >= 10x for topk at this config, and compressed rounds within a
    bounded slowdown of the uncompressed round.
    """
    auto = resolve_backend("auto")
    out = {"workers": workers, "k": k, "auto_backend": auto, "sizes": {}}
    for dim in dims:
        params = _mlp_template(jax.random.PRNGKey(0), dim)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        grads = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sin(x), (workers, *x.shape)),
            params)
        scale = (1.0 + 0.01 * jnp.arange(k, dtype=jnp.float32))
        grads_k = jax.tree.map(
            lambda g: g[None] * scale.reshape((k,) + (1,) * g.ndim), grads)
        row = {"n_params": int(n_params)}
        base_us = None
        for comp_name in compressors:
            comp = cc.parse_compressor(comp_name)
            cfg = VRLConfig(algorithm="vrl_sgd", comm_period=k,
                            learning_rate=0.01, weight_decay=1e-4,
                            update_backend="auto", compress=comp)
            eng = make_engine(cfg, jax.eval_shape(lambda: params))
            gk_buf = jax.jit(lambda g: jax.vmap(
                lambda t: flat.flatten_stacked(eng.spec, t,
                                               dtype=eng.spec.dtype)
            )(g))(grads_k)
            rstep = jax.jit(eng.round_step_flat, donate_argnums=(0,))
            box = [eng.init(params, workers)]

            def one_round():
                box[0] = rstep(box[0], gk_buf)
                return box[0]

            us = timeit(one_round, iters=iters, warmup_iters=1)
            es = eng.spec
            item = jnp.dtype(es.dtype).itemsize
            raw_b = cc.raw_bytes(es.rows, es.lanes, item)
            spec_c = cc.resolve(comp)
            if spec_c is None:
                wire_b = raw_b
            else:
                # the real next-round payload: drift vs ref (+ residual)
                st = box[0]
                payload = (st.params.astype(jnp.float32)
                           - st.comm.ref[None])
                if spec_c.error_feedback:
                    payload = payload + st.comm.resid
                rep = cc.compress(spec_c, payload,
                                  rows_used=cc.used_rows(es.size, es.lanes))
                wire_b = cc.rep_nbytes(rep) // workers
            entry = {"round_us": round(us, 1), "wire_bytes": int(wire_b),
                     "raw_bytes": int(raw_b),
                     "reduction": round(raw_b / wire_b, 2)}
            if comp_name == "none":
                base_us = us
            elif base_us:
                entry["over_none"] = round(us / base_us, 3)
            row[comp_name] = entry
            csv(f"engine/compressed/{comp_name}/d{dim}", us,
                f"{n_params/1e6:.2f}M params x {workers} workers, k={k}; "
                f"wire={wire_b} raw={raw_b} ({raw_b/wire_b:.1f}x)")
        out["sizes"][str(dim)] = row
    _merge_json(out_path, {"compressed": out})
    return out


BYTE_GATES = {"int8": 4.0, "topk": 10.0}


def gate_compressed(res: dict, time_ratio: float) -> int:
    """CI gate over bench_compressed: measured byte reduction must hold
    the headline claim (int8 >= 4x, topk >= 10x) at every size, and each
    compressed round must stay within ``time_ratio`` x the uncompressed
    round.  Returns a process exit code."""
    bad = []
    for dim, row in res["sizes"].items():
        for name, floor in BYTE_GATES.items():
            if name not in row:
                continue
            if row[name]["reduction"] < floor:
                bad.append(f"{name}/d{dim} bytes {row[name]['reduction']}x "
                           f"< {floor}x")
            over = row[name].get("over_none")
            if time_ratio:
                if over is None:
                    # a missing 'none' baseline must fail the gate, not
                    # silently skip the time check
                    bad.append(f"{name}/d{dim} has no 'none' baseline — "
                               f"time gate cannot run")
                elif over > time_ratio:
                    bad.append(f"{name}/d{dim} round {over}x > "
                               f"{time_ratio}x uncompressed")
    if bad:
        print("COMPRESSED GATE FAILED: " + "; ".join(bad))
        return 1
    print(f"compressed gate OK: int8 >= {BYTE_GATES['int8']}x, topk >= "
          f"{BYTE_GATES['topk']}x measured bytes; rounds within "
          f"{time_ratio}x uncompressed")
    return 0


# --------------------------------------------- sharded / shrunk state bench
def bench_sharded(*, workers: int = 4, k: int = 4, dim: int = 1024,
                  shards: int = 4, iters: int = 3,
                  out_path: str = "BENCH_engine.json") -> dict:
    """Sharded + shrunk engine state: measured bytes and round parity.

    Four adam/vrl_sgd variants through real rounds on the auto backend:
    the fp32 unsharded baseline, the row-sharded layout (``shards`` —
    meshless here, so layout-only: rows pad to shard boundaries and the
    trajectory must stay BITWISE the baseline; the mesh-placed path is
    exercised in tests/test_engine_collectives.py), bf16 moment storage,
    and bf16 + SM3-factored second moment.  Each variant records its
    measured ``engine_state_bytes`` / ``moment_bytes`` (what actually
    persists between steps, padding included), round time, and its
    average-model drift vs the baseline after two identical rounds.  The
    tile height is pinned (block=128) so baseline and sharded layouts pad
    comparably and the byte reductions measure dtype/factoring, not
    padding luck.  CI gates this section (``--gate-sharded``).
    """
    params = _mlp_template(jax.random.PRNGKey(0), dim)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    grads = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.sin(x), (workers, *x.shape)),
        params)
    scale = (1.0 + 0.01 * jnp.arange(k, dtype=jnp.float32))
    grads_k = jax.tree.map(
        lambda g: g[None] * scale.reshape((k,) + (1,) * g.ndim), grads)
    variants = {
        "baseline": dict(shards=1, moment_dtype="float32", sm3=False),
        "sharded": dict(shards=shards, moment_dtype="float32", sm3=False),
        "bf16": dict(shards=shards, moment_dtype="bfloat16", sm3=False),
        "bf16_sm3": dict(shards=shards, moment_dtype="bfloat16", sm3=True),
    }
    out = {"workers": workers, "k": k, "dim": dim, "shards": shards,
           "n_params": int(n_params),
           "auto_backend": resolve_backend("auto"), "variants": {}}
    avg0 = None
    for name, kv in variants.items():
        cfg = VRLConfig(algorithm="vrl_sgd", comm_period=k,
                        learning_rate=0.01, weight_decay=1e-4,
                        inner_optimizer="adam", update_backend="auto",
                        moment_dtype=kv["moment_dtype"], sm3=kv["sm3"],
                        engine=EngineConfig(block=128,
                                            shards=kv["shards"]))
        eng = make_engine(cfg, jax.eval_shape(lambda: params))
        gk_buf = jax.jit(lambda g, eng=eng: jax.vmap(
            lambda t: flat.flatten_stacked(eng.spec, t,
                                           dtype=eng.spec.dtype)
        )(g))(grads_k)
        rstep = jax.jit(eng.round_step_flat, donate_argnums=(0,))
        state = eng.init(params, workers)
        entry = {"rows": int(eng.spec.rows), "shards": int(eng.spec.shards),
                 "engine_state_bytes": _tree_nbytes(state),
                 "moment_bytes": _tree_nbytes(state.inner)}
        for _ in range(2):                 # two deterministic parity rounds
            state = rstep(state, gk_buf)
        avg = eng.average_model(state)
        if avg0 is None:
            avg0 = avg
            entry["max_abs_diff_vs_baseline"] = 0.0
            entry["bitwise_vs_baseline"] = True
        else:
            pairs = list(zip(jax.tree.leaves(avg), jax.tree.leaves(avg0)))
            entry["max_abs_diff_vs_baseline"] = max(
                float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in pairs)
            entry["bitwise_vs_baseline"] = all(
                bool(jnp.all(a == b)) for a, b in pairs)
        box = [state]

        def one_round(box=box, rstep=rstep, gk_buf=gk_buf):
            box[0] = rstep(box[0], gk_buf)
            return box[0]

        entry["round_us"] = round(timeit(one_round, iters=iters,
                                         warmup_iters=1), 1)
        csv(f"engine/sharded/{name}/d{dim}", entry["round_us"],
            f"state={entry['engine_state_bytes']}B "
            f"moments={entry['moment_bytes']}B "
            f"diff={entry['max_abs_diff_vs_baseline']:.1e}")
        out["variants"][name] = entry
    v = out["variants"]
    out["moment_reduction_bf16"] = round(
        v["baseline"]["moment_bytes"] / v["bf16"]["moment_bytes"], 2)
    out["moment_reduction_bf16_sm3"] = round(
        v["baseline"]["moment_bytes"] / v["bf16_sm3"]["moment_bytes"], 2)
    _merge_json(out_path, {"sharded": out})
    return out


def gate_sharded(res: dict) -> int:
    """CI gate over bench_sharded: the layout-only sharded round must be
    BITWISE the unsharded baseline (zero pad rows are inert — any drift
    is a real sharding bug), bf16 moments must measure >= 1.7x smaller
    than fp32 while staying within 5e-2 of the baseline trajectory at
    this scale, and SM3 must shrink the moments further still.  Returns
    a process exit code."""
    v = res["variants"]
    bad = []
    if not v["sharded"]["bitwise_vs_baseline"]:
        bad.append(f"layout-only sharded round is NOT bitwise the "
                   f"baseline (max diff "
                   f"{v['sharded']['max_abs_diff_vs_baseline']:.2e})")
    if res["moment_reduction_bf16"] < 1.7:
        bad.append(f"bf16 moments only {res['moment_reduction_bf16']}x "
                   f"smaller than fp32 (< 1.7x)")
    if v["bf16"]["max_abs_diff_vs_baseline"] > 5e-2:
        bad.append(f"bf16 trajectory drift "
                   f"{v['bf16']['max_abs_diff_vs_baseline']:.2e} > 5e-2")
    if v["bf16_sm3"]["moment_bytes"] >= v["bf16"]["moment_bytes"]:
        bad.append("SM3 did not shrink the moment buffers below bf16's")
    if bad:
        print("SHARDED GATE FAILED: " + "; ".join(bad))
        return 1
    print(f"sharded gate OK: layout-only bitwise, bf16 moments "
          f"{res['moment_reduction_bf16']}x (sm3 "
          f"{res['moment_reduction_bf16_sm3']}x), drift <= 5e-2")
    return 0


def bench_participation(*, num_clients: int = 16, k: int = 10,
                        rounds_max: int = 80, batch: int = 32,
                        lr: float = 0.5, seed: int = 0,
                        participation=(0.25, 0.5, 1.0),
                        out_path: str = "BENCH_engine.json") -> dict:
    """Rounds-to-target vs participation on the fig1 non-identical task.

    M logical clients hold disjoint class shards (the paper's
    partitioning); each round a seed-deterministic cohort of W = p·M
    clients is gathered from a ``ClientStore``, runs k VRL-SGD local
    steps on ITS OWN shard, syncs (one all-reduce), and scatters back.
    The target is the loss full participation reaches a fifth of the way
    into the budget — an intermediate milestone, since a p-participation
    round does p times the gradient work of a full round, so reaching
    full participation's ENDPOINT inside the same budget is impossible
    by construction.  Every regime then reports the rounds it needs to
    reach that common milestone: the measured rounds-vs-work trade-off.
    """
    import numpy as np

    from benchmarks.common import feature_classification, mlp_init, \
        mlp_loss
    from repro.core.clients import ClientStore, sample_cohort
    from repro.data.partition import class_shard_partition

    data = feature_classification(n=4096, dim=256, num_classes=64,
                                  seed=seed)
    parts = class_shard_partition(data.y, num_clients, seed=seed)
    params = mlp_init(jax.random.PRNGKey(seed), in_dim=data.x.shape[1],
                      hidden=128, classes=data.num_classes)
    template = jax.eval_shape(lambda: params)
    # a fixed global batch scores the average model across regimes
    ev = np.random.RandomState(seed + 1).choice(len(data.y), 512,
                                                replace=False)
    ex, ey = jnp.asarray(data.x[ev]), jnp.asarray(data.y[ev])

    def run(p: float) -> dict:
        w = max(1, round(p * num_clients))
        cfg = VRLConfig(algorithm="vrl_sgd", comm_period=k,
                        learning_rate=lr, weight_decay=1e-4,
                        warmup=False, update_backend="xla")
        eng = make_engine(cfg, template)
        state = eng.init(params, w)
        store = ClientStore(state, num_clients)
        rec = (jax.jit(eng.recenter_drift)
               if num_clients > w else None)

        @jax.jit
        def step(s, xs, ys):
            def per_worker(pp, x, y):
                return jax.grad(mlp_loss)(pp, x, y)
            grads = jax.vmap(per_worker)(eng.params_tree(s), xs, ys)
            return eng.train_step(s, grads)

        @jax.jit
        def eval_loss(s):
            return mlp_loss(eng.average_model(s), ex, ey)

        rng = np.random.RandomState(seed + 2)
        curve = []
        for r in range(rounds_max):
            cohort = sample_cohort(num_clients, w, r, seed)
            st = store.gather(cohort, seed_params=rec is not None
                              and r > 0)
            if rec is not None:
                st = rec(st)
            for _ in range(k):
                idx = np.stack([rng.choice(parts[c], batch)
                                for c in cohort])
                st = step(st, jnp.asarray(data.x[idx]),
                          jnp.asarray(data.y[idx]))
            store.scatter(st, cohort)
            curve.append(float(eval_loss(st)))
        return {"workers": w, "curve": curve}

    out = {"num_clients": num_clients, "k": k, "batch": batch, "lr": lr,
           "rounds_max": rounds_max, "regimes": {}}
    full = run(1.0)
    target = full["curve"][rounds_max // 5 - 1]
    out["target_loss"] = round(target, 4)
    for p in sorted(participation, reverse=True):
        res = full if p == 1.0 else run(p)
        hit = next((r + 1 for r, v in enumerate(res["curve"])
                    if v <= target), None)
        row = {"workers": res["workers"],
               "rounds_to_target": hit,
               "final_loss": round(res["curve"][-1], 4)}
        out["regimes"][str(p)] = row
        csv(f"participation/p{p}", 0.0,
            f"workers={res['workers']};rounds_to_target={hit};"
            f"final_loss={row['final_loss']}")
    _merge_json(out_path, {"participation": out})
    return out


def gate_participation(res: dict) -> int:
    """CI gate: every regime must actually REACH the full-participation
    target within the round budget — client sampling trades rounds for
    per-round work, it must not break convergence.  Returns an exit
    code."""
    bad = [f"p={p}: never reached target {res['target_loss']} "
           f"(final {row['final_loss']})"
           for p, row in res["regimes"].items()
           if row["rounds_to_target"] is None]
    if bad:
        print("PARTICIPATION GATE FAILED: " + "; ".join(bad))
        return 1
    rounds = {p: row["rounds_to_target"]
              for p, row in res["regimes"].items()}
    print(f"participation gate OK: rounds-to-target {rounds} "
          f"(target {res['target_loss']})")
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="all",
                    choices=["paper", "engine", "hier", "rounds",
                             "compressed", "overlap", "sharded",
                             "participation", "all"])
    ap.add_argument("--include-interpret", action="store_true",
                    help="time the fused Pallas rows even where they "
                         "would run in interpret mode (off-TPU/GPU they "
                         "are skipped by default: interpret timings "
                         "measure python dispatch, not the kernel)")
    ap.add_argument("--dims", default="256,1024",
                    help="comma list of model sizes (dim of the MLP bench)")
    ap.add_argument("--k", type=int, default=8,
                    help="bench_rounds communication period")
    ap.add_argument("--algs", default="vrl_sgd",
                    help="bench_rounds: comma list of algorithms to bench "
                         "and gate (e.g. vrl_sgd,stl_sgd,bvr_l_sgd)")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--gate-ratio", type=float, default=0.0,
                    help="bench_rounds: exit 1 if auto/reference round "
                         "time exceeds this at any size (0 = no gate)")
    ap.add_argument("--gate-diag", type=float, default=0.0,
                    help="bench_rounds: exit 1 if (round + diagnostics "
                         "pass) exceeds this ratio x the bare round at "
                         "the largest size for any algorithm (0 = no "
                         "gate)")
    ap.add_argument("--gate-overlap", type=float, default=0.0,
                    help="bench_overlap: exit 1 if the overlapped round's "
                         "p50 exceeds this ratio x the blocking round's "
                         "p50 at any size (0 = no gate)")
    ap.add_argument("--gate-compressed", type=float, default=0.0,
                    help="bench_compressed: gate the measured byte "
                         "reductions (int8 >= 4x, topk >= 10x) and hold "
                         "each compressed round within this ratio of the "
                         "uncompressed round (0 = no gate)")
    ap.add_argument("--gate-sharded", action="store_true",
                    help="bench_sharded: gate the sharded/shrunk-state "
                         "section (layout-only sharding bitwise, bf16 "
                         "moments >= 1.7x smaller within 5e-2 drift, SM3 "
                         "smaller still)")
    ap.add_argument("--gate-participation", action="store_true",
                    help="bench_participation: exit 1 if any sampled "
                         "regime fails to reach the full-participation "
                         "loss target within the round budget")
    args = ap.parse_args()
    dims = tuple(int(d) for d in args.dims.split(","))

    code = 0
    if args.bench in ("paper", "all"):
        main()
    if args.bench in ("engine", "all"):
        bench_engine(dims=dims, include_interpret=args.include_interpret)
    if args.bench in ("hier", "all"):
        bench_hierarchical(dims=dims,
                           include_interpret=args.include_interpret)
    if args.bench in ("rounds", "all"):
        rounds = bench_rounds(dims=dims, k=args.k, iters=args.iters,
                              include_interpret=args.include_interpret,
                              algs=tuple(a for a in args.algs.split(",")
                                         if a))
        if args.gate_ratio:
            code |= gate_rounds(rounds, args.gate_ratio)
        if args.gate_diag:
            code |= gate_diag(rounds, args.gate_diag)
    if args.bench in ("overlap", "all"):
        ov = bench_overlap(dims=dims, k=args.k,
                           iters=max(args.iters, 20),
                           algs=tuple(a for a in args.algs.split(",")
                                      if a))
        if args.gate_overlap:
            code |= gate_overlap(ov, args.gate_overlap)
    if args.bench in ("compressed", "all"):
        comp = bench_compressed(dims=dims, k=args.k, iters=args.iters)
        if args.gate_compressed:
            code |= gate_compressed(comp, args.gate_compressed)
    if args.bench in ("sharded", "all"):
        shd = bench_sharded(k=args.k, iters=args.iters)
        if args.gate_sharded:
            code |= gate_sharded(shd)
    if args.bench in ("participation", "all"):
        part = bench_participation()
        if args.gate_participation:
            code |= gate_participation(part)
    sys.exit(code) if code else None
